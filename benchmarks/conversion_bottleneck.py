"""Figure 8 reproduction: prototype optical FT vs software FFT.

The software side is *measured* (NumPy/JAX FFT of the same 1024x768 frame,
on this host); the hardware side is the calibrated component model of the
prototype (repro.core.accelerator.PROTOTYPE_4F), whose constants were fit
to the paper's measured totals: 5.209 s end-to-end, 99.599 % of it data
movement, 23.8x slower than the software FFT on the Raspberry Pi 4 host.

Also runs the simulated accelerator *functionally* (repro.core.optical)
on a reduced frame to demonstrate the computation the hardware performs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.accelerator import PROTOTYPE_4F
from repro.core.optical import OpticalSimParams, optical_fft2_magnitude

__all__ = ["run"]

FRAME = (1024, 768)
PAPER_SOFTWARE_S = 0.219
PAPER_HARDWARE_S = 5.209
PAPER_MOVEMENT_PCT = 99.599


def run() -> dict:
    # measured software FFT on this host
    a = jax.random.uniform(jax.random.PRNGKey(0), FRAME)
    jnp.fft.fft2(a).block_until_ready()          # warm-up
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        jnp.fft.fft2(a).block_until_ready()
    sw_s = (time.perf_counter() - t0) / reps

    # modeled prototype hardware cost for the same frame
    cost = PROTOTYPE_4F.step_cost(FRAME[0] * FRAME[1])

    # functional sim on a reduced frame (the physics the hardware performs).
    # 16-bit detector: the DC peak of a natural frame sits ~14 bits above
    # the AC spectrum (see examples/quickstart.py for the bit sweep).
    params = OpticalSimParams(dac_bits=8, adc_bits=16)
    small = jax.random.uniform(jax.random.PRNGKey(1), (256, 192))
    mag = optical_fft2_magnitude(small, params)
    oracle = jnp.abs(jnp.fft.fft2(small, norm="ortho"))
    i_err = float(jnp.mean(jnp.abs(mag ** 2 - oracle ** 2))
                  / jnp.maximum(jnp.mean(oracle ** 2), 1e-12))

    return {
        "software_fft_s": sw_s,
        "hardware_total_s": cost.total_s,
        "hardware_movement_pct": 100 * cost.data_movement_fraction,
        "hardware_vs_software": cost.total_s / sw_s,
        "paper_hardware_vs_software": PAPER_HARDWARE_S / PAPER_SOFTWARE_S,
        "paper_movement_pct": PAPER_MOVEMENT_PCT,
        "sim_intensity_rel_err": i_err,
        "breakdown": {
            "dac_s": cost.dac_s, "adc_s": cost.adc_s,
            "interface_s": cost.interface_s, "analog_s": cost.analog_s,
        },
    }
