"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from experiments/dryrun/*.json:

  compute_s    = jaxpr_flops_global / (chips * PEAK_FLOPS)
  memory_s     = bytes_accessed_corrected / HBM_BW            (per-chip)
  collective_s = collective_bytes_corrected / LINK_BW         (per-chip)

(bytes/collectives are per-device from the partitioned HLO, scan-corrected
— see dryrun.py; flops are exact global jaxpr counts / chips.)

Also: dominant term, MODEL_FLOPS = 6*N(_active)*D vs HLO flops (the
"useful-compute" ratio, catching remat/redundant work), and a one-line
lever per cell.
"""

from __future__ import annotations

import glob
import json
import os

__all__ = ["load_cells", "roofline_row", "run", "PEAK_FLOPS", "HBM_BW", "LINK_BW"]

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link (ICI)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(art_dir: str = ART_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _tokens(shape: str) -> int:
    from repro.configs import SHAPES
    sh = SHAPES[shape]
    if sh.kind == "train":
        return sh.seq_len * sh.global_batch
    if sh.kind == "prefill":
        return sh.seq_len * sh.global_batch
    return sh.global_batch            # decode: one token per lane


def roofline_row(cell: dict) -> dict:
    chips = cell["devices"]
    flops_g = cell.get("jaxpr_flops_global", cell["flops"] * chips)
    compute_s = flops_g / (chips * PEAK_FLOPS)
    memory_s = cell.get("bytes_accessed_corrected",
                        cell["bytes_accessed"]) / HBM_BW
    coll_s = cell.get("collective_bytes_corrected",
                      cell["collective_bytes_total"]) / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: 6*N*D with N = active params (MoE) and D = tokens; for
    # train shapes this is fwd+bwd; prefill/decode use 2*N*D (fwd only).
    toks = _tokens(cell["shape"])
    n = cell["params_active"]
    mult = 6.0 if cell["shape"].startswith("train") else 2.0
    model_flops = mult * n * toks
    useful = model_flops / flops_g if flops_g else 0.0
    bound_s = max(terms.values())
    return {
        "cell": cell["cell"], "arch": cell["arch"], "shape": cell["shape"],
        "mesh": cell["mesh"], "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "roofline_fraction": compute_s / bound_s if bound_s else 0.0,
        "step_lower_bound_s": bound_s,
    }


_LEVERS = {
    "compute": "compute-bound: raise MFU via larger per-chip tiles or fewer "
               "remat recomputes",
    "memory": "memory-bound: fuse converter/elementwise passes, shrink "
              "activation dtype, raise arithmetic intensity per HBM byte",
    "collective": "collective-bound: reshard to cut all-gathers (seq-parallel "
                  "attention / EP all-to-all overlap / int8 cross-pod grads)",
}


def run(art_dir: str = ART_DIR) -> list[dict]:
    rows = [roofline_row(c) for c in load_cells(art_dir)]
    for r in rows:
        r["lever"] = _LEVERS[r["dominant"]]
    return rows


def table(rows: list[dict]) -> str:
    hdr = (f"{'cell':58s} {'comp_s':>10s} {'mem_s':>10s} {'coll_s':>10s} "
           f"{'dom':>10s} {'useful':>7s} {'roof%':>6s}")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['cell']:58s} {r['compute_s']:10.3e} {r['memory_s']:10.3e} "
            f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {100*r['roofline_fraction']:6.1f}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table(run()))
