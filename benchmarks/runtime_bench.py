"""Runtime benchmark: batching amortizes the conversion boundary — for real.

Three claims, measured on the executing runtime (not just the cost model):

* **Amortization sweep** — submitting K same-shape FFT offload calls and
  letting the executor coalesce them into ONE batched invocation reduces
  both the modeled per-call conversion + interface time AND the measured
  wall time per call (the paper's §6 lever: one link handshake, one SLM
  settle, one lane-ceil residue, one dispatch round-trip, one kernel
  launch per batch instead of per call).  The ``looped_speedup`` column is
  the measured batched-vs-looped execution ratio.
* **Pipelined flush** — the executor's two-deep async flush (DAC-in of
  invocation k+1 staged while invocation k's analog+ADC compute is in
  flight) beats strictly serial dispatch-then-block crossings.
* **Telemetry round trip** — traffic profiled by the runtime itself feeds
  ``plan_offload`` and yields a plan whose offload decisions match how the
  router then executes (categories the plan offloads run on the analog
  backend, the rest stay host).
* **Trickle arrivals: holding vs drain-on-flush** — under a Poisson
  arrival process too sparse to fill a batch between flushes, the
  admission-controlled ``OffloadScheduler`` holds partially filled groups
  open across flushes (releasing on full / deadline / futile-to-wait) and
  achieves strictly higher measured occupancy — calls and boundary samples
  per conversion crossing — than the drain-every-flush regime, at a
  bounded queueing-delay cost that the modeled wall prices explicitly
  (``StepCost.hold_s``).  Arrivals ride a ``ManualClock``, so the
  admission decisions (and therefore the column) are deterministic.
* **Large frames: looped vs monolithic vs memory-budgeted tiled** — at
  512x512 the monolithic (K, H, W) stack blows the LLC off-TPU and
  batching measurably loses to looping; the memory-budgeted executor
  streams the group as ``choose_tile``-sized sub-invocations through the
  two-deep pipeline and beats both.  The row stamps the budget (bytes,
  source) and asserts the budget-chosen ``tile_k`` is the tile size the
  executor actually dispatched.
* **Traced column** — the opt-in span tracer re-runs the K-deep flush and
  reports (a) its own overhead vs the untraced executor (< 5% or the CI
  smoke fails), (b) how much of the measured flush wall the per-stage
  charged spans reconcile (coverage ~1), and (c) the boundary-stage drift
  ratio (measured host staging / modeled DAC+interface), gated by
  ``drift_gate`` against a static band plus the ``BENCH_history.jsonl``
  median.
* **Chaos column** — the same offload traffic through a chaos-wrapped
  optical backend injecting a seeded fault mix (transient errors,
  stragglers, ENOB drift, device loss) at 0 / 1% / 10% per-dispatch
  rates: every frame still retires within the ENOB bound of the looped
  host baseline (retry + host fallback + drift correction), and the row
  reports goodput, fault counts, recovery-latency percentiles, and
  quarantine events.  A separate overhead row shows the rate-0 chaos
  wrapper costs < 2% on the traced wall.
* **Residency column** — a conv layer stack re-using its frames and
  kernel through the opt-in operand residency cache: the cached flush
  (every operand resident) beats the always-cold re-stage flush on the
  measured wall, the modeled hit cost carries zero write-side DAC time
  (read-side-only pricing), and the results stay bit-equal to the
  residency-off executor.
* **Sharded vs single-device** — scattering the K=16 flush group across n
  replicated simulated accelerators (each paying its own DAC/ADC boundary)
  cuts the modeled invocation wall to max-over-devices + sync: the
  streaming conversion/interface terms split n ways while every device
  still pays the frame-sync handshake.  The wall column on a single real
  device exercises the *sequential fallback* (n smaller dispatches, no
  parallel hardware — expect ~1x or below); with real devices present the
  shards scatter via ``device_put`` and the wall follows the modeled
  column.

Frames are 128x128: small enough that per-invocation dispatch/launch
overhead is a real fraction of the work (the regime §6 batching targets —
at CNN-feature-map scale the boundary dominates), while 16 of them still
pack into one 2048x2048 SLM frame (one frame-sync).

Run:  PYTHONPATH=src python -m benchmarks.runtime_bench
"""

from __future__ import annotations

import dataclasses
import datetime
import json
import time

import jax
import numpy as np

from repro.runtime import (
    BATCHED_4F,
    CONV_CAPTURES,
    FidelityChecker,
    ManualClock,
    MemoryBudget,
    OffloadExecutor,
    OffloadScheduler,
    PlanRouter,
    Tracer,
    choose_tile,
    drift_report,
    enob_error_bound,
    reconcile,
    register_chaos,
    write_trace,
)

SHAPE = (128, 128)
CALLS = 16
BENCH_JSON = "BENCH_runtime.json"
BENCH_HISTORY = "BENCH_history.jsonl"

# Tolerance band for the boundary-stage drift gate (measured host staging /
# modeled DAC+interface price).  Below 1: the host stages frames cheaper
# than the modeled optical boundary converts them — the headroom every
# batching claim rests on.  Above 1 would mean the runtime's own dispatch
# overhead exceeds the boundary cost it claims to amortize (the cost model
# and reality have diverged in the claim-breaking direction); the low edge
# catches a broken clock / empty measurement masquerading as speed.
DRIFT_BAND = (0.005, 1.0)
DRIFT_HISTORY_FACTOR = 4.0  # vs the median of prior runs, when >= 3 exist

# Large-frame scenario: the regime where a monolithic (K, H, W) stack
# falls out of the LLC off-TPU (ROADMAP's last open lever) and the
# memory budget decides the staging granularity.
LARGE_SHAPE = (512, 512)
LARGE_CALLS = 16

# Chaos scenario: the fault-injection config stamped into
# BENCH_runtime.json.  Rates are per-dispatch fault probabilities; the
# schedule is seeded, so every bench run injects the identical fault
# sequence and the goodput/recovery columns are comparable across PRs.
CHAOS_RATES = (0.0, 0.01, 0.10)
CHAOS_CALLS = 48
CHAOS_SHAPE = (64, 64)
CHAOS_MAX_BATCH = 8
# seed chosen so the 10% stream provably injects within the bench's
# dispatch count (48 calls / max_batch 8 -> 6 draws; seed 2 faults at
# draw 2) — a chaos bench that never faults proves nothing
CHAOS_SEED = 2

# Trickle-arrival scenario: the scheduler config stamped into
# BENCH_runtime.json so the occupancy trajectory stays interpretable
# across PRs (change these and the column's meaning changes with them).
TRICKLE_RATE_HZ = 200.0     # mean Poisson arrival rate
TRICKLE_DEADLINE_S = 0.05   # per-call queueing-delay budget while held
TRICKLE_ARRIVALS = 48
TRICKLE_MAX_BATCH = 8
TRICKLE_SEED = 0


def _images(n: int = CALLS, shape: tuple[int, int] = SHAPE):
    key = jax.random.PRNGKey(7)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _timed_flush(ex: OffloadExecutor, imgs, reps: int = 3) -> float:
    """Best-of-``reps`` measured wall seconds per call for one full flush."""
    best = float("inf")
    for _ in range(reps):
        handles = [ex.submit("fft", im) for im in imgs]
        t0 = time.perf_counter()
        ex.flush()
        best = min(best, (time.perf_counter() - t0) / len(handles))
    return best


def sweep(batch_sizes=(1, 2, 4, 8, 16), shape: tuple[int, int] = SHAPE,
          calls: int = CALLS) -> list[dict]:
    """Measured + modeled per-call cost vs executor batch ceiling.

    Every executor is warmed first (single-item AND batched jit shapes) so
    first-flush compilation does not masquerade as execution time.  The
    ``max_batch=1`` row is the looped baseline: one invocation per call.
    """
    imgs = _images(calls, shape)
    rows = []
    looped_wall = None
    for k in batch_sizes:
        ex = OffloadExecutor(BATCHED_4F, max_batch=k)
        ex.warm("fft", imgs[0])
        wall = _timed_flush(ex, imgs)
        # fresh telemetry for the cost-collection flush, so the reported
        # invocation count reflects exactly the CALLS submitted calls (the
        # timing reps above would otherwise inflate it)
        ex.telemetry.reset()
        handles = [ex.submit("fft", im) for im in imgs]
        ex.flush()
        # per-call share of the modeled batched invocation cost, averaged
        # over the calls (the tail batch may be smaller than k)
        per_call = [h.cost.conversion_s + h.cost.interface_s for h in handles]
        total = [h.cost.total_s for h in handles]
        if looped_wall is None:
            looped_wall = wall
        rows.append({
            "max_batch": k,
            "boundary_s_per_call": sum(per_call) / len(per_call),
            "modeled_s_per_call": sum(total) / len(total),
            "wall_s_per_call": wall,
            "looped_speedup": looped_wall / max(wall, 1e-12),
            "invocations": ex.telemetry.stats[("fft", "optical-sim")].invocations,
        })
    return rows


def pipeline_comparison(shape: tuple[int, int] = (256, 256),
                        calls: int = CALLS) -> dict:
    """Two-deep async flush vs strictly serial dispatch-then-block.

    ``max_batch=1`` forces one invocation per call so the flush has
    ``calls`` boundary crossings to overlap; the only difference between
    the two executors is ``pipeline_depth``.  Frames are 256x256 — the
    overlap hides the host-side staging/retire work behind in-flight
    device compute, so each crossing needs enough compute to hide it
    behind (at 128x128 the win is within run-to-run noise).
    """
    imgs = _images(calls, shape)
    walls = {}
    for depth in (1, 2):
        ex = OffloadExecutor(BATCHED_4F, max_batch=1, pipeline_depth=depth)
        ex.warm("fft", imgs[0])
        walls[depth] = _timed_flush(ex, imgs)
    return {
        "serial_wall_s_per_call": walls[1],
        "pipelined_wall_s_per_call": walls[2],
        "pipeline_speedup": walls[1] / max(walls[2], 1e-12),
    }


def _scatter_stage_s(tracer: Tracer, calls: int) -> float:
    """Per-call sum of scatter-staging span time across all devices — the
    host-side re-``device_put`` cost the resident placement eliminates."""
    return (sum(s.duration_s for s in tracer.find("scatter_stage"))
            / max(calls, 1))


def sharded_comparison(shape: tuple[int, int] = SHAPE, calls: int = CALLS,
                       device_counts=(1, 2, 4)) -> list[dict]:
    """Group-sharded flush across n simulated accelerators vs one.

    The ``n_devices=1`` row is the single-device batched baseline.  The
    modeled column is the multi-aperture claim (max-over-devices boundary
    cost + per-device sync) — deterministic, asserted by the CI smoke; the
    wall column is honest about the hardware underneath (sequential
    fallback on one device, genuinely scattered when ``jax.devices()`` has
    enough).

    Two columns attack the re-scatter tax the 0.71x investigation blamed
    (every flush re-``device_put``-ing every shard through the host):

      resident    the same group flushed through a committed device-
                  resident placement (``residency=True``): after the
                  priming flush the shards live on their devices, repeat
                  flushes skip the scatter staging entirely and gather
                  only at readout.  ``scatter_stage_s`` / ``resident_
                  scatter_stage_s`` attribute the eliminated staging
                  per row from traced scatter spans.
      per_engine  a mixed fft+conv stream dispatched under per-engine
                  pipeline windows vs the old single shared window
                  (``shared_window=True``), with the ``engines=``
                  composed modeled price alongside the measured walls.
    """
    imgs = _images(calls, shape)
    h_, w_ = shape
    conv_kernel = (jax.numpy.zeros(shape)
                   .at[0, 0].set(0.5).at[1, 2].set(0.25)
                   .at[h_ - 1, 1].set(0.15))
    rows = []
    base_wall = base_modeled = None
    for n in device_counts:
        ex = OffloadExecutor(BATCHED_4F, max_batch=calls, n_devices=n,
                             default_backend="sharded")
        ex.warm("fft", imgs[0], batch=calls)
        wall = _timed_flush(ex, imgs)
        ex.telemetry.reset()
        handles = [ex.submit("fft", im) for im in imgs]
        ex.flush()
        modeled = sum(h.cost.total_s for h in handles) / len(handles)
        boundary = sum(h.cost.conversion_s + h.cost.interface_s
                       for h in handles) / len(handles)
        if base_wall is None:
            base_wall, base_modeled = wall, modeled
        # attribution flush (satellite of the 0.71x investigation): rerun
        # the same group with a tracer attached so the row carries the
        # per-device scatter-staging breakdown and the per-stage drift —
        # the timed wall above stays untraced
        tracer = Tracer()
        ex.tracer = ex.ctx.tracer = tracer
        for h in [ex.submit("fft", im) for im in imgs]:
            pass
        ex.flush()
        ex.tracer = ex.ctx.tracer = None
        rep = drift_report(tracer.spans())
        scatter_s = _scatter_stage_s(tracer, calls)

        # resident column: same group, committed placement — the priming
        # flush pays the scatter once, the timed reps flush against
        # device-resident shards
        ex_r = OffloadExecutor(BATCHED_4F, max_batch=calls, n_devices=n,
                               default_backend="sharded", residency=True)
        ex_r.warm("fft", imgs[0], batch=calls)
        for im in imgs:                       # priming flush
            ex_r.submit("fft", im)
        ex_r.flush()
        resident_wall = _timed_flush(ex_r, imgs)
        r_tracer = Tracer()
        ex_r.tracer = ex_r.ctx.tracer = r_tracer
        for im in imgs:
            ex_r.submit("fft", im)
        ex_r.flush()
        ex_r.tracer = ex_r.ctx.tracer = None
        resident_scatter_s = _scatter_stage_s(r_tracer, calls)

        # per_engine column: fft and conv streams in one flush — each
        # engine rides its own pipeline window vs the old shared gate
        mb = max(2, calls // 4)
        pe_walls = {}
        for shared in (False, True):
            ex_m = OffloadExecutor(BATCHED_4F, max_batch=mb, n_devices=n,
                                   default_backend="sharded",
                                   shared_window=shared)
            ex_m.warm("fft", imgs[0], batch=mb)
            ex_m.warm("conv", imgs[0], kernel=conv_kernel, batch=mb)
            best = float("inf")
            for _ in range(3):
                hs = []
                for im in imgs:
                    hs.append(ex_m.submit("fft", im))
                    hs.append(ex_m.submit("conv", im, kernel=conv_kernel))
                t0 = time.perf_counter()
                ex_m.flush()
                best = min(best, (time.perf_counter() - t0) / len(hs))
            pe_walls[shared] = best
        # engines= composed modeled price for one fft+conv window pair
        n_in = shape[0] * shape[1]
        spec4 = dataclasses.replace(BATCHED_4F,
                                    phase_shift_captures=CONV_CAPTURES)
        composed = BATCHED_4F.batched_step_cost(n_in, engines={
            "fft": BATCHED_4F.batched_step_cost(
                n_in, batch=mb, pipeline_depth=2, n_devices=n),
            "conv": spec4.batched_step_cost(
                n_in, batch=mb, pipeline_depth=2, n_devices=n),
        })
        rows.append({
            "n_devices": n,
            "wall_s_per_call": wall,
            "modeled_s_per_call": modeled,
            "boundary_s_per_call": boundary,
            "wall_speedup": base_wall / max(wall, 1e-12),
            "modeled_speedup": base_modeled / max(modeled, 1e-12),
            "scatter_stage_s": scatter_s,
            "resident_wall_s_per_call": resident_wall,
            "resident_wall_speedup": base_wall / max(resident_wall, 1e-12),
            "resident_vs_rescatter": wall / max(resident_wall, 1e-12),
            "resident_scatter_stage_s": resident_scatter_s,
            "resident_hit_rate": ex_r.telemetry.residency_hit_rate("fft"),
            "per_engine_wall_s_per_call": pe_walls[False],
            "shared_window_wall_s_per_call": pe_walls[True],
            "per_engine_speedup": pe_walls[True] / max(pe_walls[False],
                                                       1e-12),
            "per_engine_modeled_s_per_call": composed.total_s / (2 * mb),
            "devices_present": len(jax.devices()),
            "devices_used": ex.telemetry.devices_observed("fft"),
            "trace": rep.to_json(),
        })
    return rows


def traced_comparison(shape: tuple[int, int] = SHAPE, calls: int = CALLS,
                      trace_path: str | None = None) -> dict:
    """The observability column: what does attaching a tracer cost, and do
    its spans reconcile with both the measured wall and the cost model?

    Three numbers, each a gate the CI smoke asserts:

    * ``tracer_overhead`` — best-of-reps traced vs untraced K-deep flush
      wall (< 5%: tracing must be cheap enough to leave on in serving).
    * ``reconcile.coverage`` — per-stage charged sums (stage + compute +
      hold + shadow) over the measured accounting-flush wall (~1: the
      span decomposition accounts for the flush end to end).
    * ``drift.stages.stage.drift`` — measured host staging vs the modeled
      DAC+interface price (:func:`drift_gate`'s tolerance band).

    Pass ``trace_path`` to also write the Perfetto-loadable export (the CI
    trace artifact).
    """
    imgs = _images(calls, shape)
    ex0 = OffloadExecutor(BATCHED_4F, max_batch=calls)
    ex0.warm("fft", imgs[0])
    untraced = _timed_flush(ex0, imgs, reps=5)
    tracer = Tracer()
    ex = OffloadExecutor(BATCHED_4F, max_batch=calls, tracer=tracer)
    ex.warm("fft", imgs[0])
    traced = _timed_flush(ex, imgs, reps=5)
    # accounting flush on a cleared trace: one flush's spans, one wall
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    t0 = time.perf_counter()
    ex.flush()
    flush_wall = time.perf_counter() - t0
    spans = tracer.spans()
    rec = reconcile(spans, flush_wall)
    rep = drift_report(spans)
    out = {
        "shape": list(shape),
        "calls": calls,
        "untraced_wall_s_per_call": untraced,
        "traced_wall_s_per_call": traced,
        "tracer_overhead": traced / max(untraced, 1e-12) - 1.0,
        "spans": len(spans),
        "reconcile": rec,
        "drift": rep.to_json(),
    }
    if trace_path:
        write_trace(trace_path, spans)
        out["trace_path"] = trace_path
    return out


def drift_gate(drift: dict, history: list[dict] | None = None,
               band: tuple[float, float] = DRIFT_BAND,
               history_factor: float = DRIFT_HISTORY_FACTOR,
               ) -> tuple[bool, str]:
    """The regression gate over the boundary stage's drift ratio.

    ``drift`` is a ``DriftReport.to_json()`` dict.  Passes when the
    boundary ("stage") drift is inside ``band`` — and, when ``history``
    (prior ``BENCH_history.jsonl`` records) holds at least 3 prior traced
    runs, within ``history_factor`` of their median, so a slow machine-
    local regression trips even inside the static band.
    """
    stage = drift.get("stages", {}).get("stage", {})
    d = stage.get("drift")
    if d is None or d == "inf":
        return False, f"boundary stage drift unmeasurable: {stage!r}"
    d = float(d)
    lo, hi = band
    if not lo <= d <= hi:
        return False, (f"boundary stage drift {d:.4f} outside tolerance "
                       f"band [{lo}, {hi}] — cost model and measured "
                       f"staging have diverged")
    prior = []
    for rec in history or []:
        try:
            p = rec["traced"]["drift"]["stages"]["stage"]["drift"]
        except (KeyError, TypeError):
            continue
        if isinstance(p, (int, float)):
            prior.append(float(p))
    if len(prior) >= 3:
        med = sorted(prior)[len(prior) // 2]
        if not med / history_factor <= d <= med * history_factor:
            return False, (f"boundary stage drift {d:.4f} is more than "
                           f"{history_factor}x away from the history "
                           f"median {med:.4f} ({len(prior)} prior runs)")
        return True, (f"boundary stage drift {d:.4f} within band {band} "
                      f"and {history_factor}x of history median {med:.4f}")
    return True, f"boundary stage drift {d:.4f} within band {band}"


def load_history(path: str = BENCH_HISTORY) -> list[dict]:
    """Prior bench records, oldest first (empty when no history yet)."""
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    out = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return out


def append_history(payload: dict, path: str = BENCH_HISTORY) -> dict:
    """Append one timestamped record to the bench trajectory.

    ``BENCH_runtime.json`` is overwritten in place on every run, so on its
    own the repo holds no perf *trajectory*; this JSONL keeps every run
    (UTC-stamped), which is what the drift gate's history band and any
    cross-PR perf question read."""
    rec = dict(ts=datetime.datetime.now(datetime.timezone.utc)
               .isoformat(timespec="seconds"), **payload)
    with open(path, "a") as f:
        f.write(json.dumps(rec, default=str) + "\n")
    return rec


def large_frame_comparison(shape: tuple[int, int] = LARGE_SHAPE,
                           calls: int = LARGE_CALLS) -> dict:
    """Looped vs monolithic vs memory-budgeted tiled dispatch at 512x512.

    At large frames the monolithic ``(K, H, W)`` stack (in + complex
    intermediates + out: ~64 MB here) falls out of the CPU's last-level
    cache off-TPU, so one big batched invocation turns every XLA pass into
    a DRAM stream — *batching measurably loses to looping*.  The
    memory-budgeted executor streams the same released group as
    ``choose_tile``-sized sub-invocations through the two-deep async
    pipeline instead: amortization per tile, cache-resident working set,
    staging of tile t+1 overlapped with tile t's in-flight compute.  The
    row stamps the budget it ran under (bytes, source, reserve) plus the
    ``tile_k`` the budget chose AND the tile sizes the executor actually
    dispatched (telemetry), so the acceptance check — chosen == dispatched,
    tiled wall <= monolithic wall — is auditable from the JSON alone.
    """
    imgs = _images(calls, shape)
    budget = MemoryBudget.detect()
    plan = choose_tile(shape[0] * shape[1], calls, budget, pipeline_depth=2)
    out = {
        "shape": list(shape),
        "calls": calls,
        "budget_bytes": budget.bytes_limit,
        "budget_source": budget.source,
        "budget_reserve": budget.reserve,
        "chosen_tile_k": plan.tile_k,
        "modeled_bytes_per_frame": plan.bytes_per_frame,
    }
    regimes = {
        "looped": dict(max_batch=1, mem_budget=MemoryBudget.unlimited()),
        "monolithic": dict(max_batch=calls,
                           mem_budget=MemoryBudget.unlimited()),
        "tiled": dict(max_batch=calls, mem_budget=budget),
    }
    for name, kw in regimes.items():
        ex = OffloadExecutor(BATCHED_4F, **kw)
        ex.warm("fft", imgs[0], batch=kw["max_batch"])
        wall = _timed_flush(ex, imgs)
        ex.telemetry.reset()
        handles = [ex.submit("fft", im) for im in imgs]
        ex.flush()
        st = ex.telemetry.stats[("fft", "optical-sim")]
        out[f"{name}_wall_s_per_call"] = wall
        out[f"{name}_modeled_s_per_call"] = \
            sum(h.cost.total_s for h in handles) / len(handles)
        out[f"{name}_invocations"] = st.invocations
        if name == "tiled":
            tiles = ex.telemetry.tile_sizes_observed("fft")
            out["dispatched_tile_sizes"] = {str(k): v
                                            for k, v in tiles.items()}
            out["measured_bytes_per_frame"] = \
                ex.telemetry.bytes_per_frame("fft")
            # the acceptance link: the budget's pick IS the dispatch depth
            out["tile_matches_dispatch"] = \
                bool(tiles) and max(tiles) == plan.tile_k
    out["tiled_vs_monolithic_speedup"] = \
        out["monolithic_wall_s_per_call"] / max(out["tiled_wall_s_per_call"],
                                                1e-12)
    out["tiled_vs_looped_speedup"] = \
        out["looped_wall_s_per_call"] / max(out["tiled_wall_s_per_call"],
                                            1e-12)
    return out


def trickle_comparison(shape: tuple[int, int] = (64, 64),
                       arrivals: int = TRICKLE_ARRIVALS,
                       rate_hz: float = TRICKLE_RATE_HZ,
                       deadline_s: float = TRICKLE_DEADLINE_S,
                       max_batch: int = TRICKLE_MAX_BATCH,
                       seed: int = TRICKLE_SEED) -> dict:
    """Continuous batching vs drain-on-flush under Poisson trickle arrivals.

    One seeded exponential inter-arrival trace drives both regimes on a
    ``ManualClock`` (deterministic admission — no sleeps, no wall-clock
    races).  ``drain`` flushes on every arrival, the pre-scheduler serving
    pattern: occupancy pins at 1 whenever arrivals are sparser than
    flushes.  ``held`` routes the same trace through an
    ``OffloadScheduler``: groups stay open until full / due / futile, so
    occupancy climbs toward ``rate * deadline`` (capped by ``max_batch``)
    and the per-crossing boundary cost amortizes accordingly.  The queueing
    delay that buys it is reported, not hidden: ``held_hold_s_per_call`` is
    the modeled ``StepCost.hold_s`` share, and the modeled wall per call
    includes it.
    """
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=arrivals)
    imgs = _images(arrivals, shape)

    def _run(held: bool):
        clk = ManualClock()
        ex = OffloadExecutor(BATCHED_4F, max_batch=max_batch, clock=clk)
        ex.warm("fft", imgs[0])
        sched = OffloadScheduler(ex, deadline_s=deadline_s, clock=clk) \
            if held else None
        for gap, im in zip(gaps, imgs):
            clk.advance(float(gap))
            if held:
                sched.submit("fft", im)
            else:
                ex.submit("fft", im)
                ex.flush()          # drain-on-flush: one crossing per arrival
        if held:
            ex.drain()              # releases still-held groups
        st = ex.telemetry.stats[("fft", "optical-sim")]
        per_call = st.modeled.scaled(1.0 / st.calls)
        return {
            "occupancy": st.calls / st.invocations,
            "samples_per_crossing": st.samples_in / st.invocations,
            "invocations": st.invocations,
            "boundary_s_per_call": per_call.conversion_s + per_call.interface_s,
            "modeled_s_per_call": per_call.total_s,
            "hold_s_per_call": per_call.hold_s,
        }

    drain, held = _run(held=False), _run(held=True)
    return {
        # the scheduler config this column was measured under
        "arrival_rate_hz": rate_hz,
        "deadline_s": deadline_s,
        "arrivals": arrivals,
        "max_batch": max_batch,
        "seed": seed,
        "shape": list(shape),
        "drain_occupancy": drain["occupancy"],
        "held_occupancy": held["occupancy"],
        "drain_samples_per_crossing": drain["samples_per_crossing"],
        "held_samples_per_crossing": held["samples_per_crossing"],
        "drain_invocations": drain["invocations"],
        "held_invocations": held["invocations"],
        "drain_boundary_s_per_call": drain["boundary_s_per_call"],
        "held_boundary_s_per_call": held["boundary_s_per_call"],
        "held_hold_s_per_call": held["hold_s_per_call"],
        "drain_modeled_s_per_call": drain["modeled_s_per_call"],
        "held_modeled_s_per_call": held["modeled_s_per_call"],
        "boundary_amortization":
            drain["boundary_s_per_call"] / max(held["boundary_s_per_call"],
                                               1e-12),
    }


def chaos_comparison(rates=CHAOS_RATES, shape=CHAOS_SHAPE,
                     calls: int = CHAOS_CALLS,
                     max_batch: int = CHAOS_MAX_BATCH,
                     seed: int = CHAOS_SEED) -> dict:
    """Goodput and recovery latency under injected boundary faults.

    Each rate row routes the same ``calls`` submissions through a
    chaos-wrapped optical backend injecting a seeded fault mix (transient
    dispatch errors, stragglers, ENOB drift, device loss) at that
    per-dispatch probability, on a ``ManualClock`` so injected straggles
    and retry backoffs advance deterministic time instead of sleeping.
    The equivalence contract is asserted per row: every submitted frame
    retires, and every result lands within the converters' ENOB error
    bound of the looped host baseline (frames the retry policy degraded to
    the host fallback, or the drift-correction path repaired from the
    fidelity shadow, match it bit-for-bit).  ``recovery`` summarizes the
    first-fault-to-correct-result latency histogram from telemetry.
    """
    imgs = _images(calls, shape)
    host = OffloadExecutor(BATCHED_4F, default_backend="host", max_batch=1)
    refs = [np.asarray(h.get()) for h in
            [host.submit("fft", im) for im in imgs]]
    enob = min(BATCHED_4F.dac.effective_bits, BATCHED_4F.adc.effective_bits)
    bound = enob_error_bound(enob, 16.0)
    rows = []
    for rate in rates:
        name = register_chaos("optical-sim", name=f"chaos{int(100 * rate)}",
                              rate=rate, seed=seed)
        clk = ManualClock()
        ex = OffloadExecutor(BATCHED_4F, default_backend=name,
                             max_batch=max_batch, clock=clk,
                             fidelity=FidelityChecker() if rate else None)
        ex.warm("fft", imgs[0], backend="optical-sim")
        wall = _timed_flush(ex, imgs)
        # no telemetry reset: the fault/recovery columns cover the whole
        # seeded run (timed reps + the accounting flush below) — one
        # continuous draw stream on a ManualClock, so still deterministic
        handles = [ex.submit("fft", im) for im in imgs]
        ex.flush()
        rel = [float(np.linalg.norm(np.asarray(h.value) - r)
                     / max(float(np.linalg.norm(r)), 1e-12))
               for h, r in zip(handles, refs)]
        retired = sum(1 for h in handles
                      if h.ready and h.value is not None)
        rows.append({
            "fault_rate": rate,
            "calls": calls,
            "retired": retired,
            "all_retired": retired == calls,
            "max_rel_err": max(rel),
            "enob_bound": bound,
            "within_bound": max(rel) <= bound,
            "wall_s_per_call": wall,
            "goodput_calls_per_s": retired / max(wall * calls, 1e-12),
            "faults": {k: int(v) for k, v in
                       sorted(ex.telemetry.fault_counts.get("fft",
                                                            {}).items())},
            "faults_total": ex.telemetry.faults_total("fft"),
            "recovery": ex.telemetry.recovery_stats("fft"),
            "quarantine_events": len(ex.quarantine.events),
        })
    return {"shape": list(shape), "calls": calls, "max_batch": max_batch,
            "seed": seed, "enob_bound": bound, "rows": rows}


def chaos_overhead(shape: tuple[int, int] = SHAPE, calls: int = CALLS,
                   reps: int = 7) -> dict:
    """What the chaos wrapper costs when it injects nothing: traced
    K-deep flush through a rate-0 chaos-wrapped optical backend vs the
    bare optical backend (< 2% or the chaos CI smoke fails — fault
    *readiness* must be cheap enough to leave on)."""
    imgs = _images(calls, shape)
    plain = OffloadExecutor(BATCHED_4F, max_batch=calls, tracer=Tracer())
    plain.warm("fft", imgs[0])
    base = _timed_flush(plain, imgs, reps=reps)
    name = register_chaos("optical-sim", name="chaos-idle", rate=0.0)
    chaos = OffloadExecutor(BATCHED_4F, default_backend=name,
                            max_batch=calls, tracer=Tracer())
    chaos.warm("fft", imgs[0], backend="optical-sim")
    wall = _timed_flush(chaos, imgs, reps=reps)
    return {"plain_wall_s_per_call": base, "chaos_wall_s_per_call": wall,
            "overhead": wall / max(base, 1e-12) - 1.0}


def residency_comparison(shape: tuple[int, int] = SHAPE, calls: int = CALLS,
                         reps: int = 5) -> dict:
    """Operand residency: a conv layer stack re-using its frames and kernel.

    Four executors flush the same K-deep conv group repeatedly:

      hit      residency on, SAME frames every rep — after the priming
               flush every operand is resident, so each rep skips the
               content hashing AND the host staging stack (the measured
               win) and the model prices the flush read-side-only
               (``dac_s == 0``, the modeled win).
      delta    residency on, a CORRELATED workload — every rep drifts a
               quarter of the frames by ~1% (a drifting sensor: ~15% of
               code bits flip at 8 DAC bits, under ``DELTA_THRESHOLD``)
               and keeps the rest as the same long-lived arrays.  Each
               flush misses at group grain, but the unchanged frames are
               slot-resident (id-memoized digests, no re-hash) and the
               drifted ones take the delta-encoded partial write — the
               measured wall and the modeled ``dac_s`` both land strictly
               between the hit and restage rows.
      restage  residency on, DISTINCT frames every rep — every flush
               misses, paying digest + staging on top of the same compute
               (the honest baseline for the hit path: same code path,
               cache always cold).
      plain    residency off — the historical staging path, unchanged.

    The CI smoke asserts hit < delta < restage on the measured wall,
    that the modeled delta DAC time sits strictly between zero and the
    restage price, and that both cached paths retire bit-equal to plain;
    the row lands in ``BENCH_history.jsonl`` so the PR 6 drift gate
    covers the cached paths' trajectories too.
    """
    def _conv_kernel():
        h, w = shape
        return (jax.numpy.zeros(shape)
                .at[0, 0].set(0.5).at[1, 2].set(0.25)
                .at[h - 1, 1].set(0.15))

    def _timed(ex, groups, kernel):
        best = float("inf")
        for imgs in groups:
            hs = [ex.submit("conv", im, kernel=kernel) for im in imgs]
            t0 = time.perf_counter()
            ex.flush()
            best = min(best, (time.perf_counter() - t0) / len(hs))
        return best, hs

    kernel = _conv_kernel()
    imgs = _images(calls, shape)
    fresh = [[jax.random.uniform(
        jax.random.fold_in(jax.random.PRNGKey(100 + r), i), shape)
        for i in range(calls)] for r in range(reps)]

    plain = OffloadExecutor(BATCHED_4F, max_batch=calls)
    plain.warm("conv", imgs[0], kernel=kernel)
    plain_wall, plain_hs = _timed(plain, [imgs] * reps, kernel)

    hot = OffloadExecutor(BATCHED_4F, max_batch=calls, residency=True)
    hot.warm("conv", imgs[0], kernel=kernel)
    for im in imgs:                       # priming flush: populate the cache
        hot.submit("conv", im, kernel=kernel)
    hot.flush()
    hit_wall, hot_hs = _timed(hot, [imgs] * reps, kernel)
    hit_cost = hot_hs[0].cost

    cold = OffloadExecutor(BATCHED_4F, max_batch=calls, residency=True)
    cold.warm("conv", imgs[0], kernel=kernel)
    restage_wall, cold_hs = _timed(cold, fresh, kernel)
    restage_cost = cold_hs[0].cost

    # the correlated workload: every rep drifts frames 0, 4, 8, ... by a
    # fresh ~1% perturbation of the SAME base frame, so rep-to-rep flips
    # stay small, and keeps the other frames as the same array objects
    stride = 4
    drifted = []
    for r in range(reps):
        grp = list(imgs)
        for i in range(0, calls, stride):
            key = jax.random.fold_in(jax.random.PRNGKey(500 + r), i)
            grp[i] = imgs[i] + 0.01 * jax.random.uniform(key, shape)
        drifted.append(grp)
    part = OffloadExecutor(BATCHED_4F, max_batch=calls, residency=True)
    part.warm("conv", imgs[0], kernel=kernel)
    for im in imgs:                       # priming flush: seed the slots
        part.submit("conv", im, kernel=kernel)
    part.flush()
    delta_wall, part_hs = _timed(part, drifted, kernel)
    delta_cost = part_hs[0].cost
    # the delta path's equivalence reference: plain re-stage of the LAST
    # drifted group (_timed leaves part_hs on that group)
    _, ref_hs = _timed(plain, [drifted[-1]], kernel)

    bit_equal = all(
        np.array_equal(np.asarray(h.value), np.asarray(p.value))
        for h, p in zip(hot_hs, plain_hs))
    delta_bit_equal = all(
        np.array_equal(np.asarray(h.value), np.asarray(p.value))
        for h, p in zip(part_hs, ref_hs))
    return {
        "calls": calls,
        "shape": list(shape),
        "hit_wall_s_per_call": hit_wall,
        "delta_wall_s_per_call": delta_wall,
        "restage_wall_s_per_call": restage_wall,
        "plain_wall_s_per_call": plain_wall,
        "hit_speedup_vs_restage": restage_wall / max(hit_wall, 1e-12),
        "delta_speedup_vs_restage": restage_wall / max(delta_wall, 1e-12),
        "modeled_hit_dac_s": hit_cost.dac_s,
        "modeled_delta_dac_s": delta_cost.dac_s,
        "modeled_restage_dac_s": restage_cost.dac_s,
        "hit_rate": hot.telemetry.residency_hit_rate("conv"),
        "delta_rate": part.telemetry.delta_rate("conv"),
        "delta_flip_fraction": part.telemetry.mean_flip_fraction("conv"),
        "delta_frames_per_flush": calls // stride,
        "resident_bytes": hot.residency.resident_bytes(),
        "bit_equal_to_plain": bit_equal,
        "delta_bit_equal_to_plain": delta_bit_equal,
    }


def roundtrip() -> dict:
    """Profile on host -> plan from telemetry -> execute -> compare."""
    imgs = _images()
    ex = OffloadExecutor(BATCHED_4F, max_batch=16)
    router = PlanRouter(ex)
    # prime the jit caches (single-item and batched stack shapes) so
    # one-time compilation does not masquerade as measured per-call host
    # time in the profiles
    ex.warm("fft", imgs[0], backend="host")
    # submit in groups: replan() prices amortization at the *observed*
    # queue occupancy, so serial submission would (correctly) earn none
    ex.telemetry.start()
    for h in [router.submit("fft", im) for im in imgs]:
        h.get()
    ex.telemetry.stop()
    plan = router.replan()
    for h in [router.submit("fft", im) for im in imgs]:
        h.get()
    planned_offload = {d.category: d.offload for d in plan.decisions
                       if d.category != "other"}
    executed_on = {
        cat: [b for (c, b) in ex.telemetry.stats if c == cat]
        for cat in planned_offload
    }
    matches = all(
        ("optical-sim" in executed_on[cat]) == off
        for cat, off in planned_offload.items())
    return {
        "plan_speedup": plan.end_to_end_speedup,
        "planned_offload": planned_offload,
        "executed_on": executed_on,
        "adaptive_max_batch": dict(ex.category_max_batches()),
        "decisions_match_execution": matches,
    }


def bench_payload() -> dict:
    """Machine-readable benchmark record (written to ``BENCH_runtime.json``)
    so the perf trajectory is tracked across PRs.  ``trickle_comparison``
    carries its scheduler config (deadline, arrival rate, seed) alongside
    the measured occupancies, so the column stays interpretable when the
    scenario constants move."""
    rt = roundtrip()
    rt = {k: v for k, v in rt.items() if k != "executed_on"}
    return {
        "bench": "runtime",
        "shape": list(SHAPE),
        "calls": CALLS,
        "sweep": sweep(),
        "pipeline": pipeline_comparison(),
        "sharded": sharded_comparison(),
        "trickle_comparison": trickle_comparison(),
        "large_frame": large_frame_comparison(),
        "traced": traced_comparison(),
        "chaos": chaos_comparison(),
        "chaos_overhead": chaos_overhead(),
        "residency": residency_comparison(),
        "roundtrip": rt,
    }


def write_json(path: str = BENCH_JSON) -> dict:
    payload = bench_payload()
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    # BENCH_runtime.json is a snapshot; the JSONL keeps the trajectory the
    # drift gate's history band reads (both main() and benchmarks/run.py
    # land here, so each bench run is recorded exactly once).
    append_history(payload)
    return payload


def run(payload: dict | None = None) -> list[str]:
    """CSV rows per the harness contract: section,name,us_per_call,derived."""
    if payload is None:
        payload = bench_payload()
    rows = []
    base = None
    for r in payload["sweep"]:
        if base is None:
            base = r["boundary_s_per_call"]
        rows.append(
            f"runtime,batch{r['max_batch']},"
            f"{1e6 * r['wall_s_per_call']:.1f},"
            f"looped_speedup={r['looped_speedup']:.2f}x"
            f"|boundary={1e6 * r['boundary_s_per_call']:.1f}us"
            f"|amortization={base / max(r['boundary_s_per_call'], 1e-12):.2f}x"
            f"|modeled_total={1e6 * r['modeled_s_per_call']:.1f}us"
            f"|invocations={r['invocations']}")
    p = payload["pipeline"]
    rows.append(
        f"runtime,pipeline,{1e6 * p['pipelined_wall_s_per_call']:.1f},"
        f"speedup_vs_serial={p['pipeline_speedup']:.2f}x"
        f"|serial={1e6 * p['serial_wall_s_per_call']:.1f}us")
    for r in payload["sharded"]:
        rows.append(
            f"runtime,sharded{r['n_devices']},"
            f"{1e6 * r['wall_s_per_call']:.1f},"
            f"modeled_speedup={r['modeled_speedup']:.3f}x"
            f"|wall_speedup={r['wall_speedup']:.2f}x"
            f"|resident_wall_speedup={r['resident_wall_speedup']:.2f}x"
            f"|resident={1e6 * r['resident_wall_s_per_call']:.1f}us"
            f"|scatter_stage={1e6 * r['scatter_stage_s']:.1f}us"
            f"->{1e6 * r['resident_scatter_stage_s']:.1f}us"
            f"|per_engine={1e6 * r['per_engine_wall_s_per_call']:.1f}us"
            f"vs{1e6 * r['shared_window_wall_s_per_call']:.1f}us"
            f"shared({r['per_engine_speedup']:.2f}x)"
            f"|boundary={1e6 * r['boundary_s_per_call']:.1f}us"
            f"|devices_used={r['devices_used']}"
            f"/{r['devices_present']}present")
    t = payload["trickle_comparison"]
    rows.append(
        f"runtime,trickle,{1e6 * t['held_boundary_s_per_call']:.1f},"
        f"held_occupancy={t['held_occupancy']:.2f}"
        f"|drain_occupancy={t['drain_occupancy']:.2f}"
        f"|samples_per_crossing={t['held_samples_per_crossing']:.0f}"
        f"vs{t['drain_samples_per_crossing']:.0f}"
        f"|amortization={t['boundary_amortization']:.2f}x"
        f"|hold={1e6 * t['held_hold_s_per_call']:.1f}us"
        f"|rate={t['arrival_rate_hz']:.0f}/s"
        f"|deadline={1e3 * t['deadline_s']:.0f}ms")
    lf = payload["large_frame"]
    rows.append(
        f"runtime,large_frame,{1e6 * lf['tiled_wall_s_per_call']:.1f},"
        f"tiled_vs_monolithic={lf['tiled_vs_monolithic_speedup']:.2f}x"
        f"|tiled_vs_looped={lf['tiled_vs_looped_speedup']:.2f}x"
        f"|monolithic={1e6 * lf['monolithic_wall_s_per_call']:.1f}us"
        f"|looped={1e6 * lf['looped_wall_s_per_call']:.1f}us"
        f"|tile_k={lf['chosen_tile_k']}"
        f"|match={lf['tile_matches_dispatch']}"
        f"|budget={lf['budget_bytes'] // (1024 * 1024)}MiB"
        f"({lf['budget_source']})")
    tc = payload["traced"]
    stage_drift = tc["drift"]["stages"].get("stage", {}).get("drift")
    stage_txt = (f"{stage_drift:.3f}"
                 if isinstance(stage_drift, (int, float)) else "n/a")
    rows.append(
        f"runtime,traced,{1e6 * tc['traced_wall_s_per_call']:.1f},"
        f"tracer_overhead={100 * tc['tracer_overhead']:.1f}%"
        f"|untraced={1e6 * tc['untraced_wall_s_per_call']:.1f}us"
        f"|coverage={tc['reconcile']['coverage']:.2f}"
        f"|stage_drift={stage_txt}"
        f"|spans={tc['spans']}")
    for r in payload["chaos"]["rows"]:
        rec = r["recovery"] or {}
        rec_txt = (f"{1e3 * rec['p95_s']:.1f}ms" if rec else "n/a")
        faults = ";".join(f"{k}x{v}" for k, v in r["faults"].items()) or "none"
        rows.append(
            f"runtime,chaos{int(100 * r['fault_rate'])},"
            f"{1e6 * r['wall_s_per_call']:.1f},"
            f"retired={r['retired']}/{r['calls']}"
            f"|goodput={r['goodput_calls_per_s']:.0f}/s"
            f"|max_rel_err={r['max_rel_err']:.2e}"
            f"|within_bound={r['within_bound']}"
            f"|faults={faults}"
            f"|recovery_p95={rec_txt}"
            f"|quarantines={r['quarantine_events']}")
    co = payload["chaos_overhead"]
    rows.append(
        f"runtime,chaos_overhead,{1e6 * co['chaos_wall_s_per_call']:.1f},"
        f"overhead={100 * co['overhead']:.1f}%"
        f"|plain={1e6 * co['plain_wall_s_per_call']:.1f}us")
    res = payload["residency"]
    rows.append(
        f"runtime,residency,{1e6 * res['hit_wall_s_per_call']:.1f},"
        f"hit_vs_restage={res['hit_speedup_vs_restage']:.2f}x"
        f"|delta={1e6 * res['delta_wall_s_per_call']:.1f}us"
        f"|restage={1e6 * res['restage_wall_s_per_call']:.1f}us"
        f"|plain={1e6 * res['plain_wall_s_per_call']:.1f}us"
        f"|hit_dac_s={res['modeled_hit_dac_s']:.2e}"
        f"|delta_dac_s={res['modeled_delta_dac_s']:.2e}"
        f"|hit_rate={res['hit_rate']:.2f}"
        f"|mean_flip={res['delta_flip_fraction']:.2f}"
        f"|bit_equal={res['bit_equal_to_plain']}"
        f"|delta_bit_equal={res['delta_bit_equal_to_plain']}")
    rt = payload["roundtrip"]
    rows.append(
        f"runtime,roundtrip,,speedup={rt['plan_speedup']:.2f}x"
        f"|offload={rt['planned_offload']}"
        f"|adaptive_max_batch={rt['adaptive_max_batch']}"
        f"|match={rt['decisions_match_execution']}")
    return rows


def main() -> None:
    history = load_history()  # read before write_json appends this run
    payload = write_json()
    print("section,name,us_per_call,derived")
    for row in run(payload):
        print(row)
    ok, msg = drift_gate(payload["traced"]["drift"], history)
    print(f"drift_gate,{'ok' if ok else 'FAIL'},,{msg}")


if __name__ == "__main__":
    main()
