"""Runtime benchmark: batching amortizes the conversion boundary.

Two claims, measured on the executing runtime (not just the cost model):

* **Amortization sweep** — submitting K same-shape FFT offload calls and
  letting the executor coalesce them reduces the modeled per-call
  conversion + interface time monotonically in K (the paper's §6 lever:
  one link handshake, one SLM settle, one lane-ceil residue per batch
  instead of per call).
* **Telemetry round trip** — traffic profiled by the runtime itself feeds
  ``plan_offload`` and yields a plan whose offload decisions match how the
  router then executes (categories the plan offloads run on the analog
  backend, the rest stay host).

Run:  PYTHONPATH=src python -m benchmarks.runtime_bench
"""

from __future__ import annotations

import time

import jax

from repro.runtime import BATCHED_4F, OffloadExecutor, PlanRouter

# 512x512 frames: large enough that the host FFT genuinely costs ms while
# 16 of them still pack into one 2048x2048 SLM frame (one frame-sync).
SHAPE = (512, 512)
CALLS = 16


def _images(n: int = CALLS):
    key = jax.random.PRNGKey(7)
    return [jax.random.uniform(jax.random.fold_in(key, i), SHAPE)
            for i in range(n)]


def sweep(batch_sizes=(1, 2, 4, 8, 16)) -> list[dict]:
    """Per-call boundary cost vs executor batch ceiling, CALLS fft calls."""
    imgs = _images()
    rows = []
    for k in batch_sizes:
        ex = OffloadExecutor(BATCHED_4F, max_batch=k)
        handles = [ex.submit("fft", im) for im in imgs]
        t0 = time.perf_counter()
        ex.flush()
        wall = time.perf_counter() - t0
        # per-call share of the modeled batched invocation cost, averaged
        # over the calls (the tail batch may be smaller than k)
        per_call = [h.cost.conversion_s + h.cost.interface_s for h in handles]
        total = [h.cost.total_s for h in handles]
        rows.append({
            "max_batch": k,
            "boundary_s_per_call": sum(per_call) / len(per_call),
            "modeled_s_per_call": sum(total) / len(total),
            "wall_s_per_call": wall / len(handles),
            "invocations": ex.telemetry.stats[("fft", "optical-sim")].invocations,
        })
    return rows


def roundtrip() -> dict:
    """Profile on host -> plan from telemetry -> execute -> compare."""
    imgs = _images()
    ex = OffloadExecutor(BATCHED_4F, max_batch=16)
    router = PlanRouter(ex)
    # prime the jit caches so one-time compilation does not masquerade as
    # measured per-call host time in the profiles
    ex.warm("fft", imgs[0], backend="host")
    # submit in groups: replan() prices amortization at the *observed*
    # queue occupancy, so serial submission would (correctly) earn none
    ex.telemetry.start()
    for h in [router.submit("fft", im) for im in imgs]:
        h.get()
    ex.telemetry.stop()
    plan = router.replan()
    for h in [router.submit("fft", im) for im in imgs]:
        h.get()
    planned_offload = {d.category: d.offload for d in plan.decisions
                       if d.category != "other"}
    executed_on = {
        cat: [b for (c, b) in ex.telemetry.stats if c == cat]
        for cat in planned_offload
    }
    matches = all(
        ("optical-sim" in executed_on[cat]) == off
        for cat, off in planned_offload.items())
    return {
        "plan_speedup": plan.end_to_end_speedup,
        "planned_offload": planned_offload,
        "executed_on": executed_on,
        "decisions_match_execution": matches,
    }


def run() -> list[str]:
    """CSV rows per the harness contract: section,name,us_per_call,derived."""
    rows = []
    base = None
    for r in sweep():
        if base is None:
            base = r["boundary_s_per_call"]
        rows.append(
            f"runtime,batch{r['max_batch']},"
            f"{1e6 * r['boundary_s_per_call']:.1f},"
            f"conv+intf_amortization={base / max(r['boundary_s_per_call'], 1e-12):.2f}x"
            f"|modeled_total={1e6 * r['modeled_s_per_call']:.1f}us"
            f"|invocations={r['invocations']}")
    rt = roundtrip()
    rows.append(
        f"runtime,roundtrip,,speedup={rt['plan_speedup']:.2f}x"
        f"|offload={rt['planned_offload']}"
        f"|match={rt['decisions_match_execution']}")
    return rows


def main() -> None:
    print("section,name,us_per_call,derived")
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
