"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
  * Table 1 / Fig 9 rows: us_per_call = benchmark total time, derived =
    ideal end-to-end Amdahl speedup (paper value appended for comparison);
  * Fig 8: hardware-vs-software ratio; Fig 2: frontier gaps; Fig 3:
    complexity crossovers; planner: per-arch bounded speedups;
  * roofline rows when dry-run artifacts exist.
"""

from __future__ import annotations

import sys


def main() -> None:
    print("section,name,us_per_call,derived")

    # --- Table 1 / Figure 9: the 27-benchmark Amdahl suite ------------------
    from benchmarks.amdahl_suite import PAPER_TABLE1, run_suite
    rows = run_suite()
    speedups = []
    for r in rows:
        paper_pct, paper_s = PAPER_TABLE1[r.name]
        speedups.append(r.end_to_end_speedup)
        print(f"table1,{r.name},{1e6 * r.total_time_s:.1f},"
              f"speedup={r.end_to_end_speedup:.2f}x|frac={100*r.fraction:.2f}%"
              f"|paper={paper_s:.2f}x|paper_frac={paper_pct:.2f}%")
    ss = sorted(speedups)
    median = ss[len(ss) // 2]
    mean = sum(ss) / len(ss)
    print(f"table1,MEDIAN,,{median:.2f}x (paper 1.94x)")
    print(f"table1,MEAN,,{mean:.2f}x (paper 9.39x)")

    # --- Figure 8: prototype data-movement split ------------------------------
    from benchmarks.conversion_bottleneck import run as fig8
    r8 = fig8()
    print(f"fig8,software_fft,{1e6 * r8['software_fft_s']:.1f},measured")
    print(f"fig8,hardware_total,{1e6 * r8['hardware_total_s']:.1f},"
          f"movement={r8['hardware_movement_pct']:.3f}% (paper "
          f"{r8['paper_movement_pct']}%)")
    print(f"fig8,slowdown,,{r8['hardware_vs_software']:.1f}x slower than "
          f"software (paper {r8['paper_hardware_vs_software']:.1f}x on rpi4)")
    print(f"fig8,sim_intensity_rel_err,,{r8['sim_intensity_rel_err']:.2e}")

    # --- Figure 2: converter Pareto frontier ------------------------------------
    from benchmarks.pareto import run as fig2
    r2 = fig2()
    for k in ("kim_dac_gap", "liu_adc_gap", "anderson_dac_gap",
              "anderson_adc_gap"):
        print(f"fig2,{k},,{r2[k]:.2f}x")

    # --- Figure 3: complexity crossover -------------------------------------------
    from benchmarks.complexity_fig import run as fig3
    r3 = fig3()
    for name, n in r3["crossover_1x"].items():
        n10 = r3["crossover_10x"][name]
        print(f"fig3,{name.replace(' ', '_')},,"
              f"crossover_1x=N{n}|crossover_10x=N{n10}")

    # --- Planner: the 10 assigned archs under the decision rule --------------------
    from benchmarks.planner_table import run as planner
    for row in planner():
        mm = row["flops_pct"].get("matmul", 0.0)
        print(f"planner,{row['arch']},,mvm={row['mvm_speedup']:.2f}x"
              f"|fourier={row['fourier_speedup']:.2f}x"
              f"|matmul_flops={mm:.1f}%"
              f"|worthwhile={row['mvm_worthwhile']}"
              f"|conversion_bound={row['mvm_conversion_bound']}")

    # --- Offload runtime: batching amortization + telemetry round trip ---------------
    # Also writes BENCH_runtime.json (per-batch-size wall/boundary seconds
    # per call + batched-vs-looped speedup, and the trickle-arrival
    # continuous-batching column with its scheduler config — deadline,
    # arrival rate, seed — stamped alongside the measured occupancies) so
    # the perf trajectory is machine-readable AND interpretable across PRs.
    # write_json also appends the record to BENCH_history.jsonl — the
    # trajectory the traced column's drift gate bands against.
    from benchmarks.runtime_bench import (drift_gate, load_history,
                                          run as runtime_bench, write_json)
    history = load_history()  # read before write_json appends this run
    payload = write_json()
    for row in runtime_bench(payload):
        print(row)
    ok, msg = drift_gate(payload["traced"]["drift"], history)
    print(f"drift_gate,{'ok' if ok else 'FAIL'},,{msg}")

    # --- Roofline (needs dry-run artifacts) -------------------------------------------
    import os
    try:
        from benchmarks.roofline import ART_DIR, run as roofline
        for tag, d in (("roofline", ART_DIR),
                       ("roofline_opt", os.path.join(ART_DIR, "..",
                                                     "dryrun_opt"))):
            if not os.path.isdir(d):
                continue
            for r in roofline(d):
                print(f"{tag},{r['cell']},"
                      f"{1e6 * r['step_lower_bound_s']:.1f},"
                      f"dominant={r['dominant']}|useful={r['useful_ratio']:.3f}"
                      f"|roof={100*r['roofline_fraction']:.1f}%")
    except Exception as e:  # artifacts absent: non-fatal
        print(f"roofline,error,,{type(e).__name__}")


if __name__ == "__main__":
    main()
