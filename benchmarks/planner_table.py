"""The paper's decision rule applied to the 10 assigned LM architectures.

For each arch (smoke-scale trace, FLOP mix is depth/width-invariant per
category because every term scales with the same token count) we:

  1. trace one train step and bucket FLOPs {matmul, conv, fft, other}
     (repro.core.profiler.flops_by_category — scan-aware);
  2. convert category FLOPs to host-seconds at the TPU v5e peak
     (197 bf16 TFLOP/s) — the *most generous* host model: any real host
     inefficiency only helps the accelerator;
  3. price offload of the matmul category on the optical MVM accelerator
     (Anderson-class, honest on-frontier converters) and of conv/fft on
     the ideal 4f accelerator, including DAC/ADC + interface costs;
  4. report the Amdahl-bounded end-to-end speedup and the verdict vs the
     10x build-threshold (§5).

This is the paper's §4-§6 generalized: for matmul-dominated transformers
the conversion boundary (activations in, activations out every pass) caps
the win regardless of how fast the optical MAC itself is.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.core.accelerator import ANDERSON_MVM, IDEAL_4F
from repro.core.planner import CategoryProfile, plan_offload
from repro.core.profiler import flops_by_category
from repro.models import LM, param_shape_structs

__all__ = ["run"]

TPU_PEAK = 197e12  # bf16 FLOP/s


def _arch_profile(arch: str) -> tuple[dict, int]:
    cfg = cfgs.get_smoke_config(arch)
    model = LM(cfg)
    b, s = 2, 32
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct((b, s // 2, cfg.d_model),
                                               cfg.activation_dtype)
    if cfg.frontend == "vision":
        bt = dict(batch)
        bt["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        bt["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        bt["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_tokens,
                                              cfg.d_model),
                                             cfg.activation_dtype)
        batch = bt
    p_sds = param_shape_structs(cfg)
    cats = flops_by_category(lambda p, bb: model.loss(p, bb)[0], p_sds, batch)
    tokens = b * s
    return cats, tokens


def run() -> list[dict]:
    rows = []
    for arch in cfgs.ARCHS:
        cats, tokens = _arch_profile(arch)
        flops = {k: v for k, v in cats.items() if not k.startswith("__")}
        total = sum(flops.values())
        profiles = []
        for cat in ("matmul", "conv", "fft", "other"):
            fl = flops.get(cat, 0.0)
            if fl <= 0:
                continue
            host_s = fl / TPU_PEAK
            # boundary samples: ~3 activations per matmul pass (in, weightless
            # out, partial) — approximated as 2*sqrt-flops per call heuristic
            # replaced by explicit accounting: activations = flops / (2 * K)
            # with K~d_model; use d_model of the arch.
            d = cfgs.get_smoke_config(arch).d_model
            samples = int(fl / max(2 * d, 1))          # tokens x features out
            profiles.append(CategoryProfile(
                name=cat, host_s=host_s,
                calls=max(tokens, 1),
                samples_in=2 * samples, samples_out=samples))
        plan_mvm = plan_offload(profiles, ANDERSON_MVM)
        plan_4f = plan_offload(profiles, IDEAL_4F)
        rows.append({
            "arch": arch,
            "flops_pct": {k: 100 * v / total for k, v in sorted(flops.items())},
            "mvm_speedup": plan_mvm.end_to_end_speedup,
            "mvm_worthwhile": plan_mvm.worthwhile,
            "mvm_conversion_bound": plan_mvm.conversion_bound,
            "fourier_speedup": plan_4f.end_to_end_speedup,
            "fourier_worthwhile": plan_4f.worthwhile,
        })
    return rows
