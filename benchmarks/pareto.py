"""Figure 2 / §2 reproduction: the DAC/ADC Pareto frontier and the
Anderson-et-al. feasibility check.

Sweeps the survey-envelope model across sampling rates, places the paper's
two reference converters (Kim DAC, Liu ADC) against it, and computes how
far below the frontier the 32x-lower-energy converters assumed by the
optical-transformer energy claims would need to sit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.conversion import (
    KIM_2019_DAC,
    LIU_2022_ADC,
    ConverterSpec,
    frontier_gap,
    pareto_fom_fj,
    pareto_power_w,
)

__all__ = ["run"]


def run() -> dict:
    rates = np.logspace(6, 11, 26)
    envelope = {
        "adc_fj": [pareto_fom_fj(r, "adc") for r in rates],
        "dac_fj": [pareto_fom_fj(r, "dac") for r in rates],
        "rates_hz": list(rates),
    }
    hyp_adc = dataclasses.replace(LIU_2022_ADC, name="anderson-adc",
                                  power_w=LIU_2022_ADC.power_w / 32)
    hyp_dac = dataclasses.replace(KIM_2019_DAC, name="anderson-dac",
                                  power_w=KIM_2019_DAC.power_w / 32)
    # power an on-frontier design would need at the paper's reference points
    return {
        "kim_dac_gap": frontier_gap(KIM_2019_DAC),      # ~1: on frontier
        "liu_adc_gap": frontier_gap(LIU_2022_ADC),      # ~1: on frontier
        "anderson_dac_gap": frontier_gap(hyp_dac),       # ~32: below frontier
        "anderson_adc_gap": frontier_gap(hyp_adc),
        "kim_energy_per_sample_pj": KIM_2019_DAC.energy_per_sample_j * 1e12,
        "liu_energy_per_sample_pj": LIU_2022_ADC.energy_per_sample_j * 1e12,
        "frontier_power_at_liu_point_w": pareto_power_w(
            LIU_2022_ADC.rate_hz, LIU_2022_ADC.effective_bits, "adc"),
        "envelope": envelope,
    }
