"""Figure 3 reproduction: computational vs conversion complexity C = 2N.

Tabulates the compute/conversion advantage for each problem class across
problem sizes and the crossover size where offload first pays (threshold
1x and the paper's 10x build-bar).
"""

from __future__ import annotations

from repro.core.complexity import PROBLEM_CLASSES, advantage, crossover_n

__all__ = ["run"]


def run() -> dict:
    sizes = [2 ** k for k in range(2, 21, 3)]
    table = {name: [advantage(name, n) for n in sizes]
             for name in PROBLEM_CLASSES}
    return {
        "sizes": sizes,
        "advantage": table,
        "crossover_1x": {n: crossover_n(n, 1.0) for n in PROBLEM_CLASSES},
        "crossover_10x": {n: crossover_n(n, 10.0) for n in PROBLEM_CLASSES},
    }
