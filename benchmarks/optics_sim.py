"""Minimal Fourier-optics library in JAX (LightPipes/Prysm stand-in).

Every FFT-based propagation runs through the ``OpProfiler`` under the
"fft" category, exactly mirroring the paper's methodology of attributing
FFT/conv-named library functions to the accelerator (App. C.1).  All other
array math lands in the profiled 'other' residual.

Fields are complex (N, N) grids with physical extent ``size_m``.
Propagation uses the band-limited angular-spectrum method (two FFTs per
step, like LightPipes' Forvard).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.profiler import OpProfiler

__all__ = ["Field", "begin", "forvard", "lens", "circ_aperture", "circ_screen",
           "rect_slits", "gauss", "axicon", "spiral_phase_plate", "zone_plate",
           "tilt", "intensity", "lenslet_array", "hermite_gauss", "far_field"]


@dataclasses.dataclass
class Field:
    u: jnp.ndarray          # complex amplitude (N, N)
    size_m: float           # physical side length
    wavelength: float

    @property
    def n(self) -> int:
        return self.u.shape[0]

    def grid(self):
        n = self.n
        x = (jnp.arange(n) - n / 2) * (self.size_m / n)
        return jnp.meshgrid(x, x, indexing="xy")


def begin(size_m: float, wavelength: float, n: int) -> Field:
    return Field(jnp.ones((n, n), jnp.complex64), size_m, wavelength)


def intensity(f: Field) -> jnp.ndarray:
    return jnp.abs(f.u) ** 2


# --- elements (pure phase/amplitude masks: 'other' time) -----------------------


def circ_aperture(f: Field, radius: float, x0=0.0, y0=0.0) -> Field:
    x, y = f.grid()
    mask = ((x - x0) ** 2 + (y - y0) ** 2) <= radius ** 2
    return Field(f.u * mask, f.size_m, f.wavelength)


def circ_screen(f: Field, radius: float) -> Field:
    x, y = f.grid()
    mask = (x ** 2 + y ** 2) > radius ** 2
    return Field(f.u * mask, f.size_m, f.wavelength)


def rect_slits(f: Field, width: float, height: float,
               centers: list[tuple[float, float]]) -> Field:
    x, y = f.grid()
    mask = jnp.zeros(f.u.shape, bool)
    for (cx, cy) in centers:
        mask |= (jnp.abs(x - cx) <= width / 2) & (jnp.abs(y - cy) <= height / 2)
    return Field(f.u * mask, f.size_m, f.wavelength)


def gauss(f: Field, w0: float) -> Field:
    x, y = f.grid()
    return Field(f.u * jnp.exp(-(x ** 2 + y ** 2) / w0 ** 2), f.size_m,
                 f.wavelength)


def lens(f: Field, focal_m: float) -> Field:
    x, y = f.grid()
    k = 2 * jnp.pi / f.wavelength
    phase = -k * (x ** 2 + y ** 2) / (2 * focal_m)
    return Field(f.u * jnp.exp(1j * phase), f.size_m, f.wavelength)


def axicon(f: Field, cone_rad: float) -> Field:
    x, y = f.grid()
    k = 2 * jnp.pi / f.wavelength
    r = jnp.sqrt(x ** 2 + y ** 2)
    return Field(f.u * jnp.exp(-1j * k * r * cone_rad), f.size_m, f.wavelength)


def spiral_phase_plate(f: Field, charge: int = 1) -> Field:
    x, y = f.grid()
    return Field(f.u * jnp.exp(1j * charge * jnp.arctan2(y, x)), f.size_m,
                 f.wavelength)


def zone_plate(f: Field, focal_m: float) -> Field:
    x, y = f.grid()
    r2 = x ** 2 + y ** 2
    zones = jnp.floor(r2 / (f.wavelength * focal_m)).astype(jnp.int32)
    return Field(f.u * (zones % 2 == 0), f.size_m, f.wavelength)


def tilt(f: Field, tx: float, ty: float) -> Field:
    x, y = f.grid()
    k = 2 * jnp.pi / f.wavelength
    return Field(f.u * jnp.exp(1j * k * (x * tx + y * ty)), f.size_m,
                 f.wavelength)


def lenslet_array(f: Field, pitch: float, focal_m: float) -> Field:
    x, y = f.grid()
    xl = jnp.mod(x + pitch / 2, pitch) - pitch / 2
    yl = jnp.mod(y + pitch / 2, pitch) - pitch / 2
    k = 2 * jnp.pi / f.wavelength
    return Field(f.u * jnp.exp(-1j * k * (xl ** 2 + yl ** 2) / (2 * focal_m)),
                 f.size_m, f.wavelength)


def hermite_gauss(f: Field, m: int, n: int, w0: float) -> Field:
    x, y = f.grid()
    hx = np.polynomial.hermite.hermval(
        np.asarray(np.sqrt(2) * x / w0), [0] * m + [1])
    hy = np.polynomial.hermite.hermval(
        np.asarray(np.sqrt(2) * y / w0), [0] * n + [1])
    env = jnp.exp(-(x ** 2 + y ** 2) / w0 ** 2)
    return Field(f.u * jnp.asarray(hx * hy) * env, f.size_m, f.wavelength)


# --- propagation (the FFT hot path) ----------------------------------------------


def _propagate(u: jnp.ndarray, size_m: float, wavelength: float,
               z_m: float) -> jnp.ndarray:
    n = u.shape[0]
    fx = jnp.fft.fftfreq(n, d=size_m / n)
    fxx, fyy = jnp.meshgrid(fx, fx, indexing="xy")
    arg = 1.0 - (wavelength * fxx) ** 2 - (wavelength * fyy) ** 2
    kz = 2 * jnp.pi / wavelength * jnp.sqrt(jnp.maximum(arg, 0.0))
    h = jnp.exp(1j * kz * z_m) * (arg > 0)
    return jnp.fft.ifft2(jnp.fft.fft2(u) * h)


def forvard(f: Field, z_m: float, prof: OpProfiler | None = None) -> Field:
    """Angular-spectrum propagation over distance z (2 FFTs)."""
    if prof is not None:
        u = prof.run("fft", _propagate, f.u, f.size_m, f.wavelength, z_m)
    else:
        u = _propagate(f.u, f.size_m, f.wavelength, z_m)
    return Field(u, f.size_m, f.wavelength)


def far_field(f: Field, prof: OpProfiler | None = None) -> jnp.ndarray:
    """Fraunhofer far field (1 FFT), shifted to center."""
    fn = lambda u: jnp.fft.fftshift(jnp.fft.fft2(u, norm="ortho"))
    if prof is not None:
        return prof.run("fft", fn, f.u)
    return fn(f.u)
