"""The paper's 27-benchmark Amdahl case study (Table 1 / Figure 9), in JAX.

Methodology mirrors App. C.1: every benchmark runs with FFT/conv library
calls bracketed under the profiler's accelerable categories; the ideal
(zero-cost) optical accelerator's end-to-end speedup is the Amdahl bound
1 / (1 - f_accel).  Each benchmark is warmed up once (compile caches) and
timed over REPEATS runs.

Array sizes are scaled to this container (the paper used an i7 + 100
repeats); absolute seconds therefore differ from Table 1, the reproduced
quantities are the FFT/conv *fractions* and the speedup distribution
(median ~2x, mean dragged up by the two pure-kernel apps).  Paper values
are carried in PAPER_TABLE1 for side-by-side comparison.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import optics_sim as op
from repro.core.amdahl import AmdahlReport, report
from repro.core.profiler import OpProfiler

__all__ = ["run_suite", "BENCHMARKS", "PAPER_TABLE1"]

REPEATS = 3
_WL = 633e-9  # HeNe

# (fft/conv %, end-to-end speedup) from the paper's Table 1, same order.
PAPER_TABLE1 = {
    "convolution": (99.37, 159.41),
    "fourier_transform": (97.79, 45.32),
    "wiener_filter": (67.51, 3.08),
    "airy_beam": (63.24, 2.72),
    "youngs_experiment": (61.70, 2.61),
    "poisson_to_bessel": (61.33, 2.59),
    "bessel_annular_slit": (60.82, 2.55),
    "bessel_axicon": (60.71, 2.55),
    "multi_holes_slits": (60.70, 2.55),
    "circular_aperture": (60.65, 2.54),
    "shack_hartmann": (52.88, 2.12),
    "spot_of_poisson": (48.44, 1.94),
    "fresnel_zone_plate": (47.34, 1.90),
    "unstable_resonator": (39.43, 1.65),
    "doughnut_collinear": (30.54, 1.44),
    "michelson": (29.45, 1.42),
    "phase_recovery": (18.75, 1.23),
    "spiral_phase_plate": (18.75, 1.23),
    "hermite_to_laguerre": (18.29, 1.22),
    "doughnut_tilted": (7.31, 1.08),
    "double_slit_prysm": (55.91, 2.27),
    "first_diffraction_model": (47.80, 1.92),
    "image_simulation": (10.95, 1.12),
    "cnn_inference": (63.17, 2.71),
    "cnn_training": (10.68, 1.12),
    "audio_resampling": (37.94, 1.61),
    "wav2vec2_inference": (34.53, 1.53),
}


def _conv2d(x: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x[None, None], k[None, None], (1, 1), "SAME")[0, 0]


# --------------------------------------------------------------------------- #
# applications 0-2: pure kernels                                               #
# --------------------------------------------------------------------------- #


def bench_convolution(prof: OpProfiler) -> None:
    """App 0: SciPy-style full 2-D convolution of two 100x100 arrays
    (direct form, like scipy.signal.convolve2d)."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (100, 100))
    b = jax.random.normal(key, (100, 100))

    def direct_conv(x, k):
        return jax.lax.conv_general_dilated(
            x[None, None], k[None, None, ::-1, ::-1], (1, 1),
            [(99, 99), (99, 99)])[0, 0]

    for _ in range(4):
        prof.run("conv", direct_conv, a, b)


def bench_fourier_transform(prof: OpProfiler) -> None:
    """App 1: 2-D FFT over a large array (paper: 5000^2; here 1500^2)."""
    a = jax.random.normal(jax.random.PRNGKey(1), (1500, 1500))
    prof.run("fft", jnp.fft.fft2, a)


def bench_wiener_filter(prof: OpProfiler) -> None:
    """App 2: Wiener filter = two box-filter correlations + pointwise."""
    img = jax.random.normal(jax.random.PRNGKey(2), (800, 800))
    box = jnp.ones((5, 5)) / 25.0
    mean = prof.run("conv", _conv2d, img, box)
    sq_mean = prof.run("conv", _conv2d, img * img, box)
    var = sq_mean - mean ** 2
    noise = jnp.mean(var)
    out = mean + jnp.maximum(var - noise, 0) / jnp.maximum(var, 1e-9) * (img - mean)
    out.block_until_ready()


# --------------------------------------------------------------------------- #
# applications 3-19: LightPipes-style optics sims                              #
# --------------------------------------------------------------------------- #


def bench_airy_beam(prof: OpProfiler) -> None:
    f = op.begin(10e-3, _WL, 512)
    x, y = f.grid()
    sc = 1.2e-3
    airy = jnp.exp(-(x + y) / (4 * sc))  # exponential apodization
    f = op.Field(f.u * airy, f.size_m, f.wavelength)
    f = op.circ_screen(f, 0.4e-3)          # obstruction: beam self-heals
    for _ in range(6):
        f = op.forvard(f, 0.05, prof)
        _ = op.intensity(f)


def bench_youngs_experiment(prof: OpProfiler) -> None:
    f = op.begin(5e-3, _WL, 512)
    f = op.rect_slits(f, 0.06e-3, 2e-3, [(-0.3e-3, 0), (0.3e-3, 0)])
    f = op.forvard(f, 0.5, prof)
    _ = op.intensity(f)


def bench_poisson_to_bessel(prof: OpProfiler) -> None:
    f = op.begin(8e-3, _WL, 512)
    f = op.circ_screen(f, 1.0e-3)
    for z in (0.2, 0.4, 0.8, 1.6):
        g = op.forvard(f, z, prof)
        _ = op.intensity(g)


def bench_bessel_annular_slit(prof: OpProfiler) -> None:
    f = op.begin(8e-3, _WL, 512)
    f = op.circ_aperture(f, 1.5e-3)
    g = op.circ_screen(f, 1.4e-3)           # annulus
    g = op.lens(g, 0.5)
    for z in (0.3, 0.5, 0.7):
        h = op.forvard(g, z, prof)
        _ = op.intensity(h)


def bench_bessel_axicon(prof: OpProfiler) -> None:
    f = op.begin(8e-3, _WL, 512)
    f = op.gauss(f, 2e-3)
    f = op.axicon(f, 0.01)
    for z in (0.1, 0.2, 0.3):
        g = op.forvard(f, z, prof)
        _ = op.intensity(g)


def bench_multi_holes_slits(prof: OpProfiler) -> None:
    f = op.begin(5e-3, _WL, 512)
    centers = [(dx * 1e-4, dy * 1e-4) for dx in (-4, 0, 4) for dy in (-4, 0, 4)]
    f = op.rect_slits(f, 0.05e-3, 0.05e-3, centers)
    f = op.forvard(f, 1.0, prof)
    _ = op.intensity(f)


def bench_circular_aperture(prof: OpProfiler) -> None:
    f = op.begin(5e-3, _WL, 512)
    f = op.circ_aperture(f, 0.5e-3)
    f = op.forvard(f, 0.8, prof)
    _ = op.intensity(f)


def bench_shack_hartmann(prof: OpProfiler) -> None:
    f = op.begin(10e-3, _WL, 512)
    x, y = f.grid()
    aberration = jnp.exp(1j * 40 * (x / 5e-3) ** 3)   # coma-like wavefront
    f = op.Field(f.u * aberration, f.size_m, f.wavelength)
    f = op.lenslet_array(f, 1e-3, 0.05)
    f = op.forvard(f, 0.05, prof)
    spots = op.intensity(f)
    # centroid readout per lenslet (non-accelerable)
    s = spots.reshape(8, 64, 8, 64)
    w = s.sum((1, 3))
    (w / jnp.maximum(w.sum(), 1e-9)).block_until_ready()


def bench_spot_of_poisson(prof: OpProfiler) -> None:
    f = op.begin(8e-3, _WL, 512)
    f = op.circ_screen(f, 1.0e-3)
    f = op.forvard(f, 1.0, prof)
    _ = op.intensity(f)


def bench_fresnel_zone_plate(prof: OpProfiler) -> None:
    f = op.begin(6e-3, _WL, 512)
    f = op.zone_plate(f, 0.5)
    f = op.forvard(f, 0.5, prof)
    _ = op.intensity(f)


def bench_unstable_resonator(prof: OpProfiler) -> None:
    f = op.begin(10e-3, _WL, 256)
    for _ in range(8):                       # round trips
        f = op.circ_aperture(f, 2.5e-3)
        f = op.lens(f, -0.75)
        f = op.forvard(f, 0.5, prof)
        f = op.lens(f, 1.5)
        f = op.forvard(f, 0.5, prof)
        u = f.u / jnp.maximum(jnp.max(jnp.abs(f.u)), 1e-9)
        f = op.Field(u, f.size_m, f.wavelength)
    _ = op.intensity(f)


def bench_doughnut_collinear(prof: OpProfiler) -> None:
    f = op.begin(6e-3, _WL, 512)
    d = op.spiral_phase_plate(op.gauss(f, 1.5e-3), charge=1)
    d = op.forvard(d, 0.3, prof)
    g = op.gauss(f, 1.5e-3)
    g = op.forvard(g, 0.3, prof)
    for phase in np.linspace(0, 2 * np.pi, 12):
        _ = jnp.abs(d.u + jnp.exp(1j * phase) * g.u) ** 2
    _.block_until_ready()


def bench_michelson(prof: OpProfiler) -> None:
    f = op.begin(6e-3, _WL, 512)
    f = op.gauss(f, 2e-3)
    arm1 = op.forvard(f, 0.30, prof)
    for dz in np.linspace(0, _WL, 8):
        arm2 = op.Field(arm1.u * jnp.exp(2j * jnp.pi * dz / _WL),
                        f.size_m, f.wavelength)
        fringe = jnp.abs(arm1.u + arm2.u) ** 2
    fringe.block_until_ready()


def bench_phase_recovery(prof: OpProfiler) -> None:
    """Gerchberg-Saxton: iterative forward/backward FFTs + constraints."""
    key = jax.random.PRNGKey(3)
    target = jnp.abs(jax.random.normal(key, (256, 256)))
    field = jnp.exp(1j * jax.random.uniform(key, (256, 256)) * 2 * jnp.pi)
    for _ in range(15):
        far = prof.run("fft", jnp.fft.fft2, field)
        far = target * far / jnp.maximum(jnp.abs(far), 1e-9)
        near = prof.run("fft", jnp.fft.ifft2, far)
        field = near / jnp.maximum(jnp.abs(near), 1e-9)
        # host-side constraint bookkeeping (non-accelerable)
        err = jnp.mean((jnp.abs(far) - target) ** 2)
        err.block_until_ready()


def bench_spiral_phase_plate(prof: OpProfiler) -> None:
    f = op.begin(6e-3, _WL, 512)
    f = op.gauss(f, 1.5e-3)
    f = op.spiral_phase_plate(f, charge=1)
    f = op.forvard(f, 0.5, prof)
    _ = op.intensity(f)
    # mode purity analysis (non-accelerable azimuthal decomposition)
    x, y = f.grid()
    theta = jnp.arctan2(y, x)
    for m in range(-2, 3):
        (jnp.abs(jnp.sum(f.u * jnp.exp(-1j * m * theta))) ** 2).block_until_ready()


def bench_hermite_to_laguerre(prof: OpProfiler) -> None:
    f = op.begin(8e-3, _WL, 256)
    f = op.hermite_gauss(f, 1, 0, 1.5e-3)
    # astigmatic mode converter: two cylindrical lenses
    x, y = f.grid()
    k = 2 * jnp.pi / _WL
    for _ in range(2):
        f = op.Field(f.u * jnp.exp(-1j * k * x ** 2 / (2 * 0.5)), f.size_m, _WL)
        f = op.forvard(f, 0.35, prof)
    _ = op.intensity(f)
    # overlap with target LG mode (non-accelerable)
    r2 = x ** 2 + y ** 2
    lg = (x + 1j * y) * jnp.exp(-r2 / (1.5e-3) ** 2)
    (jnp.abs(jnp.vdot(lg, f.u)) ** 2).block_until_ready()


def bench_doughnut_tilted(prof: OpProfiler) -> None:
    f = op.begin(6e-3, _WL, 512)
    d = op.spiral_phase_plate(op.gauss(f, 1.5e-3), charge=1)
    d = op.forvard(d, 0.2, prof)
    g = op.tilt(op.gauss(f, 1.5e-3), 2e-4, 0.0)
    # many interference/analysis frames, single propagation: low fft share
    for phase in np.linspace(0, 2 * np.pi, 40):
        fr = jnp.abs(d.u + jnp.exp(1j * phase) * g.u) ** 2
        (fr / jnp.maximum(fr.max(), 1e-9)).block_until_ready()


# --------------------------------------------------------------------------- #
# applications 20-22: Prysm-style                                              #
# --------------------------------------------------------------------------- #


def bench_double_slit_prysm(prof: OpProfiler) -> None:
    f = op.begin(4e-3, _WL, 384)
    f = op.rect_slits(f, 0.05e-3, 1.5e-3, [(-0.25e-3, 0), (0.25e-3, 0)])
    ff = op.far_field(f, prof)
    psf = jnp.abs(ff) ** 2
    (psf / psf.max()).block_until_ready()


def bench_first_diffraction_model(prof: OpProfiler) -> None:
    f = op.begin(4e-3, _WL, 384)
    f = op.circ_aperture(f, 0.8e-3)
    ff = op.far_field(f, prof)
    psf = jnp.abs(ff) ** 2
    mtf = prof.run("fft", jnp.fft.fft2, psf)
    (jnp.abs(mtf) / jnp.abs(mtf).max()).block_until_ready()


def bench_image_simulation(prof: OpProfiler) -> None:
    """End-to-end Siemens-star imaging: optics PSF + detector chain."""
    n = 384
    # object: Siemens star (pure host math)
    xx, yy = jnp.meshgrid(jnp.linspace(-1, 1, n), jnp.linspace(-1, 1, n))
    theta = jnp.arctan2(yy, xx)
    star = 0.5 * (1 + jnp.sign(jnp.sin(24 * theta)))
    # optics: aberrated pupil -> PSF
    f = op.begin(4e-3, _WL, n)
    f = op.circ_aperture(f, 1.0e-3)
    x, y = f.grid()
    f = op.Field(f.u * jnp.exp(1j * 8 * (x / 1e-3) ** 2 * (y / 1e-3)), f.size_m, _WL)
    psf = jnp.abs(op.far_field(f, prof)) ** 2
    psf = psf / psf.sum()
    # image formation: conv via FFT (accelerable)
    conv = lambda a, b: jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(b)))
    img = prof.run("conv", conv, star, jnp.fft.ifftshift(psf))
    # detector chain (non-accelerable): sampling, shot/read noise, quantize
    key = jax.random.PRNGKey(4)
    ds = img.reshape(n // 4, 4, n // 4, 4).mean((1, 3))
    ds = ds + 0.01 * jax.random.normal(key, ds.shape)
    ds = jnp.clip(ds / jnp.maximum(ds.max(), 1e-9), 0, 1)
    q = jnp.round(ds * 4095) / 4095
    for _ in range(6):      # radiometric calibration sweeps
        g = (q - q.min()) / jnp.maximum(q.max() - q.min(), 1e-9)
        (g ** 2.2).block_until_ready()


# --------------------------------------------------------------------------- #
# applications 23-26: ML workloads                                             #
# --------------------------------------------------------------------------- #


def _cnn_params(key):
    k = jax.random.split(key, 4)
    return {
        "c1": 0.1 * jax.random.normal(k[0], (16, 3, 5, 5)),
        "c2": 0.1 * jax.random.normal(k[1], (32, 16, 5, 5)),
        "w1": 0.1 * jax.random.normal(k[2], (32 * 8 * 8, 64)),
        "w2": 0.1 * jax.random.normal(k[3], (64, 10)),
    }


def _cnn_forward(prof: OpProfiler | None, p, x):
    conv = lambda a, w: jax.lax.conv_general_dilated(a, w, (1, 1), "SAME")
    run = (lambda f, *a: prof.run("conv", f, *a)) if prof else (lambda f, *a: f(*a))
    h = jax.nn.relu(run(conv, x, p["c1"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 1, 2, 2),
                              (1, 1, 2, 2), "VALID")
    h = jax.nn.relu(run(conv, h, p["c2"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 1, 2, 2),
                              (1, 1, 2, 2), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["w1"])
    return h @ p["w2"]


def bench_cnn_inference(prof: OpProfiler) -> None:
    """App 23: CIFAR-style convnet inference (conv accelerable)."""
    p = _cnn_params(jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (64, 3, 32, 32))
    logits = _cnn_forward(prof, p, x)
    jax.nn.softmax(logits, -1).block_until_ready()


def bench_cnn_training(prof: OpProfiler) -> None:
    """App 24: one training epoch-slice: fwd is bracketed per-conv; the
    entire backward + SGD update is host ('other') work, mirroring the
    paper's finding that training accelerates far less than inference."""
    p = _cnn_params(jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 3, 32, 32))
    yl = jax.random.randint(jax.random.PRNGKey(9), (64,), 0, 10)

    def loss_fn(p):
        lg = _cnn_forward(None, p, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(64), yl])

    for _ in range(2):
        _ = _cnn_forward(prof, p, x)                  # measured fwd convs
        g = jax.grad(loss_fn)(p)                      # backward: 'other'
        p = jax.tree_util.tree_map(lambda a, b: a - 0.01 * b, p, g)
        jax.tree_util.tree_leaves(p)[0].block_until_ready()


def bench_audio_resampling(prof: OpProfiler) -> None:
    """App 25: sinc-kernel resampling of a batch of waveforms (1-D conv)."""
    key = jax.random.PRNGKey(10)
    wav = jax.random.normal(key, (4, 1, 48_000))
    t = jnp.arange(-64, 65) / 48_000
    sinc = jnp.sinc(2 * 16_000 * t) * jnp.hanning(129)
    kern = sinc[None, None, :]
    conv = lambda a: jax.lax.conv_general_dilated(a, kern, (3,), "SAME")
    out = prof.run("conv", conv, wav)
    # host: normalization + envelope checks
    (out / jnp.maximum(jnp.abs(out).max(), 1e-9)).block_until_ready()


def bench_wav2vec2_inference(prof: OpProfiler) -> None:
    """App 26: conv feature extractor (accelerable) + small transformer
    encoder (matmuls: host under a Fourier/conv accelerator)."""
    key = jax.random.PRNGKey(11)
    wav = jax.random.normal(key, (1, 1, 32_000))
    convs = []
    cin = 1
    for i, (cout, kw, st) in enumerate([(64, 10, 5), (64, 3, 2), (64, 3, 2),
                                        (64, 2, 2)]):
        convs.append(0.1 * jax.random.normal(jax.random.fold_in(key, i),
                                             (cout, cin, kw)))
        cin = cout
    h = wav
    for i, w in enumerate(convs):
        st = [5, 2, 2, 2][i]
        h = prof.run("conv", lambda a, ww: jax.nn.gelu(
            jax.lax.conv_general_dilated(a, ww, (st,), "VALID")), h, w)
    x = h.transpose(0, 2, 1)                         # (1, T, 64)
    dk = 64
    for i in range(4):                               # encoder layers: 'other'
        kq = 0.1 * jax.random.normal(jax.random.fold_in(key, 100 + i), (dk, dk))
        att = jax.nn.softmax((x @ kq) @ (x @ kq).transpose(0, 2, 1) / 8.0, -1)
        x = x + att @ (x @ kq)
        x = x + jax.nn.gelu(x @ kq) @ kq.T
    x.block_until_ready()


# --------------------------------------------------------------------------- #
# driver                                                                       #
# --------------------------------------------------------------------------- #

BENCHMARKS = [
    ("convolution", bench_convolution),
    ("fourier_transform", bench_fourier_transform),
    ("wiener_filter", bench_wiener_filter),
    ("airy_beam", bench_airy_beam),
    ("youngs_experiment", bench_youngs_experiment),
    ("poisson_to_bessel", bench_poisson_to_bessel),
    ("bessel_annular_slit", bench_bessel_annular_slit),
    ("bessel_axicon", bench_bessel_axicon),
    ("multi_holes_slits", bench_multi_holes_slits),
    ("circular_aperture", bench_circular_aperture),
    ("shack_hartmann", bench_shack_hartmann),
    ("spot_of_poisson", bench_spot_of_poisson),
    ("fresnel_zone_plate", bench_fresnel_zone_plate),
    ("unstable_resonator", bench_unstable_resonator),
    ("doughnut_collinear", bench_doughnut_collinear),
    ("michelson", bench_michelson),
    ("phase_recovery", bench_phase_recovery),
    ("spiral_phase_plate", bench_spiral_phase_plate),
    ("hermite_to_laguerre", bench_hermite_to_laguerre),
    ("doughnut_tilted", bench_doughnut_tilted),
    ("double_slit_prysm", bench_double_slit_prysm),
    ("first_diffraction_model", bench_first_diffraction_model),
    ("image_simulation", bench_image_simulation),
    ("cnn_inference", bench_cnn_inference),
    ("cnn_training", bench_cnn_training),
    ("audio_resampling", bench_audio_resampling),
    ("wav2vec2_inference", bench_wav2vec2_inference),
]


def run_one(name: str, fn, repeats: int = REPEATS) -> AmdahlReport:
    fn(OpProfiler())            # warm-up: populate compile caches
    prof = OpProfiler()
    prof.start()
    for _ in range(repeats):
        fn(prof)
    prof.stop()
    return report(name, prof.accelerable_s(("fft", "conv")), prof.total_s)


def run_suite(repeats: int = REPEATS):
    rows = []
    for name, fn in BENCHMARKS:
        rows.append(run_one(name, fn, repeats))
    return rows
