"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the xlstm-125m architecture at FULL width/depth (196M params with
embeddings) on the deterministic Markov task, with checkpointing + the
fault-tolerant runner — the complete production loop, CPU-runnable.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(expect ~15-40 min on one CPU core for 200 steps; use --steps 30 for a
quick look — loss visibly decreases within ~20 steps.)
"""

import argparse

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config instead of the full 125M")
    args = ap.parse_args()

    _, losses, task = train_loop(
        "xlstm-125m", smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, peak_lr=1e-3, log_every=10)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(task entropy floor {task.entropy_floor_nats:.3f} nats)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
