"""Serve a small model with batched requests (continuous batching).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main() -> None:
    cfg = get_smoke_config("recurrentgemma-9b")   # hybrid: attn + RG-LRU
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=4, max_len=96)

    t0 = time.time()
    for rid in range(10):
        prompt = [((rid + 1) * (j + 3)) % cfg.vocab_size for j in range(8)]
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=10))
    done = engine.run_to_completion()
    dt = time.time() - t0

    for r in sorted(done, key=lambda r: r.rid)[:5]:
        print(f"rid={r.rid}: {r.prompt[:4]}... -> {r.out_tokens}")
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, 4 slots, per-lane positions)")


if __name__ == "__main__":
    main()
