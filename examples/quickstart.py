"""Quickstart: the paper in five minutes.

1. Simulate the 4f optical accelerator computing an FFT and a convolution
   (physics vs digital oracle).
2. Price the same ops through the calibrated prototype cost model — see
   the data-conversion/data-movement bottleneck (Fig. 8).
3. Apply the planner's decision rule (§4-§6): when is offload worth it?

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    IDEAL_4F,
    PROTOTYPE_4F,
    CategoryProfile,
    OpticalSimParams,
    fourier_mask_for_kernel,
    ideal_speedup,
    optical_conv2d,
    optical_fft2_magnitude,
    plan_offload,
)


def main() -> None:
    print("=== 1. the physics: light computes the Fourier transform ===")
    key = jax.random.PRNGKey(0)
    image = jax.random.uniform(key, (64, 64))
    oracle = jnp.abs(jnp.fft.fft2(image, norm="ortho"))
    # The detector ADC auto-ranges on the DC peak, which sits ~14 bits above
    # the AC spectrum of a natural image: converter resolution IS the
    # accelerator's accuracy — another face of the conversion bottleneck.
    for adc_bits in (8, 12, 16):
        params = OpticalSimParams(dac_bits=12, adc_bits=adc_bits)
        mag = optical_fft2_magnitude(image, params)
        rel = float(jnp.linalg.norm(mag - oracle) / jnp.linalg.norm(oracle))
        print(f"  optical |FFT| vs digital oracle: rel error {rel:8.4f}  "
              f"({adc_bits:2d}-bit ADC)")

    params = OpticalSimParams(dac_bits=12, adc_bits=16)
    kernel = jnp.zeros((64, 64)).at[0, 0].set(0.6).at[1, 1].set(0.4)
    mask = fourier_mask_for_kernel(kernel)
    blur = optical_conv2d(image, mask, params)
    ob = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(image) * jnp.fft.fft2(kernel)))
    rel = float(jnp.linalg.norm(blur - ob) / jnp.linalg.norm(ob))
    print(f"  optical conv (4-step interferometric, 16-bit ADC): rel error "
          f"{rel:.4f}")

    print("\n=== 2. the bottleneck: pricing the same op end to end ===")
    n = 1024 * 768
    cost = PROTOTYPE_4F.step_cost(n)
    print(f"  prototype 4f, {n} px frame: total {cost.total_s:.3f}s of which "
          f"{100 * cost.data_movement_fraction:.3f}% is data movement")
    print(f"    DAC {cost.dac_s * 1e3:.2f}ms | ADC {cost.adc_s * 1e3:.2f}ms | "
          f"interface {cost.interface_s:.3f}s | optics {cost.analog_s * 1e3:.1f}ms")
    print("  (paper Fig. 8: 5.209s, 99.599% movement, 23.8x slower than "
          "the software FFT)")

    print("\n=== 3. the decision rule: Amdahl with conversion costs ===")
    # an application that is 60% FFT time (a typical optics sim, Table 1)
    profiles = [
        CategoryProfile("fft", host_s=0.6, calls=10,
                        samples_in=10 * 512 * 512, samples_out=10 * 512 * 512),
        CategoryProfile("other", host_s=0.4),
    ]
    for spec in (IDEAL_4F, PROTOTYPE_4F):
        plan = plan_offload(profiles, spec)
        print(f"  {spec.name:13s}: end-to-end speedup "
              f"{plan.end_to_end_speedup:5.2f}x "
              f"(ideal Amdahl bound {plan.ideal_speedup:.2f}x, "
              f"worthwhile(>=10x)={plan.worthwhile})")
    print(f"  to reach 10x you must offload >= {100 * (1 - 1 / 10):.0f}% of "
          f"the application (paper §5): here only 60% is offloadable ->"
          f" bound {ideal_speedup(0.6):.1f}x.")


if __name__ == "__main__":
    main()
