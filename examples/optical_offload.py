"""Offload a CNN's convolutions to the simulated optical accelerator.

The paper's App. C benchmark 23 (CNN inference), made concrete: run the
network digitally, then run it with every conv layer routed through the
4f physics simulator (DAC -> SLM -> diffraction -> detector -> ADC), and
price the offload with the honest conversion-cost model.

Shows all three of the paper's findings at once:
  * functionally the optics compute the right thing (accuracy gap small);
  * the conversion boundary dominates the accelerator's wall time;
  * Amdahl caps the end-to-end win because only convs offload.

Run:  PYTHONPATH=src python examples/optical_offload.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import (
    PROTOTYPE_4F,
    CategoryProfile,
    OpticalSimParams,
    OpProfiler,
    fourier_mask_for_kernel,
    optical_conv2d,
    plan_offload,
)


def conv_digital(x: jax.Array, k: jax.Array) -> jax.Array:
    """Per-channel circular conv via FFT (the op the optics replace)."""
    return jnp.real(jnp.fft.ifft2(jnp.fft.fft2(x) * jnp.fft.fft2(k)))


def conv_optical(x: jax.Array, k: jax.Array, params, key) -> jax.Array:
    mask = fourier_mask_for_kernel(k, params=params)     # amortized per kernel
    xm = jnp.maximum(x.max(), 1e-9)
    return optical_conv2d(x / xm, mask, params, key) * xm


def main() -> None:
    key = jax.random.PRNGKey(0)
    params = OpticalSimParams(dac_bits=8, adc_bits=12)
    img = jax.random.uniform(key, (64, 64))
    kernels = [jnp.zeros((64, 64)).at[:5, :5].set(
        0.04 * jax.random.normal(jax.random.fold_in(key, i), (5, 5)))
        for i in range(3)]

    # --- functional comparison: digital vs optical conv stack ---------------
    dig = opt = img
    for i, k in enumerate(kernels):
        dig = jax.nn.relu(conv_digital(dig, k))
        opt = jax.nn.relu(conv_optical(opt, k, params,
                                       jax.random.fold_in(key, 100 + i)))
    rel = float(jnp.linalg.norm(dig - opt) / jnp.maximum(
        jnp.linalg.norm(dig), 1e-9))
    print(f"3-layer conv stack, digital vs optical: rel error {rel:.4f}")

    # --- profile the digital app, then price offload ------------------------
    prof = OpProfiler()
    prof.start()
    x = img
    for k in kernels:
        x = prof.run("conv", conv_digital, x, k)
        x = jax.nn.relu(x)                      # 'other' (host nonlinearity:
        x.block_until_ready()                   # the paper's §3 point)
    head = x.reshape(-1) @ jax.random.normal(key, (64 * 64, 10))
    jax.nn.softmax(head).block_until_ready()
    prof.stop()

    profiles = [
        CategoryProfile("conv", host_s=prof.seconds["conv"],
                        calls=prof.calls["conv"],
                        samples_in=prof.samples_in["conv"],
                        samples_out=prof.samples_out["conv"]),
        CategoryProfile("other",
                        host_s=prof.total_s - prof.seconds["conv"]),
    ]
    plan = plan_offload(profiles, PROTOTYPE_4F)
    print(plan.summary())
    print("\npaper's conclusion, reproduced: the nonlinearity between conv "
          "layers forces a full conversion round-trip per layer (§3); with "
          "honest DAC/ADC+interface costs the prototype never wins "
          f"(offload chosen: {any(d.offload for d in plan.decisions)}).")


if __name__ == "__main__":
    main()
