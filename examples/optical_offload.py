"""Run a CNN workload through the conversion-aware offload runtime.

The seed version of this example *priced* offload (profile -> plan ->
print); PR 1 *executed* the plan.  This version executes it the way the
batching story prices it:

  1. profile   — serve the conv workload through the runtime's host backend;
                 telemetry measures per-category time and boundary traffic;
  2. plan      — ``PlanRouter.replan()`` prices the measured profiles on the
                 prototype 4f engine (spoiler: the conversion boundary loses,
                 the paper's conclusion) and on a batched column-parallel
                 variant.  Replanning is *adaptive*: the router picks each
                 category's coalescing ceiling from observed traffic, and a
                 latency ``deadline_s`` caps how deep batching may go;
  3. execute   — apply the plan: conv traffic routes through the simulated
                 optical engine; same-shape calls coalesce into ONE batched
                 invocation each (stacked operands, vmapped 4f physics), and
                 ``flush_async`` double-buffers the boundary — invocation
                 k+1 stages while invocation k's analog+ADC compute is in
                 flight, with per-result ``wait()``/``done()`` readiness;
  4. verify    — every offloaded batch is shadowed by the host reference and
                 scored against the converters' ENOB budget, so the speedup
                 story is always paired with its accuracy cost.
  5. scale out — the same flush group scatters across four replicated
                 simulated apertures (``n_devices=4``, the ``sharded``
                 backend): every device pays its own DAC/ADC boundary
                 crossing, telemetry aggregates per-device samples, and the
                 modeled invocation wall drops to max-over-devices + sync.
  6. trickle   — serve a sparse Poisson arrival stream through the
                 admission-controlled ``OffloadScheduler``: partially
                 filled groups are *held open across flushes* (released
                 when full, due, or futile to keep holding per the measured
                 arrival rate), so occupancy climbs where drain-on-flush
                 would cross the boundary one frame at a time — and the
                 queueing delay that buys it is priced (``StepCost.hold_s``).
  7. tile      — large frames under a memory budget: at 512x512 the
                 monolithic stacked flush group overflows the LLC
                 (VMEM on TPU), so ``replan`` picks a sub-group ``tile_k``
                 from the detected byte budget and the released group
                 streams as tile-sized sub-invocations through the same
                 two-deep pipeline — amortization per tile, cache-resident
                 working set.
  8. observe   — attach the opt-in span tracer and re-run the conv
                 workload: one span tree per batched invocation
                 (submit -> release -> stage -> compute -> shadow), a
                 one-screen trace digest, wall percentiles per category,
                 and the modeled-vs-measured drift table that names the
                 stage where the cost model and the wall clock disagree
                 most.
  9. survive   — wrap the optical backend in a seeded ``ChaosBackend``
                 (10% of dispatches fault: transient errors, stragglers,
                 ENOB drift, device loss) and serve the same frames: the
                 retry ladder re-runs transient faults, exhaustion
                 degrades gracefully to the host backend, drifted batches
                 are corrected from the fidelity shadow and the category
                 quarantined — every frame still retires, in order, within
                 the converters' error budget, with the whole fault story
                 visible in fault counters and recovery percentiles.
  10. reuse    — turn on the operand residency cache
                 (``OffloadExecutor(residency=True)``) and re-serve a conv
                 layer stack that re-uses its frames and kernel: the first
                 flush stages and quantizes everything (and registers it
                 resident), every later flush skips the write-side DAC
                 crossing entirely — priced read-side-only
                 (``cost.dac_s == 0``) and bit-equal to the re-staged
                 path, with the hit/miss ledger in telemetry.

Executors are context managers: each ``with`` block below guarantees no
pending, held, or in-flight group outlives the demo that created it.

Run:  PYTHONPATH=src python examples/optical_offload.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE_4F
from repro.runtime import (
    BATCHED_4F,
    CONV_CAPTURES,
    FidelityChecker,
    ManualClock,
    MemoryBudget,
    OffloadExecutor,
    OffloadScheduler,
    PlanRouter,
    Tracer,
    drift_report,
    enob_error_bound,
    register_chaos,
    summarize,
)


def conv_stack(router: PlanRouter, imgs, kernels) -> list[jax.Array]:
    """3-layer circular-conv + relu stack over a batch of images.

    Convolutions go through the router (host or optical per the current
    plan); the nonlinearities stay on the host — the paper's §3 point that
    inter-layer nonlinearity forces a conversion round trip per layer.
    Dispatch is async: the flush returns with results in flight and each
    layer blocks only when the relu actually needs the values.
    """
    outs = list(imgs)
    for k in kernels:
        handles = [router.submit("conv", x, kernel=k) for x in outs]
        router.executor.flush_async()        # batched + double-buffered
        outs = [jax.nn.relu(h.wait().value) for h in handles]
    return outs


def main() -> None:
    key = jax.random.PRNGKey(0)
    # 512x512 frames: the regime where the host FFT costs real milliseconds
    # and 8 inputs still pack into one 2048x2048 SLM frame (one frame-sync).
    imgs = [jax.random.uniform(jax.random.fold_in(key, i), (512, 512))
            for i in range(8)]
    # 5x5 taps around an identity center: keeps each layer's output norm
    # comparable to its input (a near-cancelling kernel would amplify the
    # boundary's relative error — the fidelity checker flags such cases).
    kernels = [jnp.zeros((512, 512)).at[:5, :5].set(
        0.04 * jax.random.normal(jax.random.fold_in(key, 100 + i), (5, 5)))
        .at[0, 0].add(0.5) for i in range(3)]

    fidelity = FidelityChecker()
    # the executor is a context manager: nothing queued, held, or in
    # flight survives the block (results materialize, telemetry balances).
    # The budget is pinned to unlimited here: steps 1-4 demonstrate the
    # full-occupancy amortization story (one monolithic invocation per
    # group); step 7 below turns the detected budget on and shows what
    # memory-budgeted tiling changes at this frame size.
    with OffloadExecutor(BATCHED_4F, fidelity=fidelity, max_batch=16,
                         pipeline_depth=2,
                         mem_budget=MemoryBudget.unlimited()) as executor:
        run_plan_demo(executor, imgs, kernels)
    run_sharded_demo(imgs, kernels)
    run_trickle_demo()
    run_tiled_demo(imgs)
    run_traced_demo(imgs, kernels)
    run_chaos_demo()
    run_residency_demo()


def run_plan_demo(executor: OffloadExecutor, imgs, kernels) -> None:
    router = PlanRouter(executor)            # starts all-host: profiling mode

    # --- 1. profile: measured traffic, no hand-written numbers --------------
    # warm primes the single-item AND batched jit shapes, so the first real
    # flush below pays zero compilation
    executor.warm("conv", imgs[0], kernel=kernels[0], backend="host",
                  batch=len(imgs))
    executor.telemetry.start()
    host_out = conv_stack(router, imgs, kernels)
    executor.telemetry.stop()
    print(executor.telemetry.summary())

    # --- 2. plan: price the observed workload, adapt the batching ------------
    proto_plan = router.replan(spec=PROTOTYPE_4F, apply=False, max_batch=1)
    print("\n-- measured plan on the paper's prototype (Fig. 8 links) --")
    print(proto_plan.summary())
    print("paper's conclusion, reproduced from *measured* traffic: "
          f"offload chosen = {any(d.offload for d in proto_plan.decisions)}")

    # adaptive batching: the ceiling follows the workload, and a latency
    # deadline trades amortization depth against invocation wall time
    print("\n-- adaptive per-category coalescing ceilings --")
    print(f"unconstrained: {router.choose_max_batch()}")
    n_in, _ = executor.telemetry.samples_per_call("conv")
    tight = dataclasses.replace(
        BATCHED_4F, phase_shift_captures=CONV_CAPTURES).batched_step_cost(
            n_in, batch=4, pipeline_depth=2).total_s
    print(f"deadline {tight * 1e3:.1f} ms: "
          f"{router.choose_max_batch(deadline_s=tight)}")

    plan = router.replan()                   # batched-4f spec; applies routes
    print("\n-- measured plan on the batched column-parallel variant --")
    print(plan.summary())
    print(f"routes now: {router.routes}  "
          f"max_batch now: {dict(executor.category_max_batches())}")

    # --- 3. execute the plan: conv through the optical engine ----------------
    opt_out = conv_stack(router, imgs, kernels)
    rel = max(float(jnp.linalg.norm(h - o) / jnp.maximum(
        jnp.linalg.norm(h), 1e-9)) for h, o in zip(host_out, opt_out))
    conv_stats = executor.telemetry.stats.get(("conv", "optical-sim"))
    if conv_stats is not None:
        per_call = conv_stats.modeled.scaled(1.0 / max(conv_stats.calls, 1))
        single = dataclasses.replace(
            BATCHED_4F, phase_shift_captures=CONV_CAPTURES).step_cost(512 * 512)
        print(f"\nbatched boundary cost/call: conv+interface "
              f"{per_call.conversion_s + per_call.interface_s:.4g}s "
              f"(unbatched would pay {single.conversion_s + single.interface_s:.4g}s)"
              f" — {conv_stats.calls} calls in {conv_stats.invocations} "
              f"batched invocations")

    # --- 4. verify: the accuracy cost of the speedup --------------------------
    print(f"\nend-to-end stack divergence vs host: rel error {rel:.4f}")
    print(executor.fidelity.summary())


def run_sharded_demo(imgs, kernels) -> None:
    # --- 5. scale out: shard the flush group across replicated apertures ------
    # Photonic systems scale by replicating apertures, not growing one.
    # unlimited budget: sharding's claim is ONE invocation scattered whole
    # across the fleet — tiling first would scatter 2-frame tiles over 2
    # devices each and muddle the comparison (step 7 owns that story)
    with OffloadExecutor(BATCHED_4F, max_batch=16, n_devices=4,
                         default_backend="sharded",
                         mem_budget=MemoryBudget.unlimited()) as sharded:
        sharded.warm("conv", imgs[0], kernel=kernels[0], batch=len(imgs))
        handles = [sharded.submit("conv", im, kernel=kernels[0])
                   for im in imgs]
        sharded.flush()
        # runtime-equivalence invariant: sharded == host reference
        ref = [jnp.real(jnp.fft.ifft2(jnp.fft.fft2(im)
                                      * jnp.fft.fft2(kernels[0])))
               for im in imgs]
        rel_sh = max(float(jnp.linalg.norm(h.value - r) / jnp.linalg.norm(r))
                     for h, r in zip(handles, ref))
        sharded_total = sum(h.cost.total_s for h in handles)
        single_total = dataclasses.replace(
            BATCHED_4F, phase_shift_captures=CONV_CAPTURES).batched_step_cost(
                512 * 512, batch=len(imgs), pipeline_depth=2).total_s
        print("\n-- sharded offload: 4 replicated apertures, group sharding --")
        per_dev = sharded.telemetry.device_samples("conv")
        for d, (s_in, s_out) in per_dev.items():
            print(f"  device {d}: {s_in} samples through its DAC, "
                  f"{s_out} back through its ADC")
        print(f"sharded-vs-host rel error {rel_sh:.4f} (equivalence invariant)")
        print(f"modeled invocation wall: sharded {sharded_total:.4g}s "
              f"(max-over-devices + sync) vs single-device {single_total:.4g}s "
              f"-> {single_total / sharded_total:.3f}x")


def run_trickle_demo(rate_hz: float = 200.0, deadline_s: float = 0.05,
                     arrivals: int = 24) -> None:
    # --- 6. trickle traffic: admission-controlled continuous batching ---------
    # A Poisson stream too sparse to fill a batch between flushes.  The
    # pre-scheduler regime drained the queue on every flush: occupancy 1,
    # full handshake + settle per frame.  The scheduler holds partially
    # filled groups open across flushes — released when full (max_batch),
    # due (deadline), or futile (measured arrival rate says the next
    # arrival lands past the deadline) — and the modeled wall prices the
    # queueing delay it spent (StepCost.hold_s).  A ManualClock drives the
    # arrivals, so the occupancy shown is deterministic.
    frames = [jax.random.uniform(jax.random.fold_in(
        jax.random.PRNGKey(42), i), (128, 128)) for i in range(arrivals)]
    print(f"\n-- trickle arrivals ({rate_hz:.0f}/s Poisson, "
          f"{deadline_s * 1e3:.0f} ms hold deadline) --")
    for held in (False, True):
        rng = np.random.RandomState(0)       # same trace for both regimes
        clk = ManualClock()
        with OffloadExecutor(BATCHED_4F, max_batch=8, clock=clk) as ex:
            ex.warm("fft", frames[0])
            sched = OffloadScheduler(ex, deadline_s=deadline_s, clock=clk) \
                if held else None
            for i, frame in enumerate(frames):
                clk.advance(float(rng.exponential(1.0 / rate_hz)))
                if held:
                    sched.submit("fft", frame)   # polls: holds or releases
                else:
                    ex.submit("fft", frame)
                    ex.flush()                   # drain-on-flush baseline
        st = ex.telemetry.stats[("fft", "optical-sim")]
        per_call = st.modeled.scaled(1.0 / st.calls)
        label = "scheduler-held" if held else "drain-on-flush"
        print(f"  {label:>15}: {st.calls} calls in {st.invocations} "
              f"crossings (occupancy {st.calls / st.invocations:.2f}), "
              f"boundary {per_call.conversion_s + per_call.interface_s:.4g}s"
              f"/call, hold {per_call.hold_s:.4g}s/call, "
              f"modeled wall {per_call.total_s:.4g}s/call")


def run_tiled_demo(imgs) -> None:
    # --- 7. large frames: memory-budgeted tiled dispatch ----------------------
    # A 512x512 K=8 flush group's monolithic stack (frames + complex
    # intermediates + results) falls out of the CPU's last-level cache
    # off-TPU — the regime where batching measurably loses to looping.
    # The executor's memory budget (LLC-derived here, VMEM-derived on
    # TPU) makes replan pick a sub-group tile_k: the released group
    # streams as budget-sized sub-invocations through the same two-deep
    # pipeline, each tile's staging overlapped with the previous tile's
    # in-flight compute.
    budget = MemoryBudget.detect()
    print(f"\n-- large frames: memory-budgeted tiled dispatch "
          f"({budget.bytes_limit // (1024 * 1024)} MiB {budget.source} "
          f"budget, reserve {budget.reserve:.0%}) --")
    with OffloadExecutor(BATCHED_4F, max_batch=16,
                         mem_budget=budget) as ex:
        router = PlanRouter(ex)              # all-host profiling mode
        ex.warm("fft", imgs[0], backend="host", batch=len(imgs))
        ex.telemetry.start()
        for h in [router.submit("fft", im) for im in imgs]:
            h.get()
        ex.telemetry.stop()
        router.replan()                      # picks (max_batch, n_devices, tile_k)
        k, _n, t = router.choose_sharding()["fft"]
        print(f"replan chose max_batch={k}, tile_k={t} for 512x512 fft "
              f"(monolithic would stage "
              f"{k * 2 * 512 * 512 * 4 // (1024 * 1024)} MiB + intermediates)")
        n_in, n_out = ex.telemetry.samples_per_call("fft")
        mono = BATCHED_4F.batched_step_cost(n_in, n_out, batch=k,
                                            pipeline_depth=2)
        tiled = BATCHED_4F.batched_step_cost(n_in, n_out, batch=k,
                                             pipeline_depth=2, tile_k=t)
        print(f"modeled invocation wall: tiled {tiled.total_s:.4g}s vs "
              f"monolithic {mono.total_s:.4g}s — the boundary model prices "
              f"each tile's own handshake/settle honestly; tiling wins on "
              f"the MEASURED host wall (cache locality), which is what the "
              f"benchmark's large_frame row asserts")
        # drive one group through the simulated engine to show the
        # dispatch granularity the budget (via replan's set_tile_k)
        # forced — on fresh telemetry, so the printed tile counts are the
        # optical dispatches alone, not the host profiling phase's
        ex.telemetry.reset()
        ex.warm("fft", imgs[0], batch=len(imgs))
        for h in [ex.submit("fft", im, backend="optical-sim")
                  for im in imgs]:
            h.get()
        tiles = ex.telemetry.tile_sizes_observed("fft")
        print(f"dispatched tile sizes (telemetry): {tiles} — measured "
              f"{ex.telemetry.bytes_per_frame('fft') // 1024} KiB/frame "
              f"staged")


def run_traced_demo(imgs, kernels) -> None:
    # --- 8. observe: boundary-attributed tracing -------------------------------
    # The tracer is opt-in (OffloadExecutor(tracer=...)); the default is a
    # no-op with zero hot-path cost.  Each batched invocation becomes one
    # span tree — submit instants on the sched lane, the release that
    # dispatched it, the charged host staging (DAC-side) span, the charged
    # device compute (analog+ADC) span, the fidelity shadow — annotated
    # with the modeled batched_step_cost decomposition, so the drift
    # report can name the stage where model and wall clock disagree.
    tracer = Tracer()
    with OffloadExecutor(BATCHED_4F, max_batch=16, tracer=tracer,
                         mem_budget=MemoryBudget.unlimited()) as ex:
        ex.warm("conv", imgs[0], kernel=kernels[0], batch=len(imgs))
        ex.telemetry.start()
        for h in [ex.submit("conv", im, kernel=kernels[0]) for im in imgs]:
            h.get()
        ex.telemetry.stop()
        print("\n-- traced: one flush group, boundary-attributed --")
        print(summarize(tracer.spans()))
        pct = ex.telemetry.percentiles("conv")
        print("conv wall percentiles: " + "  ".join(
            f"p{int(p)}={v * 1e3:.2f}ms" for p, v in pct.items()))
        print("\nmodeled-vs-measured drift (per stage):")
        print(drift_report(tracer.spans()).table())


def run_chaos_demo(calls: int = 32, rate: float = 0.10) -> None:
    # --- 9. survive: fault-injected offload under the retry/quarantine policy --
    # A seeded ChaosBackend perturbs 10% of dispatches (transient errors,
    # latency-spike stragglers, ENOB drift, hard device loss).  The
    # executor's RetryPolicy retries transients with jittered backoff
    # (slept through the ManualClock — no real waiting), degrades to the
    # host backend when the ladder exhausts (quarantining the category so
    # later dispatches reroute instead of re-paying retries), and the
    # fidelity shadow corrects drifted batches on the spot.  The claim:
    # every frame retires, in submit order, within the ENOB error budget.
    frames = [jax.random.uniform(jax.random.fold_in(
        jax.random.PRNGKey(7), i), (64, 64)) for i in range(calls)]
    chaos = register_chaos("optical-sim", name="chaos-demo",
                           rate=rate, seed=2)
    clk = ManualClock()
    with OffloadExecutor(BATCHED_4F, default_backend=chaos, max_batch=4,
                         clock=clk, fidelity=FidelityChecker()) as ex:
        ex.warm("fft", frames[0])
        handles = [ex.submit("fft", f) for f in frames]
    with OffloadExecutor(BATCHED_4F, default_backend="host",
                         max_batch=1) as host:
        refs = [host.submit("fft", f) for f in frames]
    enob = min(BATCHED_4F.dac.effective_bits, BATCHED_4F.adc.effective_bits)
    bound = enob_error_bound(enob, 16.0)
    worst = max(float(jnp.linalg.norm(h.value - r.value)
                      / jnp.maximum(jnp.linalg.norm(r.value), 1e-12))
                for h, r in zip(handles, refs))
    served = {h.backend for h in handles}
    print(f"\n-- chaos: {rate:.0%} injected fault rate over {calls} calls --")
    print(ex.telemetry.summary())
    print(f"served by {sorted(served)}; all retired: "
          f"{all(h.ready for h in handles)}; worst rel error {worst:.2e} "
          f"(ENOB bound {bound:.2e}) -> within budget: {worst <= bound}")
    print(ex.quarantine.summary(ex.now()))


def run_residency_demo(calls: int = 8) -> None:
    # --- 10. reuse: operand residency across repeated flushes -----------------
    # A conv layer stack that re-serves the SAME frames through the SAME
    # kernel (inference over a fixed activation set, an iterative solve,
    # a re-scored beam) pays the write-side DAC crossing once.  With
    # ``residency=True`` the first flush stages + quantizes every operand
    # and registers it resident under the staging budget; the second flush
    # finds everything already on the device, skips the write side
    # entirely, and is priced read-side-only: cost.dac_s == 0 while the
    # results stay bit-equal to a residency-off executor.
    key = jax.random.PRNGKey(11)
    imgs = [jax.random.uniform(jax.random.fold_in(key, i), (128, 128))
            for i in range(calls)]
    kernel = jnp.zeros((128, 128)).at[:3, :3].set(
        0.05 * jax.random.normal(jax.random.fold_in(key, 99), (3, 3))
    ).at[0, 0].add(0.5)

    with OffloadExecutor(BATCHED_4F, max_batch=calls,
                         residency=True) as ex:
        first = [ex.submit("conv", x, kernel=kernel) for x in imgs]
        ex.flush()
        second = [ex.submit("conv", x, kernel=kernel) for x in imgs]
        ex.flush()
        hit_rate = ex.telemetry.residency_hit_rate("conv")
        ledger = ex.residency.summary()
    with OffloadExecutor(BATCHED_4F, max_batch=calls) as plain:
        refs = [plain.submit("conv", x, kernel=kernel) for x in imgs]

    bit_equal = all(bool(jnp.array_equal(s.value, r.value))
                    for s, r in zip(second, refs))
    print(f"\n-- residency: serve {calls} conv frames twice, "
          f"pay the DAC once --")
    print(f"first flush  (cold): dac {first[0].cost.dac_s * 1e6:8.2f}us/call "
          f"total {first[0].cost.total_s * 1e6:8.2f}us/call")
    print(f"second flush (hit):  dac {second[0].cost.dac_s * 1e6:8.2f}us/call "
          f"total {second[0].cost.total_s * 1e6:8.2f}us/call")
    print(f"hit rate {hit_rate:.0%}; bit-equal to residency-off: {bit_equal}")
    print(ledger)


if __name__ == "__main__":
    main()
