"""Cross-pod int8 gradient compression: numeric + lowering proof.

The train-time analogue of the paper's conversion boundary: gradients must
cross the slow inter-pod link every step.  This test proves (a) the
error-feedback int8 all-reduce matches the fp32 all-reduce in the long run,
and (b) the wire payload in the partitioned HLO is int8/int16 — 2-4x fewer
bytes than the bf16/fp32 collective it replaces (subprocess: forces its own
device count)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import ef_compress, ef_decompress, ef_init, ef_scale

from repro.distributed.compat import enter_mesh, make_auto_mesh
mesh = make_auto_mesh((2, 2), ("pod", "data"))
enter_mesh(mesh)
N_POD = 2

def compressed_pod_allreduce(g, res):
    # per-pod shard: psum over data in bf16, then int8 over the pod link;
    # the quantization scale is pmax-shared across pods (one scalar
    # collective) so dequantization is exact and error feedback unbiased
    g = jax.lax.psum(g.astype(jnp.float32), "data") / mesh.shape["data"]
    scale = ef_scale({"g": g}, {"g": res})
    scale = {"g": jax.lax.pmax(scale["g"], "pod")}
    q, scale, res_d = ef_compress({"g": g}, {"g": res}, scale=scale)
    wire = jax.lax.psum(q["g"].astype(jnp.int16), "pod")   # |sum|<=254: int16 safe
    out = wire.astype(jnp.float32) * scale["g"] / N_POD
    return out, res_d["g"]

fn = shard_map(compressed_pod_allreduce, mesh=mesh,
               in_specs=(P("pod", "data"), P("pod", "data")),
               out_specs=(P("pod", "data"), P("pod", "data")))

key = jax.random.PRNGKey(0)
g_global = jax.random.normal(key, (8, 64))
res = ef_init({"g": jnp.zeros((4, 32))})["g"]  # per-shard residual

jit_fn = jax.jit(fn)
out, res2 = jit_fn(g_global, jnp.zeros((8, 64)))
# reference: plain mean over pods of data-mean
ref = g_global  # every shard holds its own grad; all-reduce = global mean
# numeric: single round int8 error <= 2*scale; accumulate 10 rounds w/ feedback
tot = jnp.zeros((8, 64)); r = jnp.zeros((8, 64))
for _ in range(10):
    o, r = jit_fn(g_global, r)
    tot = tot + o
err = float(jnp.max(jnp.abs(tot / 10 - jax.jit(lambda g: g)(g_global) * 0 - tot / 10)))
# long-run unbiasedness: mean of sent == true mean reduce
true = jax.jit(shard_map(
    lambda g: jax.lax.pmean(jax.lax.pmean(g.astype(jnp.float32), "data"), "pod"),
    mesh=mesh, in_specs=P("pod", "data"), out_specs=P("pod", "data")))(g_global)
drift = float(jnp.max(jnp.abs(tot / 10 - true)))

txt = jit_fn.lower(g_global, jnp.zeros((8, 64))).compile().as_text()
has_int_wire = ("s16[" in txt and "all-reduce" in txt) or ("s8[" in txt)
int_ar = [l for l in txt.splitlines() if "all-reduce" in l and ("s16[" in l or "s32[" in l)]
print("RESULT:" + json.dumps({"drift": drift, "int_wire": bool(int_ar),
                              "n_int_allreduce": len(int_ar)}))
"""


@pytest.mark.slow
def test_int8_cross_pod_allreduce():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    # error-feedback keeps the 10-round mean within a few quantization steps
    # (per-pod scales differ; the residual tracks the mismatch)
    assert out["drift"] < 0.15, out
    # the pod-link collective really is an integer all-reduce in the HLO
    assert out["int_wire"], out
