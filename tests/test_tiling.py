"""Memory-budgeted tiled dispatch: unit + integration coverage.

The tentpole invariant (tiled == monolithic == looped on every backend,
sharded and scheduler-held paths included) lives in the property harnesses
of ``tests/test_sharded.py`` / ``tests/test_scheduler.py``; this file
covers the subsystem itself: budget detection and arithmetic, tile/block
choice, the cost model's ``tile_k``/``mem_budget`` mode on both
accelerator families, the executor's budget-driven dispatch + warm-up
parity, telemetry's per-tile samples and measured bytes/frame, the
block-keyed kernel caches (the stale-compile satellite), and the router's
joint ``(max_batch, n_devices, tile_k)`` choice.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.runtime import (
    BATCHED_4F,
    MemoryBudget,
    OffloadExecutor,
    PlanRouter,
    RuntimeTelemetry,
    choose_blocks,
    choose_tile,
    tile_sizes,
)
from repro.runtime.tiling import _INTERMEDIATE_FACTOR, BYTES_F32

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6,
    device_sync_s=1.0e-5)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)

SPEC = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)


def _imgs(n, shape, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _budget_for_frames(n_samples: int, frames: int,
                       pipeline_depth: int = 2) -> MemoryBudget:
    """A manual budget sized to admit exactly ``frames`` frames of
    ``n_samples`` f32 samples under the standard working-set model."""
    bpf = int(BYTES_F32 * 2 * n_samples * _INTERMEDIATE_FACTOR)
    return MemoryBudget(bpf * pipeline_depth * frames, source="manual",
                        reserve=1.0)


# --- MemoryBudget -------------------------------------------------------------


def test_memory_budget_arithmetic():
    b = MemoryBudget(1000, reserve=0.5)
    assert b.spendable_bytes == 500
    assert b.frames_within(100) == 5
    assert b.frames_within(100, pipeline_depth=2) == 2
    # a lone frame bigger than the whole budget still dispatches
    assert b.frames_within(10_000) == 1
    with pytest.raises(ValueError):
        b.frames_within(0)
    with pytest.raises(ValueError):
        MemoryBudget(1000, reserve=0.0)
    u = MemoryBudget.unlimited()
    assert u.is_unlimited
    assert u.frames_within(10**9) is None
    assert u.tile_for(10**9) is None


def test_memory_budget_detect_off_tpu_is_llc_derived():
    b = MemoryBudget.detect(platform="cpu")
    assert b.source == "llc" and b.bytes_limit > 0
    t = MemoryBudget.detect(platform="tpu")
    assert t.source == "vmem" and t.bytes_limit == 16 * 1024 * 1024
    # the default platform resolves without error and is one of the two
    assert MemoryBudget.detect().source in ("llc", "vmem")


# --- choose_tile / tile_sizes -------------------------------------------------


def test_tile_sizes_covers_ragged_tails():
    assert tile_sizes(7, 3) == [3, 3, 1]
    assert tile_sizes(8, 4) == [4, 4]
    assert tile_sizes(5, 1) == [1, 1, 1, 1, 1]
    assert tile_sizes(3, 9) == [3]          # tile clamps to the group
    with pytest.raises(ValueError):
        tile_sizes(0, 1)


def test_choose_tile_monolithic_under_ample_budget():
    # an explicit ample budget, not detect(): tier-1 must not depend on
    # the host machine's LLC size
    plan = choose_tile(128 * 128, 16, _budget_for_frames(128 * 128, 16))
    assert plan.monolithic and plan.tile_k == 16 and plan.tiles == 1
    plan_u = choose_tile(10**8, 64, MemoryBudget.unlimited())
    assert plan_u.monolithic


def test_choose_tile_splits_oversized_groups():
    budget = _budget_for_frames(512 * 512, 3)
    plan = choose_tile(512 * 512, 16, budget)
    # cap 3 admits the even split 2x8 (2*2 > 3): no ragged tail
    assert plan.tile_k == 2 and plan.sizes() == [2] * 8
    # a prime group depth cannot split evenly above 1: take the cap
    plan_p = choose_tile(512 * 512, 17, budget)
    assert plan_p.tile_k == 3 and plan_p.sizes()[-1] == 2
    # one frame over budget degenerates to looped
    tiny = _budget_for_frames(512 * 512, 1)
    assert choose_tile(4 * 512 * 512, 8, tiny).tile_k == 1


def test_choose_tile_monotone_in_budget():
    prev = None
    for frames in (1, 2, 4, 8, 16):
        t = choose_tile(256 * 256, 16, _budget_for_frames(256 * 256,
                                                          frames)).tile_k
        if prev is not None:
            assert t >= prev
        prev = t
    assert prev == 16


# --- choose_blocks ------------------------------------------------------------


def test_choose_blocks_defaults_without_budget():
    for budget in (None, MemoryBudget.unlimited()):
        plan = choose_blocks(16, 512, 512, 512, budget)
        assert plan.key == (1, 128, 128, 128)


def test_choose_blocks_shrinks_to_fit_and_grows_bb():
    # a tight budget shrinks the cube below the MXU-preferred 128
    tight = MemoryBudget(64 * 64 * 4 * 8, source="manual", reserve=1.0)
    plan = choose_blocks(16, 512, 512, 512, tight)
    assert max(plan.bm, plan.bk, plan.bn) < 128
    assert plan.bb >= 1
    # an ample budget keeps the 128 cube and batches frames per grid step
    ample = MemoryBudget(16 * 1024 * 1024, source="manual", reserve=0.75)
    plan_a = choose_blocks(16, 512, 512, 512, ample)
    assert (plan_a.bm, plan_a.bk, plan_a.bn) == (128, 128, 128)
    assert plan_a.bb > 1 and 16 % plan_a.bb == 0
    # blocks always divide the dims they tile
    for batch, m in ((6, 96), (5, 40)):
        p = choose_blocks(batch, m, m, m, ample)
        assert batch % p.bb == 0 and m % p.bm == 0 \
            and m % p.bk == 0 and m % p.bn == 0


def test_batched_pallas_kernels_honor_bb():
    """bb > 1 (several frames per grid step sharing one factor-block load)
    must be bit-identical to bb = 1 — interpret mode executes the same
    kernel body TPU runs."""
    from repro.kernels.optical_dft import (
        dft_matrix_factors,
        dft_stage1_batched,
        dft_stage2_batched,
        optical_dft2_intensity_batched,
    )
    h = w = 16
    a = jax.random.uniform(jax.random.PRNGKey(3), (4, h, w))
    whr, whi = dft_matrix_factors(h)
    wwr, wwi = dft_matrix_factors(w)
    tr1, ti1 = dft_stage1_batched(whr, whi, a, dac_bits=8, bb=1)
    tr2, ti2 = dft_stage1_batched(whr, whi, a, dac_bits=8, bb=2)
    np.testing.assert_allclose(tr1, tr2, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ti1, ti2, rtol=1e-6, atol=1e-6)
    out1 = dft_stage2_batched(tr1, ti1, wwr, wwi, bb=1)
    out2 = dft_stage2_batched(tr1, ti1, wwr, wwi, bb=4)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)
    full1 = optical_dft2_intensity_batched(a, dac_bits=8, use_pallas=True,
                                           bb=1)
    full2 = optical_dft2_intensity_batched(a, dac_bits=8, use_pallas=True,
                                           bb=2)
    np.testing.assert_allclose(full1, full2, rtol=1e-6, atol=1e-6)


# --- the cost model's tile mode -----------------------------------------------


@pytest.mark.parametrize("spec,n_in,n_out", [
    (SPEC, 4096, 4096),
    (dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC), 512, 512),
])
def test_batched_step_cost_tile_mode(spec, n_in, n_out):
    mono = spec.batched_step_cost(n_in, n_out, batch=8, pipeline_depth=2)
    # tile_k >= batch (or None) is exactly the monolithic price
    same = spec.batched_step_cost(n_in, n_out, batch=8, pipeline_depth=2,
                                  tile_k=8)
    assert same.total_s == pytest.approx(mono.total_s, rel=1e-12)
    over = spec.batched_step_cost(n_in, n_out, batch=8, pipeline_depth=2,
                                  tile_k=99)
    assert over.total_s == pytest.approx(mono.total_s, rel=1e-12)
    # tiling pays per-tile prologues: the un-overlapped (depth-1) tiled
    # stream costs exactly the sum of its per-tile invocations
    tiled_serial = spec.batched_step_cost(n_in, n_out, batch=8, tile_k=3)
    per = [spec.batched_step_cost(n_in, n_out, batch=b) for b in (3, 3, 2)]
    assert tiled_serial.total_s == pytest.approx(
        sum(c.total_s for c in per), rel=1e-12)
    assert tiled_serial.conversion_s == pytest.approx(
        sum(c.conversion_s for c in per), rel=1e-12)
    # pipeline overlap across tiles strictly helps the tiled stream
    tiled_piped = spec.batched_step_cost(n_in, n_out, batch=8,
                                         pipeline_depth=2, tile_k=3)
    assert tiled_piped.total_s < tiled_serial.total_s
    # ...but each tile still pays its own handshake: tiled boundary >= mono
    assert tiled_serial.interface_s >= mono.interface_s
    with pytest.raises(ValueError):
        spec.batched_step_cost(n_in, n_out, batch=8, tile_k=0)


def test_batched_step_cost_mem_budget_duck_typing():
    """``mem_budget=`` must resolve the same tile depth ``choose_tile``
    picks under the same budget — one model, one resolution (divisor
    refinement included), two entry points."""
    n = 512 * 512
    budget = _budget_for_frames(n, 3)
    tile = choose_tile(n, 16, budget, pipeline_depth=2).tile_k
    assert tile == 2                 # the even split, NOT the raw cap of 3
    via_budget = SPEC.batched_step_cost(n, batch=16, pipeline_depth=2,
                                        mem_budget=budget)
    via_tile = SPEC.batched_step_cost(n, batch=16, pipeline_depth=2,
                                      tile_k=tile)
    assert via_budget.total_s == pytest.approx(via_tile.total_s, rel=1e-12)
    # ...and differs from pricing at the unrefined cap: the divisor split
    # dispatches more tiles, hence more prologues
    via_cap = SPEC.batched_step_cost(n, batch=16, pipeline_depth=2,
                                     tile_k=3)
    assert via_budget.total_s != pytest.approx(via_cap.total_s, rel=1e-12)
    # unlimited budget = monolithic
    mono = SPEC.batched_step_cost(n, batch=16, pipeline_depth=2)
    free = SPEC.batched_step_cost(n, batch=16, pipeline_depth=2,
                                  mem_budget=MemoryBudget.unlimited())
    assert free.total_s == pytest.approx(mono.total_s, rel=1e-12)


def test_batched_step_cost_tile_composes_with_sharding_and_hold():
    n = 4096
    # each tile scatters across the fleet and re-pays the sync barrier
    tiled_sharded = SPEC.batched_step_cost(n, batch=8, tile_k=4, n_devices=2)
    per_tile = SPEC.batched_step_cost(n, batch=4, n_devices=2)
    assert tiled_sharded.total_s == pytest.approx(2 * per_tile.total_s,
                                                 rel=1e-12)
    # hold is charged once to the whole stream, not once per tile
    held = SPEC.batched_step_cost(n, batch=8, tile_k=4, hold_s=0.25)
    base = SPEC.batched_step_cost(n, batch=8, tile_k=4)
    assert held.hold_s == 0.25
    assert held.total_s == pytest.approx(base.total_s + 0.25, rel=1e-12)


# --- executor: budget-driven dispatch -----------------------------------------


def test_executor_tiles_groups_against_the_budget():
    shape = (16, 12)
    budget = _budget_for_frames(16 * 12, 2)
    ex = OffloadExecutor(SPEC, max_batch=8, mem_budget=budget)
    imgs = _imgs(7, shape)
    hs = [ex.submit("fft", im) for im in imgs]
    ex.flush()
    st = ex.telemetry.stats[("fft", "optical-sim")]
    # 7 calls, cap 8, tile 2 -> stacks of 2,2,2,1
    assert st.invocations == 4 and st.calls == 7
    assert ex.telemetry.tile_sizes_observed("fft") == {1: 1, 2: 3}
    # each handle knows the invocation depth it actually shared
    assert sorted(h.batch for h in hs) == [1, 2, 2, 2, 2, 2, 2]
    # measured bytes/frame: f32 in + f32 out per sample
    assert ex.telemetry.bytes_per_frame("fft") == 2 * 16 * 12 * 4


def test_executor_tile_k_override_beats_budget():
    ex = OffloadExecutor(SPEC, max_batch=8, tile_k=3,
                         mem_budget=MemoryBudget.unlimited())
    imgs = _imgs(6, (8, 8))
    for h in [ex.submit("fft", im) for im in imgs]:
        pass
    ex.flush()
    assert ex.telemetry.tile_sizes_observed("fft") == {3: 2}
    # per-category pin wins over the global override
    ex2 = OffloadExecutor(SPEC, max_batch=8, tile_k=3,
                          mem_budget=MemoryBudget.unlimited())
    ex2.set_tile_k("fft", 2)
    for h in [ex2.submit("fft", im) for im in imgs]:
        pass
    ex2.flush()
    assert ex2.telemetry.tile_sizes_observed("fft") == {2: 3}
    with pytest.raises(ValueError):
        ex2.set_tile_k("fft", 0)
    with pytest.raises(ValueError):
        OffloadExecutor(SPEC, tile_k=0)


def test_resolve_tile_k_uses_matmul_output_size():
    """The working-set model must see the matmul's real result footprint
    (rows x weight cols), not assume n_out == n_in — otherwise the
    executor's tile drifts from the router's and the cost model's near
    the budget boundary."""
    import jax.numpy as jnp

    from repro.core.accelerator import ANDERSON_MVM

    mvm = dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC)
    x = jnp.ones((64, 64))                 # n_in = 4096
    w_small = jnp.ones((64, 4))            # n_out = 256
    w_big = jnp.ones((64, 1024))           # n_out = 65536
    # budget sized so the verdict flips on the output term alone
    budget = MemoryBudget(
        int(BYTES_F32 * (4096 + 4096) * _INTERMEDIATE_FACTOR) * 2 * 4,
        source="manual", reserve=1.0)
    ex = OffloadExecutor(mvm, max_batch=8, mem_budget=budget)
    small = ex.resolve_tile_k("matmul", x, 8, weights=w_small)
    big = ex.resolve_tile_k("matmul", x, 8, weights=w_big)
    assert small > big
    # and each matches choose_tile fed the same (n_in, n_out)
    assert small == choose_tile(4096, 8, budget, n_out=256).tile_k
    assert big == choose_tile(4096, 8, budget, n_out=65536).tile_k


def test_small_frames_never_tile_under_the_detected_budget():
    """The auto-detected budget must leave the classic small-frame regime
    untouched: one group, one invocation (the pre-tiling behavior every
    older test asserts on)."""
    ex = OffloadExecutor(SPEC, max_batch=16)
    assert ex.mem_budget.source in ("llc", "vmem")
    for h in [ex.submit("fft", im) for im in _imgs(16, (32, 32))]:
        pass
    ex.flush()
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 1


def test_warm_primes_tiled_dispatch_shapes():
    """warm() must resolve tile_k exactly as dispatch does, so the first
    tiled flush pays no stack-shape compile (the PR 3 sharded-warm bug,
    tiled edition)."""
    budget = _budget_for_frames(16 * 12, 3)
    ex = OffloadExecutor(SPEC, max_batch=8, mem_budget=budget)
    be = ex._backend("optical-sim")
    seen: list[tuple] = []
    orig = type(be).run

    def spy(self, category, xs, ctx, **kw):
        seen.append((len(xs),) + tuple(xs[0].shape))
        return orig(self, category, xs, ctx, **kw)

    type(be).run = spy
    try:
        (im,) = _imgs(1, (16, 12))
        ex.warm("fft", im, batch=8)
        warmed, seen[:] = set(seen), []
        assert not ex.telemetry.stats       # warm never records
        for h in [ex.submit("fft", x) for x in _imgs(8, (16, 12))]:
            h.get()
        flushed = set(seen)
    finally:
        type(be).run = orig
    # every tiled stack the flush dispatched was already warmed: cap 8 at
    # tile 2 (the even split under a 3-frame budget) -> (2, 16, 12) stacks
    assert flushed <= warmed, (flushed, warmed)
    assert (2, 16, 12) in warmed


def test_block_plan_cache_keys_by_stack_and_budget():
    """The resolved-block cache must never serve a plan shaped for a
    different stack depth or budget (the stale-compile satellite)."""
    ex = OffloadExecutor(SPEC, mem_budget=MemoryBudget.unlimited())
    p16 = ex.ctx.blocks_for(16, 512, 512)
    assert ex.ctx.blocks_for(16, 512, 512) is p16     # cached
    p4 = ex.ctx.blocks_for(4, 512, 512)               # new depth, new plan
    assert len(ex.ctx.block_cache) == 2
    assert p4.key[1:] == p16.key[1:]                  # same cube, no budget
    ex.ctx.mem_budget = MemoryBudget(64 * 64 * 4 * 8, source="manual",
                                     reserve=1.0)
    tight = ex.ctx.blocks_for(16, 512, 512)           # budget change: fresh
    assert len(ex.ctx.block_cache) == 3
    assert max(tight.bm, tight.bk, tight.bn) < 128


# --- telemetry ----------------------------------------------------------------


def test_telemetry_tile_samples_and_bytes_merge_and_reset():
    t = RuntimeTelemetry()
    t.record("fft", "optical-sim", calls=4, samples_in=400, samples_out=400,
             wall_s=0.01, bytes_in=1600, bytes_out=1600)
    t.record("fft", "optical-sim", calls=2, samples_in=200, samples_out=200,
             wall_s=0.01, bytes_in=800, bytes_out=800)
    assert t.tile_sizes_observed("fft") == {2: 1, 4: 1}
    assert t.bytes_per_frame("fft") == (2400 + 2400) // 6
    other = RuntimeTelemetry()
    other.record("fft", "optical-sim", calls=4, samples_in=400,
                 samples_out=400, wall_s=0.01, bytes_in=1600, bytes_out=1600)
    t.merge(other)
    assert t.tile_sizes_observed("fft") == {2: 1, 4: 2}
    assert "tiles:" in t.summary()
    t.reset()
    assert t.tile_sizes_observed("fft") == {} and t.bytes_per_frame("fft") == 0


# --- router: the joint (max_batch, n_devices, tile_k) choice -------------------


def test_choose_sharding_picks_budget_tile_and_respects_operator_pin():
    budget = _budget_for_frames(16 * 16, 2)
    ex = OffloadExecutor(SPEC, default_backend="host", max_batch=16,
                         n_devices=4, mem_budget=budget)
    router = PlanRouter(ex, offload_backend="sharded")
    for im in _imgs(8, (16, 16)):
        router.run("fft", im)
    k, n, t = router.choose_sharding()["fft"]
    assert k == 16 and t == 2        # the budget's pick, not the batch
    router.replan()
    assert ex.category_tile_ks()["fft"] == t
    # an operator pin below the budget's choice is a bound the router keeps
    ex.set_tile_k("fft", 1)
    k2, n2, t2 = router.choose_sharding()["fft"]
    assert t2 == 1
    router.replan()
    assert ex.category_tile_ks()["fft"] == 1


def test_choose_sharding_tile_rides_the_deadline_batch():
    """When the deadline halves the batch, the tile follows it down
    (tile <= batch always)."""
    ex = OffloadExecutor(SPEC, default_backend="host", max_batch=16,
                         mem_budget=MemoryBudget.unlimited())
    router = PlanRouter(ex)
    for im in _imgs(8, (16, 16)):
        router.run("fft", im)
    loose_k, _, loose_t = router.choose_sharding()["fft"]
    assert loose_t == loose_k == 16  # unlimited budget: tile = batch
    tight_k, _, tight_t = router.choose_sharding(deadline_s=1e-9)["fft"]
    assert tight_k == 1 and tight_t == 1
