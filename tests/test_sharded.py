"""Multi-device sharded offload: the runtime-equivalence property harness.

The invariant this file locks down (the ISSUE's acceptance criterion):

    sharded execution  ==  single-device batched  ==  looped per-frame

on all three backends, for random shapes / batch sizes / device counts,
ragged tails included.  Group sharding must be numerically *tight* (the
per-frame computations are identical, only their grouping changes); frame
sharding is exact for digital inners and within converter-quantization
tolerance for the optical simulator (each aperture's detector legitimately
auto-exposes its own tile).

Runs under hypothesis when installed (nightly CI uses the ``nightly``
profile for more examples); falls back to a fixed example grid otherwise.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.distributed.sharding import shard_devices
from repro.runtime import (
    OffloadExecutor,
    PlanRouter,
    RuntimeTelemetry,
    ShardedOpticalBackend,
    get_backend,
    kernel_halo,
    shard_sizes,
)

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6,
    device_sync_s=1.0e-5)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)

SPEC = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)
MVM = dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC, device_sync_s=1.0e-6)

# inner backend -> its registered sharded wrapper
SHARDED_OF = {"host": "sharded-host", "optical-sim": "sharded",
              "ideal": "sharded-ideal"}


def _imgs(n, shape, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _kernel(shape):
    """Small-support kernel incl. wrap-around rows (negative circular
    offsets), so overlap-save needs halo on BOTH sides of a tile."""
    h, w = shape
    return (jnp.zeros(shape)
            .at[0, 0].set(0.5).at[1, 2 % w].set(0.25)
            .at[h - 1, 1 % w].set(0.15).at[2 % h, 0].set(0.1))


def _run(backend, category, imgs, spec, *, max_batch, n_devices=1,
         shard_mode="group", kernel=None, weights=None, tile_k=None):
    ex = OffloadExecutor(spec, max_batch=max_batch, n_devices=n_devices,
                         default_backend=backend, shard_mode=shard_mode,
                         tile_k=tile_k)
    kw = {}
    if kernel is not None:
        kw["kernel"] = kernel
    if weights is not None:
        kw["weights"] = weights
    hs = [ex.submit(category, im, **kw) for im in imgs]
    ex.flush()
    return hs, ex


# --- the runtime-equivalence invariant (tentpole acceptance) ------------------


def check_group_equivalence(backend, category, shape, calls, max_batch,
                            n_devices, tile_k=None):
    """tiled == sharded == single-device batched == looped, to float
    tolerance.  ``tile_k`` forces memory-budgeted tiled dispatch on the
    sharded executor (each sub-invocation scatters across the fleet), so
    the invariant covers tiling composed with sharding."""
    imgs = _imgs(calls, shape)
    kernel = _kernel(shape) if category == "conv" else None
    sharded, exs = _run(SHARDED_OF[backend], category, imgs, SPEC,
                        max_batch=max_batch, n_devices=n_devices,
                        kernel=kernel, tile_k=tile_k)
    batched, _ = _run(backend, category, imgs, SPEC, max_batch=max_batch,
                      kernel=kernel)
    looped, _ = _run(backend, category, imgs, SPEC, max_batch=1,
                     kernel=kernel)
    for hs, hb, hl in zip(sharded, batched, looped):
        np.testing.assert_allclose(hs.value, hb.value, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(hb.value, hl.value, rtol=1e-5, atol=1e-5)
    # every device that took a shard is visible in telemetry, and the
    # shards jointly carried exactly the submitted boundary traffic
    per_dev = exs.telemetry.device_samples(category)
    chunk = min(max_batch, calls)
    tile = chunk if tile_k is None else max(1, min(tile_k, chunk))
    n_eff = min(n_devices, tile)
    assert exs.telemetry.devices_observed(category) == n_eff
    assert sum(s for s, _ in per_dev.values()) == \
        sum(int(im.size) for im in imgs)
    if tile_k is not None:
        # every dispatched stack honored the tile ceiling
        assert max(exs.telemetry.tile_sizes_observed(category)) <= tile


GROUP_CASES = [
    # (backend, category, shape, calls, max_batch, n_devices, tile_k) —
    # ragged tails (calls % max_batch != 0), shards (chunk % n_devices
    # != 0), and tile tails (chunk % tile_k != 0) throughout; tile_k=None
    # resolves from the (ample) budget = monolithic chunks.
    ("host", "fft", (16, 12), 5, 3, 2, None),
    ("host", "conv", (16, 12), 7, 4, 4, None),
    ("optical-sim", "fft", (16, 12), 7, 4, 4, None),
    ("optical-sim", "fft", (12, 8), 6, 6, 1, None),
    ("optical-sim", "conv", (16, 12), 5, 5, 2, None),
    ("optical-sim", "conv", (8, 8), 3, 3, 4, None),  # fewer items than devices
    ("ideal", "fft", (16, 12), 4, 2, 2, None),
    ("ideal", "conv", (16, 12), 6, 4, 4, None),
    # tiled: ragged tile tails, tile_k=1 (looped), tile_k>=K (monolithic),
    # and tiled+sharded combined (each tile scatters across the fleet)
    ("host", "fft", (16, 12), 7, 7, 1, 3),
    ("optical-sim", "fft", (16, 12), 7, 7, 1, 3),
    ("optical-sim", "fft", (12, 8), 5, 5, 1, 1),
    ("optical-sim", "fft", (12, 8), 5, 5, 1, 8),
    ("optical-sim", "conv", (16, 12), 6, 6, 2, 4),
    ("ideal", "conv", (12, 8), 7, 4, 2, 2),
]

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(deadline=None)
    @given(backend=st.sampled_from(["host", "optical-sim", "ideal"]),
           category=st.sampled_from(["fft", "conv"]),
           h=st.integers(min_value=4, max_value=20),
           w=st.integers(min_value=4, max_value=20),
           calls=st.integers(min_value=1, max_value=8),
           max_batch=st.integers(min_value=1, max_value=5),
           n_devices=st.sampled_from([1, 2, 4]),
           tile_k=st.one_of(st.none(), st.integers(min_value=1, max_value=6)))
    def test_group_sharded_equivalence_property(backend, category, h, w,
                                                calls, max_batch, n_devices,
                                                tile_k):
        check_group_equivalence(backend, category, (h, w), calls, max_batch,
                                n_devices, tile_k)


@pytest.mark.parametrize(
    "backend,category,shape,calls,max_batch,n_devices,tile_k", GROUP_CASES)
def test_group_sharded_equivalence_fixed(backend, category, shape, calls,
                                         max_batch, n_devices, tile_k):
    """Tier-1 anchor grid (the hypothesis sweep above is nightly/slow)."""
    check_group_equivalence(backend, category, shape, calls, max_batch,
                            n_devices, tile_k)


@pytest.mark.parametrize("backend", ["host", "optical-sim"])
@pytest.mark.parametrize("mode", ["group", "frame"])
def test_sharded_matmul_equivalence(backend, mode):
    key = jax.random.PRNGKey(5)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (12, 16))
          for i in range(5)]
    w = jax.random.normal(jax.random.fold_in(key, 99), (16, 8))
    sharded, _ = _run(SHARDED_OF[backend], "matmul", xs, MVM, max_batch=5,
                      n_devices=3, shard_mode=mode, weights=w)
    batched, _ = _run(backend, "matmul", xs, MVM, max_batch=5, weights=w)
    looped, _ = _run(backend, "matmul", xs, MVM, max_batch=1, weights=w)
    for hs, hb, hl in zip(sharded, batched, looped):
        if mode == "frame" and backend == "optical-sim":
            # row tiles DAC-range per tile (each engine auto-ranges its
            # own activations): quantization-level differences, not bugs
            rel = float(jnp.linalg.norm(hs.value - hb.value)
                        / jnp.maximum(jnp.linalg.norm(hb.value), 1e-9))
            assert rel < 0.05, rel
        else:
            np.testing.assert_allclose(hs.value, hb.value, rtol=1e-5,
                                       atol=1e-5)
        np.testing.assert_allclose(hb.value, hl.value, rtol=1e-5, atol=1e-5)


# --- frame sharding (overlap-save tiling) -------------------------------------


def check_frame_conv(backend, shape, calls, n_devices):
    imgs = _imgs(calls, shape)
    kernel = _kernel(shape)
    sharded, ex = _run(SHARDED_OF[backend], "conv", imgs, SPEC,
                       max_batch=calls, n_devices=n_devices,
                       shard_mode="frame", kernel=kernel)
    unsharded, _ = _run(backend, "conv", imgs, SPEC, max_batch=calls,
                        kernel=kernel)
    for hs, hb in zip(sharded, unsharded):
        if backend == "optical-sim":
            # per-tile detector auto-exposure: quantization tolerance
            rel = float(jnp.linalg.norm(hs.value - hb.value)
                        / jnp.maximum(jnp.linalg.norm(hb.value), 1e-9))
            assert rel < 0.02, rel
        else:
            np.testing.assert_allclose(hs.value, hb.value, rtol=1e-4,
                                       atol=1e-5)
    n_eff = min(n_devices, shape[0])
    assert ex.telemetry.devices_observed("conv") == n_eff
    # halo rows are extra boundary traffic each device genuinely pays
    halo = sum(kernel_halo(kernel))
    s_in = sum(s for s, _ in ex.telemetry.device_samples("conv").values())
    assert s_in == calls * (shape[0] + n_eff * halo) * shape[1]


FRAME_CASES = [
    ("host", (16, 12), 2, 2),
    ("host", (17, 8), 1, 4),        # rows don't divide the device count
    ("ideal", (16, 12), 2, 3),
    ("optical-sim", (16, 12), 2, 2),
    ("optical-sim", (20, 8), 1, 4),
]

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(deadline=None)
    @given(backend=st.sampled_from(["host", "ideal", "optical-sim"]),
           h=st.integers(min_value=6, max_value=24),
           w=st.integers(min_value=4, max_value=16),
           calls=st.integers(min_value=1, max_value=3),
           n_devices=st.sampled_from([2, 3, 4]))
    def test_frame_sharded_conv_property(backend, h, w, calls, n_devices):
        check_frame_conv(backend, (h, w), calls, n_devices)


@pytest.mark.parametrize("backend,shape,calls,n_devices", FRAME_CASES)
def test_frame_sharded_conv_fixed(backend, shape, calls, n_devices):
    check_frame_conv(backend, shape, calls, n_devices)


def test_auto_mode_frame_shards_only_oversized_frames():
    """auto: deep groups scatter whole frames; a frame bigger than one
    aperture tiles; a shallow group of SMALL frames group-shards over
    fewer devices instead of trading tight numerics for fan-out (a ragged
    tail chunk must not silently flip to frame mode mid-flush)."""
    # 8x8 aperture: a 16x12 frame cannot fit one device -> tiling pays
    tiny = dataclasses.replace(SPEC, slm_pixels=(8, 8))
    ex = OffloadExecutor(tiny, max_batch=8, n_devices=4,
                         default_backend="sharded")  # shard_mode="auto"
    k = _kernel((16, 12))
    (im,) = _imgs(1, (16, 12))
    ex.submit("conv", im, kernel=k)
    ex.flush()
    # frame sharding: 4 devices saw row tiles of the single frame
    assert ex.telemetry.devices_observed("conv") == 4
    per_dev = ex.telemetry.device_samples("conv")
    assert all(s_out == 4 * 12 for _, s_out in per_dev.values())
    # the same lone frame on a roomy aperture stays whole (group over 1)
    ex2 = OffloadExecutor(SPEC, max_batch=8, n_devices=4,
                          default_backend="sharded")
    ex2.submit("conv", im, kernel=k)
    ex2.flush()
    assert ex2.telemetry.devices_observed("conv") == 1
    per_dev2 = ex2.telemetry.device_samples("conv")
    assert all(s_in == 16 * 12 for s_in, _ in per_dev2.values())  # no halo
    # fft never frame-shards (the DFT is global), even when oversized
    ex3 = OffloadExecutor(tiny, max_batch=8, n_devices=4,
                          default_backend="sharded")
    ex3.submit("fft", im)
    ex3.flush()
    assert ex3.telemetry.devices_observed("fft") == 1


# --- pricing: max-over-devices + sync epsilon ---------------------------------


def test_sharded_cost_matches_spec_n_devices_pricing():
    """The executed sharded invocation must be priced exactly as the cost
    model's n_devices mode (max-over-devices + per-device sync) — also
    when the group is shallower than the fleet (only the participating
    devices' sync barriers are charged, on both paths)."""
    for calls, counts in ((7, (1, 2, 4)), (3, (4,))):
        imgs = _imgs(calls, (16, 12))
        for n in counts:
            hs, _ = _run("sharded", "fft", imgs, SPEC, max_batch=8,
                         n_devices=n)
            want = SPEC.batched_step_cost(16 * 12, batch=calls,
                                          pipeline_depth=2, n_devices=n)
            got = hs[0].cost.total_s * len(imgs)
            assert got == pytest.approx(want.total_s, rel=1e-9)


def test_batched_step_cost_n_devices_semantics():
    n = 4096
    base = LANED_4F.batched_step_cost(n, batch=8, pipeline_depth=2)
    sharded = LANED_4F.batched_step_cost(n, batch=8, pipeline_depth=2,
                                         n_devices=4)
    per_shard = LANED_4F.batched_step_cost(n, batch=2, pipeline_depth=2)
    # max-over-devices: the largest (ceil) shard's cost plus the sync term
    assert sharded.total_s == pytest.approx(
        per_shard.total_s + 4 * LANED_4F.device_sync_s)
    assert sharded.conversion_s == pytest.approx(per_shard.conversion_s)
    # parallel crossings beat one serial deep crossing on a
    # streaming-dominated spec ...
    assert sharded.total_s < base.total_s
    # ... but each device still pays its own handshake: the per-call
    # boundary (conversion+interface) amortizes WORSE than single-device
    assert (sharded.conversion_s + sharded.interface_s) > \
        (base.conversion_s + base.interface_s) / 4
    # n_devices=1 is exactly the old pricing (no sync term)
    one = LANED_4F.batched_step_cost(n, batch=8, pipeline_depth=2,
                                     n_devices=1)
    assert one.total_s == base.total_s
    # a group shallower than the fleet occupies (and syncs) only batch
    # devices — matching the runtime's shard_sizes split
    shallow = LANED_4F.batched_step_cost(n, batch=3, n_devices=4)
    single = LANED_4F.batched_step_cost(n, batch=1)
    assert shallow.total_s == pytest.approx(
        single.total_s + 3 * LANED_4F.device_sync_s)
    with pytest.raises(ValueError):
        LANED_4F.batched_step_cost(n, batch=8, n_devices=0)
    # the MVM engine prices sharded streaming the same way
    m = ANDERSON_MVM
    m_sync = dataclasses.replace(m, device_sync_s=1e-6)
    assert m_sync.batched_step_cost(512, 512, batch=8, n_devices=2).total_s \
        == pytest.approx(m_sync.batched_step_cost(512, 512, batch=4).total_s
                         + 2e-6)


def test_shard_sizes_and_halo_helpers():
    assert shard_sizes(7, 4) == [2, 2, 2, 1]       # max == ceil(7/4)
    assert shard_sizes(3, 8) == [1, 1, 1]          # never more shards than items
    assert shard_sizes(8, 1) == [8]
    for total, n in ((1, 1), (5, 2), (16, 5), (9, 9)):
        sizes = shard_sizes(total, n)
        assert sum(sizes) == total
        assert max(sizes) - min(sizes) <= 1
    k = jnp.zeros((16, 8)).at[0, 0].set(1.0).at[2, 1].set(0.5)
    assert kernel_halo(k) == (2, 0)
    k_wrap = k.at[15, 0].set(0.25)                 # row -1 in circular terms
    assert kernel_halo(k_wrap) == (2, 1)
    assert kernel_halo(jnp.zeros((8, 8))) == (0, 0)


def test_shard_devices_sequential_fallback_on_one_device():
    # the CPU test environment has a single device: the dispatch helper
    # must hand back None (sequential fallback), never a short list
    assert shard_devices(1) is None
    if len(jax.devices()) < 4:
        assert shard_devices(4) is None


def test_sharded_backend_registry_and_supports():
    be = get_backend("sharded")
    assert isinstance(be, ShardedOpticalBackend)
    assert be.name == "sharded" and be.inner_name == "optical-sim"
    assert get_backend("sharded-host").name == "sharded-host"
    ex = OffloadExecutor(SPEC, n_devices=2, default_backend="sharded")
    with pytest.raises(ValueError):  # Fourier spec cannot serve matmul
        ex.submit("matmul", jnp.ones((8, 8)), weights=jnp.ones((8, 8)))
    with pytest.raises(ValueError):
        OffloadExecutor(SPEC, n_devices=0)
    with pytest.raises(ValueError):
        OffloadExecutor(SPEC, shard_mode="diagonal")


# --- warm() primes sharded dispatch shapes (satellite fix) --------------------


def test_warm_primes_sharded_dispatch_shapes():
    """The first sharded flush must not compile new shard-stack shapes:
    warm() must resolve the per-category device count exactly as dispatch
    does, so the per-device shard stacks it runs are the ones flush runs."""
    ex = OffloadExecutor(SPEC, max_batch=6, n_devices=4,
                         default_backend="sharded")
    ex.set_n_devices("fft", 3)  # operator fan-out != the global default
    be = ex._backend("sharded")
    seen: list[tuple] = []
    inner = be.inner
    orig = inner.run

    def spy(category, xs, ctx, **kw):
        seen.append((len(xs),) + tuple(xs[0].shape))
        return orig(category, xs, ctx, **kw)

    inner.run = spy
    try:
        (im,) = _imgs(1, (16, 12))
        ex.warm("fft", im, batch=6)
        warmed, seen[:] = set(seen), []
        assert not ex.telemetry.stats  # warm never records
        for h in [ex.submit("fft", x) for x in _imgs(6, (16, 12))]:
            h.get()
        flushed = set(seen)
    finally:
        inner.run = orig
    # every shard stack the flush dispatched was already warmed: 3 devices
    # over a 6-deep group -> (2, 16, 12) shards, plus the single-item path
    assert flushed <= warmed, (flushed, warmed)
    assert (2, 16, 12) in warmed


# --- telemetry: per-device aggregation ----------------------------------------


def test_telemetry_aggregates_and_merges_per_device_samples():
    t = RuntimeTelemetry()
    t.record("fft", "sharded", calls=4, samples_in=400, samples_out=400,
             wall_s=0.01, per_device=[(200, 200), (200, 200)])
    t.record("fft", "sharded", calls=2, samples_in=200, samples_out=200,
             wall_s=0.01, per_device=[(100, 100), (100, 100)])
    assert t.device_samples("fft") == {0: (300, 300), 1: (300, 300)}
    assert t.devices_observed("fft") == 2
    assert t.devices_observed("conv") == 1
    other = RuntimeTelemetry()
    other.record("fft", "sharded", calls=1, samples_in=50, samples_out=50,
                 wall_s=0.001, per_device=[(25, 25), (20, 20), (5, 5)])
    t.merge(other)
    assert t.devices_observed("fft") == 3
    assert t.device_samples("fft")[2] == (5, 5)
    assert "devices[3]" in t.summary()
    t.reset()
    assert t.device_samples("fft") == {} and t.devices_observed() == 1


def test_sharded_host_wall_counts_as_host_time():
    """Profiles must treat sharded-over-digital wall as honest host time."""
    t = RuntimeTelemetry()
    t.record("fft", "sharded-host", calls=4, samples_in=40, samples_out=40,
             wall_s=0.04)
    assert t.host_timed("fft")
    (prof,) = t.profiles(include_other=False)
    assert prof.host_s == pytest.approx(0.04)


# --- PlanRouter: devices chosen alongside max_batch (satellite property) ------


def _routed_executor(n_devices=4, max_batch=16):
    ex = OffloadExecutor(SPEC, default_backend="host", max_batch=max_batch,
                         n_devices=n_devices)
    router = PlanRouter(ex, offload_backend="sharded")
    for im in _imgs(8, (16, 16)):
        router.run("fft", im)
    return ex, router


def check_replan_sharding(batch_cap, dev_cap, deadlines):
    """Chosen (max_batch, n_devices, tile_k) never violate operator
    ceilings; batch and devices are monotone non-increasing as the
    deadline tightens, and the tile depth never exceeds the batch."""
    ex, router = _routed_executor()
    if batch_cap is not None:
        ex.set_max_batch("fft", batch_cap)
    if dev_cap is not None:
        ex.set_n_devices("fft", dev_cap)
    prev_k = prev_n = None
    # loosest first: no deadline, then deadlines tightening monotonically
    order = [None] + sorted(deadlines, reverse=True)
    for deadline in order:
        k, n, t = router.choose_sharding(deadline_s=deadline)["fft"]
        assert 1 <= k <= min(16, batch_cap or 16)
        assert 1 <= n <= min(4, dev_cap or 4, k)
        assert 1 <= t <= k
        if prev_k is not None:
            assert k <= prev_k and n <= prev_n
        prev_k, prev_n = k, n
        router.replan(deadline_s=deadline)  # applying must respect the caps
        assert ex.max_batch_for("fft") == k
        assert ex.n_devices_for("fft") == n
        assert ex.category_tile_ks()["fft"] == t


REPLAN_CASES = [
    (None, None, [1e-1, 1e-2, 1e-3, 1e-4]),
    (8, 2, [5e-2, 5e-3, 5e-4]),
    (4, None, [1e-2, 1e-3]),
    (None, 1, [1e-2, 2e-4]),
]

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(deadline=None)
    @given(batch_cap=st.one_of(st.none(), st.integers(1, 16)),
           dev_cap=st.one_of(st.none(), st.integers(1, 4)),
           deadlines=st.lists(
               st.floats(min_value=1e-5, max_value=1.0), min_size=1,
               max_size=5))
    def test_replan_sharding_property(batch_cap, dev_cap, deadlines):
        check_replan_sharding(batch_cap, dev_cap, deadlines)


@pytest.mark.parametrize("batch_cap,dev_cap,deadlines", REPLAN_CASES)
def test_replan_sharding_fixed(batch_cap, dev_cap, deadlines):
    check_replan_sharding(batch_cap, dev_cap, deadlines)


def test_replan_restores_operator_device_bound_after_deadline():
    """A deadline-lowered device fan-out must snap back to the operator's
    bound (not the global cap) when the deadline relaxes."""
    ex, router = _routed_executor(n_devices=4, max_batch=16)
    ex.set_n_devices("fft", 2)  # operator bound below the global 4
    router.replan()
    assert ex.n_devices_for("fft") == 2
    # deadline so tight the batch collapses to 1 -> 1 device
    router.replan(deadline_s=1e-9)
    assert ex.max_batch_for("fft") == 1
    assert ex.n_devices_for("fft") == 1
    router.replan()  # relaxed: back to the operator's 2, not the global 4
    assert ex.n_devices_for("fft") == 2
    assert ex.max_batch_for("fft") == 16


# --- real multi-device dispatch (forced host devices, subprocess) -------------

_MULTI_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.runtime import OffloadExecutor
from repro.distributed.sharding import shard_devices

assert len(jax.devices()) == 4
assert shard_devices(4) is not None and len(shard_devices(4)) == 4

key = jax.random.PRNGKey(0)
imgs = [jax.random.uniform(jax.random.fold_in(key, i), (16, 12))
        for i in range(8)]
kern = (jnp.zeros((16, 12)).at[0, 0].set(0.5).at[1, 2].set(0.25)
        .at[15, 1].set(0.15))


def run(backend, category, xs, n_devices, shard_mode, **kw):
    ex = OffloadExecutor(max_batch=8, n_devices=n_devices,
                         default_backend=backend, shard_mode=shard_mode)
    hs = [ex.submit(category, x, **kw) for x in xs]
    ex.flush()
    return hs, ex


# group-sharded fft over the host inner: shards land on distinct devices
hs, ex = run("sharded-host", "fft", imgs, 4, "group")
ss, _ = run("host", "fft", imgs, 1, "auto")
for a, b in zip(hs, ss):
    np.testing.assert_allclose(np.asarray(a.value), np.asarray(b.value),
                               rtol=1e-5, atol=1e-6)
placements = {next(iter(h.value.devices())).id for h in hs}

# group-sharded OPTICAL conv: each device gets its own committed kernel
# copy, so the Fourier-mask cache must be device-aware (regression: a
# content-only cache key served device 0's mask to every shard)
ho, exo = run("sharded", "conv", imgs, 4, "group", kernel=kern)
so, _ = run("optical-sim", "conv", imgs, 1, "auto", kernel=kern)
for a, b in zip(ho, so):
    np.testing.assert_allclose(np.asarray(a.value), np.asarray(b.value),
                               rtol=1e-5, atol=1e-5)

# frame sharding on real devices: per-device tiles are committed to
# distinct devices and must be re-homed before reassembly (regression:
# jnp.concatenate over mixed-device operands raised)
for backend, single, tol in (("sharded-host", "host", 1e-5),
                             ("sharded", "optical-sim", None)):
    hf, _ = run(backend, "conv", imgs[:1], 4, "frame", kernel=kern)
    sf, _ = run(single, "conv", imgs[:1], 1, "auto", kernel=kern)
    got, want = np.asarray(hf[0].value), np.asarray(sf[0].value)
    if tol is not None:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=tol)
    else:  # per-tile detector auto-exposure: quantization tolerance
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        assert rel < 0.05, rel

out = {"devices_used": sorted(placements),
       "per_device": {str(k): v for k, v in
                      ex.telemetry.device_samples("fft").items()},
       "optical_group_devices": len(exo.telemetry.device_samples("conv"))}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_dispatch_scatters_across_forced_devices():
    """With real (forced host) devices present, shards land on distinct
    devices and results still match the single-device batched path — for
    group AND frame sharding, over digital and optical inners (the
    mixed-device mask-cache and tile-reassembly regressions)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert len(out["devices_used"]) == 4, out
    assert len(out["per_device"]) == 4
    assert out["optical_group_devices"] == 4


# --- per-engine windows + device-resident placements --------------------------


def test_warm_primes_per_engine_window_and_placed_shapes():
    """warm() must run the category at its per-engine window depth (the
    context's pipeline depth feeds the tile choice and the modeled price)
    and restore the context afterwards — and the shard stacks it warms
    must cover what a committed-placement flush dispatches: the placement
    regroups frames by the same ``shard_sizes`` split the re-scatter path
    uses, so warmed shapes ARE placed shapes by construction."""
    ex = OffloadExecutor(SPEC, max_batch=6, n_devices=3,
                         default_backend="sharded-host", residency=True)
    ex.set_pipeline_window("fft", 3)
    be = ex._backend("sharded-host")
    seen: list[tuple] = []
    depths: list[int] = []
    inner = be.inner
    orig = inner.run

    def spy(category, xs, ctx, **kw):
        seen.append((len(xs),) + tuple(xs[0].shape))
        depths.append(ctx.pipeline_depth)
        return orig(category, xs, ctx, **kw)

    inner.run = spy
    try:
        (im,) = _imgs(1, (16, 12))
        saved_depth = ex.ctx.pipeline_depth
        ex.warm("fft", im, batch=6)
        assert depths and all(d == 3 for d in depths)  # pinned window depth
        assert ex.ctx.pipeline_depth == saved_depth    # restored after warm
        warmed, seen[:] = set(seen), []
        for h in [ex.submit("fft", x) for x in _imgs(6, (16, 12))]:
            h.get()
        flushed = set(seen)
        flush_depths = list(depths[len(warmed):] or depths)
    finally:
        inner.run = orig
    assert flushed <= warmed, (flushed, warmed)
    assert ex.ctx.pipeline_depth == 3  # dispatch ran at the pinned window


def test_placement_not_committed_without_residency_or_off_mesh():
    """Placements are gated exactly like shard residency: no residency
    cache, or no real device mesh (the sequential off-mesh fallback),
    means no commit — dispatch stays on the legacy re-scatter path."""
    imgs = _imgs(6, (16, 12))
    ex = OffloadExecutor(SPEC, max_batch=6, n_devices=3,
                         default_backend="sharded-host")
    for h in [ex.submit("fft", x) for x in imgs]:
        h.get()
    assert not ex._backend("sharded-host")._placements
    # residency on, but a single-CPU mesh cannot host 3 shards: the
    # sequential fallback commits nothing (shard_devices returns None)
    ex_r = OffloadExecutor(SPEC, max_batch=6, n_devices=3,
                          default_backend="sharded-host", residency=True)
    for h in [ex_r.submit("fft", x) for x in imgs]:
        h.get()
    if shard_devices(3) is None:
        assert not ex_r._backend("sharded-host")._placements


_PLACEMENT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import time
import jax
import numpy as np
from repro.runtime import OffloadExecutor

assert len(jax.devices()) == 4

K = 16
key = jax.random.PRNGKey(3)
imgs = [jax.random.uniform(jax.random.fold_in(key, i), (16, 12))
        for i in range(K)]

# looped single-device host baseline: the equivalence anchor
base = OffloadExecutor(max_batch=1, default_backend="host")
want = [np.asarray(h.value) for h in
        ([base.submit("fft", im) for im in imgs], base.flush())[0]]


def check(handles):
    for h, w in zip(handles, want):
        np.testing.assert_array_equal(np.asarray(h.value), w)


def timed(ex, reps=3):
    best = float("inf")
    for _ in range(reps):
        hs = [ex.submit("fft", im) for im in imgs]
        t0 = time.perf_counter()
        ex.flush()
        best = min(best, time.perf_counter() - t0)
    return best, hs


# 1. commit + repeat-flush hits, bit-equal to the looped baseline
ex = OffloadExecutor(max_batch=K, n_devices=4,
                     default_backend="sharded-host", residency=True)
ex.warm("fft", imgs[0], batch=K)
hs = [ex.submit("fft", im) for im in imgs]
ex.flush()
check(hs)
be = ex._backend("sharded-host")
assert be._placements, "first flush must commit a placement"
pkey, pl = next(iter(be._placements.items()))
assert pl.pool == [0, 1, 2, 3] and pl.frames == K
hs = [ex.submit("fft", im) for im in imgs]
ex.flush()
check(hs)
hits = dict(ex.telemetry.residency_counts["fft"])
assert hits.get("hit", 0) >= K, hits   # repeat flush rode the placement

# 2. tiled dispatch routes tile sub-stacks through the SAME placement
ex.set_tile_k("fft", 5)
hs = [ex.submit("fft", im) for im in imgs]
ex.flush()
check(hs)
assert be._placements, "tiled flush must re-commit, not abandon, placement"

# 3. device loss mid-placed-dispatch: quarantine, drop, serve from survivor
ex.set_tile_k("fft", K)
ex.ctx.lost_devices = frozenset({1})
hs = [ex.submit("fft", im) for im in imgs]
ex.flush()
ex.ctx.lost_devices = frozenset()
check(hs)                               # every frame retired, bit-equal
assert ex.quarantine.is_quarantined(("device", 1), ex.now())
assert not be._placements, "fault must drop the placement"

# 4. next flush rebuilds on the survivors only
hs = [ex.submit("fft", im) for im in imgs]
ex.flush()
check(hs)
(_, pl2), = be._placements.items()
assert pl2.pool == [0, 2, 3], pl2.pool  # quarantined device excluded

# 5. CI-smoke mirror: resident repeat-flush wall <= re-scatter wall at K=16
rescatter = OffloadExecutor(max_batch=K, n_devices=4,
                            default_backend="sharded-host")
rescatter.warm("fft", imgs[0], batch=K)
wall_rescatter, hs = timed(rescatter)
check(hs)
resident = OffloadExecutor(max_batch=K, n_devices=4,
                           default_backend="sharded-host", residency=True)
resident.warm("fft", imgs[0], batch=K)
for im in imgs:
    resident.submit("fft", im)
resident.flush()                        # priming flush commits + stages
wall_resident, hs = timed(resident)
check(hs)

print("RESULT:" + json.dumps({
    "resident_wall_s": wall_resident,
    "rescatter_wall_s": wall_rescatter,
    "hit_rate": resident.telemetry.residency_hit_rate("fft"),
}))
"""


@pytest.mark.slow
def test_placement_lifecycle_on_forced_devices():
    """Commit -> repeat-flush hits -> tiled re-commit -> device-loss drop ->
    survivor rebuild, bit-equal to the looped host baseline throughout,
    and the resident repeat-flush wall beats the re-scatter wall (the CI
    multi-device smoke's assertion, runnable locally)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _PLACEMENT_SCRIPT],
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["resident_wall_s"] <= out["rescatter_wall_s"], out
    assert out["hit_rate"] > 0.5, out
