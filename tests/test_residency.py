"""Operand residency: the cache that stops paying the DAC for resident bytes.

The invariant this file extends (PR 5/6/7's equivalence property, one more
axis): ``cached == re-staged == looped`` — a flush served from the
residency cache retires bit-equal to one that re-staged every operand on
digital backends (the hit replays the same jitted computation on the same
staged array), and allclose on the optical sim — across plain, scheduler-
held, tiled, sharded, and chaos-wrapped dispatch.  The cost model must
*agree* with dispatch: a fully resident flush prices read-side-only
(``dac_s == 0``), and turning residency off reproduces the historical
prices bit for bit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.runtime import (
    Fault,
    ManualClock,
    MemoryBudget,
    OffloadExecutor,
    OffloadScheduler,
    ResidencyCache,
    ShardedOpticalBackend,
    operating_point,
    register_chaos,
    residency_key,
)

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6,
    device_sync_s=1.0e-5)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)

SPEC = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)


def _imgs(n, shape=(32, 32), seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _kernel(shape=(32, 32)):
    h, w = shape
    return (jnp.zeros(shape)
            .at[0, 0].set(0.5).at[1, 2 % w].set(0.25)
            .at[h - 1, 1 % w].set(0.15))


def _flush(ex, category, imgs, **kw):
    hs = [ex.submit(category, im, **kw) for im in imgs]
    ex.flush()
    return [np.asarray(h.value) for h in hs], [h.cost for h in hs]


# --- the equivalence invariant, extended -----------------------------------------

@pytest.mark.parametrize("backend", ["host", "optical-sim"])
def test_cached_equals_restaged_and_looped(backend):
    """A conv layer stack re-using its frames: the cached second flush
    retires bit-equal to the re-staged first flush AND to a residency-off
    executor; looped per-frame matches bit-equal on digital backends,
    allclose on the optical sim (batch-1 vs batch-K lowering)."""
    imgs, kernel = _imgs(6), _kernel()
    plain = OffloadExecutor(SPEC, max_batch=8, default_backend=backend)
    restaged, _ = _flush(plain, "conv", imgs, kernel=kernel)
    looped_ex = OffloadExecutor(SPEC, max_batch=1, default_backend=backend)
    looped, _ = _flush(looped_ex, "conv", imgs, kernel=kernel)

    ex = OffloadExecutor(SPEC, max_batch=8, default_backend=backend,
                         residency=True)
    first, _ = _flush(ex, "conv", imgs, kernel=kernel)
    cached, _ = _flush(ex, "conv", imgs, kernel=kernel)

    for c, f, r in zip(cached, first, restaged):
        np.testing.assert_array_equal(c, f)
        np.testing.assert_array_equal(c, r)
    for c, l in zip(cached, looped):
        if backend == "host":
            np.testing.assert_array_equal(c, l)
        else:
            np.testing.assert_allclose(c, l, rtol=1e-5)


def test_hit_miss_counters_and_hit_rate():
    imgs, kernel = _imgs(4), _kernel()
    ex = OffloadExecutor(SPEC, max_batch=8, residency=True)
    _flush(ex, "conv", imgs, kernel=kernel)     # frame stack + kernel miss
    _flush(ex, "conv", imgs, kernel=kernel)     # both hit
    counts = ex.residency.counts["conv"]
    assert counts["miss"] == 2 and counts["hit"] == 2
    assert ex.residency.hit_rate("conv") == 0.5
    # mirrored into telemetry: the router replans from this ledger
    assert ex.telemetry.residency_hit_rate("conv") == 0.5
    assert ex.telemetry.residency_counts["conv"]["hit"] == 2
    # the summaries surface the ledger
    assert "residency" in ex.residency.summary()
    assert "residency[conv]" in ex.telemetry.summary()


def test_hit_priced_read_side_only():
    """The acceptance criterion on the cost model: a fully resident flush
    pays no write-side DAC traffic but the full read side — the ADC still
    converts every output sample whether or not the input was resident."""
    imgs, kernel = _imgs(4), _kernel()
    ex = OffloadExecutor(SPEC, max_batch=8, residency=True)
    _, first = _flush(ex, "conv", imgs, kernel=kernel)
    _, second = _flush(ex, "conv", imgs, kernel=kernel)
    assert first[0].dac_s > 0.0
    assert second[0].dac_s == 0.0
    assert second[0].adc_s == first[0].adc_s
    assert second[0].analog_s == first[0].analog_s


def test_cost_model_agrees_with_dispatch():
    """The dispatched hit cost IS ``batched_step_cost(resident_frames=K)``
    — the model and the runtime price the same thing."""
    imgs = _imgs(4)
    n = imgs[0].size
    ex = OffloadExecutor(SPEC, max_batch=8, residency=True)
    _flush(ex, "fft", imgs)
    _, costs = _flush(ex, "fft", imgs)
    want = ex.spec.batched_step_cost(n, n, batch=len(imgs),
                                     pipeline_depth=ex.pipeline_depth,
                                     resident_frames=len(imgs))
    got = costs[0]   # per-call share of the invocation's modeled cost
    assert got.dac_s == want.dac_s == 0.0
    np.testing.assert_allclose(got.total_s, want.total_s / len(imgs),
                               rtol=1e-12)


def test_batched_step_cost_residency_params():
    """Defaults reproduce the historical prices bit for bit; resident
    frames are monotone savings; negatives are rejected."""
    for spec in (LANED_4F, ANDERSON_MVM):
        base = spec.batched_step_cost(4096, batch=8)
        again = spec.batched_step_cost(4096, batch=8, resident_frames=0,
                                       weight_samples=0, resident_weights=0)
        assert base == again
        prev = base.total_s
        for r in (2, 4, 8):
            c = spec.batched_step_cost(4096, batch=8, resident_frames=r)
            assert c.total_s <= prev
            prev = c.total_s
        full = spec.batched_step_cost(4096, batch=8, resident_frames=8)
        assert full.dac_s == 0.0
        assert full.adc_s == base.adc_s
        # a resident weight panel cancels exactly the weight write charge
        w = spec.batched_step_cost(4096, batch=8, weight_samples=512)
        wr = spec.batched_step_cost(4096, batch=8, weight_samples=512,
                                    resident_weights=512)
        assert w.dac_s > base.dac_s
        assert wr == base
        with pytest.raises(ValueError):
            spec.batched_step_cost(4096, batch=8, resident_frames=-1)
        with pytest.raises(ValueError):
            spec.batched_step_cost(4096, batch=8, weight_samples=-1)


def test_matmul_weight_panel_residency():
    """MVM serving: with residency on, the first flush prices the weight
    panel write honestly (``matmul_cost(weight_write=True)``); once the
    panel is resident the weight-stationary price returns — and a fully
    resident activation flush reads back for free on the write side."""
    acts = [jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(3), i),
                              (16, 24)) for i in range(4)]
    w = jax.random.normal(jax.random.PRNGKey(9), (24, 8))
    plain = OffloadExecutor(ANDERSON_MVM, max_batch=8)
    base, base_costs = _flush(plain, "matmul", acts, weights=w)
    ex = OffloadExecutor(ANDERSON_MVM, max_batch=8, residency=True)
    first, c1 = _flush(ex, "matmul", acts, weights=w)
    second, c2 = _flush(ex, "matmul", acts, weights=w)
    for g, r in zip(first + second, base + base):
        np.testing.assert_array_equal(g, r)
    assert c1[0].dac_s > base_costs[0].dac_s      # honest panel write
    assert c2[0].dac_s == 0.0                      # panel + acts resident
    assert ANDERSON_MVM.matmul_cost(16, 24, 8, weight_write=True).dac_s \
        > ANDERSON_MVM.matmul_cost(16, 24, 8).dac_s


# --- scheduler-held / tiled / sharded / chaos-wrapped dispatch -------------------

def test_scheduler_held_cached_equivalence():
    imgs, kernel = _imgs(5), _kernel()
    plain = OffloadExecutor(SPEC, max_batch=4)
    ref, _ = _flush(plain, "conv", imgs, kernel=kernel)
    clk = ManualClock()
    ex = OffloadExecutor(SPEC, max_batch=4, clock=clk, residency=True)
    with OffloadScheduler(ex, deadline_s=0.1, clock=clk) as sched:
        for rep in range(2):
            hs = []
            for im in imgs:
                clk.advance(0.01)
                sched.poll()
                hs.append(sched.submit("conv", im, kernel=kernel))
            clk.advance(0.5)
            sched.poll()
            ex.drain()
            for h, r in zip(hs, ref):
                np.testing.assert_array_equal(np.asarray(h.value), r)
    assert ex.residency.counts["conv"]["hit"] > 0


def test_tiled_cached_equivalence():
    """Budget-forced tiled dispatch: each tile's stack is its own resident
    entry, and the cached re-flush still streams tile by tile, bit-equal."""
    imgs = _imgs(6)
    budget = MemoryBudget(bytes_limit=3 * imgs[0].nbytes * 4, reserve=1.0)
    plain = OffloadExecutor(SPEC, max_batch=8, mem_budget=budget)
    ref, _ = _flush(plain, "fft", imgs)
    ex = OffloadExecutor(SPEC, max_batch=8, mem_budget=budget,
                         residency=True)
    for _rep in range(2):
        got, _ = _flush(ex, "fft", imgs)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g, r)
    assert ex.residency.counts["fft"]["hit"] > 0
    assert len(ex.residency) > 1          # one entry per tile, not one blob


def test_sharded_partial_residency_rescatter():
    """Half the frames change between flushes: the re-scatter ships only
    the missing half (hits AND misses both advance) and every frame still
    retires equal to a fresh re-staged baseline."""
    imgs, kernel = _imgs(6), _kernel()
    fresh = _imgs(3, seed=99) + imgs[3:]
    ex = OffloadExecutor(SPEC, max_batch=8, n_devices=3, residency=True)
    _flush(ex, "conv", imgs, kernel=kernel, backend="sharded-host")
    before = dict(ex.residency.counts["conv"])
    got, _ = _flush(ex, "conv", fresh, kernel=kernel, backend="sharded-host")
    after = ex.residency.counts["conv"]
    assert after["hit"] > before.get("hit", 0)     # unchanged shards served
    assert after["miss"] > before.get("miss", 0)   # changed shards re-shipped
    plain = OffloadExecutor(SPEC, max_batch=8, n_devices=3)
    ref, _ = _flush(plain, "conv", fresh, kernel=kernel,
                    backend="sharded-host")
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)


def test_chaos_wrapped_cached_equivalence():
    """A transient fault mid-stream neither corrupts nor bypasses the
    cache: the retried dispatch retires equal, and the re-flush hits."""
    imgs = _imgs(4)
    name = register_chaos("optical-sim", name="chaos-residency",
                          script={0: Fault("error")})
    clk = ManualClock()
    ex = OffloadExecutor(SPEC, default_backend=name, max_batch=4,
                         clock=clk, residency=True)
    first, _ = _flush(ex, "fft", imgs)
    second, _ = _flush(ex, "fft", imgs)
    plain = OffloadExecutor(SPEC, max_batch=4, clock=ManualClock())
    ref, _ = _flush(plain, "fft", imgs)
    for g, r in zip(first + second, ref + ref):
        np.testing.assert_array_equal(g, r)
    assert ex.telemetry.fault_counts["fft"]["error"] == 1
    assert ex.residency.counts["fft"]["hit"] > 0


# --- eviction, collisions, invalidation (the edge-case satellite) ----------------

def test_eviction_under_budget_pressure_mid_pipeline():
    """A capacity smaller than the working set evicts LRU entries while
    the pipeline keeps flushing — results stay correct, the ledger counts
    the evictions, and the cache never exceeds its capacity."""
    cache = ResidencyCache(capacity_bytes=2 * 32 * 32 * 4 * 4)
    ex = OffloadExecutor(SPEC, max_batch=4, residency=cache)
    plain = OffloadExecutor(SPEC, max_batch=4)
    for seed in range(4):                 # distinct groups: cache churns
        imgs = _imgs(4, seed=seed)
        got, _ = _flush(ex, "fft", imgs)
        ref, _ = _flush(plain, "fft", imgs)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g, r)
        assert cache.resident_bytes() <= cache.capacity_bytes
    assert cache.counts["fft"]["eviction"] > 0
    # the evicted groups re-stage (miss), the survivors still hit
    imgs = _imgs(4, seed=3)
    _flush(ex, "fft", imgs)
    assert cache.counts["fft"]["hit"] > 0


def test_oversized_operand_is_not_cached():
    cache = ResidencyCache(capacity_bytes=64)
    evicted = cache.store("host", ("k",), object(), 1024,
                          category="fft", kind="frame")
    assert evicted == [] and len(cache) == 0


def test_digest_collision_distinct_shapes_never_collide():
    """Equal bytes, different shapes: the shape is part of the digest, so
    a (4, 16) zeros block can never serve a (8, 8) zeros lookup."""
    ex = OffloadExecutor(SPEC, residency=True)
    a, b = jnp.zeros((4, 16)), jnp.zeros((8, 8))
    ka = residency_key(ex.ctx, [a], "frame")
    kb = residency_key(ex.ctx, [b], "frame")
    assert ka != kb
    cache = ex.residency
    cache.store("host", ka, a, int(a.nbytes), category="fft", kind="frame")
    assert cache.lookup("host", kb, category="fft") is None
    assert cache.lookup("host", ka, category="fft") is not None


def test_operating_point_change_invalidates_resident_operands():
    """Retuning a converter (ADC ENOB here) moves the quantization grid:
    operands staged under the old operating point must stop matching."""
    assert operating_point(LANED_4F) != operating_point(SPEC)
    cache = ResidencyCache()
    imgs, kernel = _imgs(4), _kernel()
    ex1 = OffloadExecutor(LANED_4F, max_batch=8, residency=cache)
    _flush(ex1, "conv", imgs, kernel=kernel)
    hits_before = cache.counts["conv"]["hit"]
    # same cache, same operands, retuned ADC: every lookup misses
    ex2 = OffloadExecutor(SPEC, max_batch=8, residency=cache)
    got, _ = _flush(ex2, "conv", imgs, kernel=kernel)
    assert cache.counts["conv"]["hit"] == hits_before
    assert cache.counts["conv"]["miss"] >= 4
    plain = OffloadExecutor(SPEC, max_batch=8)
    ref, _ = _flush(plain, "conv", imgs, kernel=kernel)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)


def test_invalidate_device_drops_only_that_device():
    cache = ResidencyCache()
    cache.store(("device", 0), ("a",), object(), 10, category="conv",
                kind="shard")
    cache.store(("device", 1), ("b",), object(), 20, category="conv",
                kind="shard")
    dropped = cache.invalidate_device(("device", 0))
    assert dropped == 10
    assert cache.resident_keys(("device", 0)) == []
    assert cache.resident_keys(("device", 1)) == [("b",)]
    assert cache.counts["conv"]["invalidation"] == 1


def test_quarantine_drops_device_resident_set():
    """The fault story meets the cache: quarantining a device drops its
    resident set — its bytes are not trustworthy after the fault, and
    re-admission must re-stage."""
    ex = OffloadExecutor(SPEC, max_batch=4, n_devices=2, residency=True,
                         clock=ManualClock())
    cache = ex.residency
    cache.store(("device", 1), ("stale",), object(), 10, category="conv",
                kind="shard")
    cache.store("host", ("fine",), object(), 10, category="conv",
                kind="frame")
    be = ShardedOpticalBackend(inner="host")
    be._quarantine_device(ex.ctx, 1, reason="error")
    assert cache.resident_keys(("device", 1)) == []
    assert cache.resident_keys("host") == [("fine",)]
    assert cache.counts["conv"]["invalidation"] == 1
    assert ex.quarantine.is_quarantined(("device", 1), ex.now())


# --- executor integration --------------------------------------------------------

def test_residency_opt_in_and_off_switch():
    ex_on = OffloadExecutor(SPEC, residency=True)
    assert isinstance(ex_on.residency, ResidencyCache)
    assert ex_on.ctx.residency is ex_on.residency
    for off in (None, False):
        ex_off = OffloadExecutor(SPEC, residency=off)
        assert ex_off.residency is None and ex_off.ctx.residency is None
        imgs = _imgs(2)
        got, _ = _flush(ex_off, "fft", imgs)
        ref, _ = _flush(OffloadExecutor(SPEC), "fft", imgs)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g, r)


def test_warm_does_not_pollute_residency():
    """Priming runs are not workload: warm() must neither populate the
    cache nor advance the hit/miss ledger the router replans from."""
    ex = OffloadExecutor(SPEC, max_batch=4, residency=True)
    ex.warm("fft", _imgs(1)[0])
    assert len(ex.residency) == 0
    assert not ex.residency.counts
    assert ex.telemetry.residency_hit_rate() is None


def test_submit_reuse_token_skips_rehashing():
    """submit(reuse=) promises content stability: after the first digest
    the token seeds the digest memo, so repeat submissions of the same
    live array hit without re-hashing; a token re-used with a different
    shape is re-digested and re-bound rather than trusted."""
    imgs = _imgs(3)
    ex = OffloadExecutor(SPEC, max_batch=4, residency=True)
    for _rep in range(2):
        for i, im in enumerate(imgs):
            ex.submit("fft", im, reuse=f"frame{i}")
        ex.flush()
    assert ex.residency.counts["fft"]["hit"] >= 1
    assert id(imgs[0]) in ex.ctx._digest_memo
    # token re-bound on a shape change, not trusted
    tall = jnp.zeros((64, 16))
    k1 = ex.residency.note_token("frame0", tall, ex.ctx)
    assert k1 == ex.ctx.content_key(tall)


def test_residency_shares_the_staging_budget_with_tiles():
    """Resident bytes shrink the budget tiles spend from: as the cache
    fills, ``effective_mem_budget`` drops and the resolved tile depth
    can only shrink."""
    img = _imgs(1, (64, 64))[0]
    budget = MemoryBudget(bytes_limit=64 * img.nbytes, reserve=1.0)
    ex = OffloadExecutor(SPEC, max_batch=16, mem_budget=budget,
                         residency=True)
    t_empty = ex.resolve_tile_k("fft", img, 16)
    assert t_empty > 1
    assert ex.effective_mem_budget().bytes_limit == budget.bytes_limit
    # capacity is half the budget's spendable bytes: pin 24 frames (fits)
    ex.residency.store("host", ("pinned",), object(), 24 * img.nbytes,
                       category="fft", kind="frame")
    assert ex.effective_mem_budget().bytes_limit < budget.bytes_limit
    t_full = ex.resolve_tile_k("fft", img, 16)
    assert t_full < t_empty
    # the floor: a cache bigger than the budget leaves 1 byte, never 0
    # (0 reads as unlimited) — tile_k degrades to 1, not to monolithic
    assert budget.minus(10**9).bytes_limit == 1
    assert MemoryBudget.unlimited().minus(10**9).is_unlimited
    assert budget.minus(0) is budget


def test_residency_capacity_derives_from_budget():
    budget = MemoryBudget(bytes_limit=1 << 20, reserve=1.0)
    cache = ResidencyCache(budget)
    assert cache.capacity_bytes == int(budget.spendable_bytes * 0.5)
    assert ResidencyCache().capacity_bytes == 64 * 1024 * 1024
    assert ResidencyCache(capacity_bytes=123).capacity_bytes == 123


def test_router_replan_weighs_residency():
    """The deadline-halving loop prices the measured hit rate in: the same
    observed traffic sustains a deeper batch when the cache is absorbing
    the write side."""
    from repro.runtime import PlanRouter

    def _router(hits):
        ex = OffloadExecutor(SPEC, max_batch=16)
        ex.telemetry.record("fft", "optical-sim", calls=16,
                            samples_in=16 * 4096, samples_out=16 * 4096,
                            wall_s=0.01)
        for _ in range(hits):
            ex.telemetry.note_residency("fft", "hit")
        return PlanRouter(ex), ex

    cost = lambda k, res: SPEC.batched_step_cost(
        4096, 4096, batch=k, pipeline_depth=2, n_devices=1, tile_k=k,
        resident_frames=res)
    # a deadline only the resident price meets at full depth
    deadline = (cost(16, 16).total_s + cost(16, 0).total_s) / 2
    hot, _ = _router(hits=8)      # hit rate 1.0
    cold, _ = _router(hits=0)     # no residency traffic: rate treated as 0
    k_hot = hot.choose_sharding(deadline)["fft"][0]
    k_cold = cold.choose_sharding(deadline)["fft"][0]
    assert k_hot == 16
    assert k_cold < 16
