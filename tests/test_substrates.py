"""Optimizers, data pipeline, checkpointing, compression, profiler."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # light fallback: run property tests on fixed examples
    class _FixedStrategy:
        def __init__(self, examples):
            self.examples = examples

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def integers(min_value=0, max_value=0):
            span = max_value - min_value
            return _FixedStrategy([min_value, max_value,
                                   min_value + span // 2,
                                   min_value + span // 7])

    def given(strategy):
        def deco(fn):
            import inspect
            arg = next(iter(inspect.signature(fn).parameters))
            return pytest.mark.parametrize(arg, strategy.examples)(fn)
        return deco

    def settings(**_kwargs):
        return lambda fn: fn

from repro.checkpoint import CheckpointManager
from repro.data import MarkovTask, SyntheticTask
from repro.optim import (adafactor, adamw, apply_updates, ef_compress,
                         ef_decompress, ef_init, warmup_cosine)
from repro.core.profiler import flops_by_category


# --- optimizers -----------------------------------------------------------------

def _quadratic_steps(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(steps):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        ups, state, _ = opt.update(g, state, params, jnp.asarray(i))
        params = apply_updates(params, ups)
    return float(jnp.sum((params["w"] - target) ** 2))


def test_adamw_converges_quadratic():
    assert _quadratic_steps(adamw(0.2, weight_decay=0.0)) < 1e-2


def test_adafactor_converges_quadratic():
    # momentum-free adafactor rings near the optimum; 0.5 from a start
    # error of 14.0 is converged for this check
    assert _quadratic_steps(adafactor(0.5), steps=200) < 0.5


def test_adafactor_state_is_factored():
    opt = adafactor(1e-3)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 4))}
    st_ = opt.init(params)
    assert st_["v"]["big"]["vr"].shape == (256,)
    assert st_["v"]["big"]["vc"].shape == (512,)
    assert st_["v"]["small"]["v"].shape == (4, 4)


def test_adamw_clips_global_norm():
    opt = adamw(1e-1, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 1e6)}
    ups, state, metrics = opt.update(g, state, params, jnp.asarray(0))
    assert float(metrics["grad_norm"]) > 1e5         # pre-clip norm reported
    assert np.all(np.isfinite(np.asarray(ups["w"])))


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                              total_steps=100)) for s in range(100)]
    assert lr[0] == 0.0
    assert max(lr) == pytest.approx(1.0, abs=1e-2)
    assert lr[99] < lr[50] < lr[10] + 1e-6


# --- error-feedback compression -----------------------------------------------------

@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_ef_compression_error_feedback_reduces_bias(seed):
    """With error feedback, the *accumulated* quantization error stays
    bounded (residual never grows), so long-run updates are unbiased."""
    key = jax.random.PRNGKey(seed % 2 ** 31)
    g = {"w": jax.random.normal(key, (64,))}
    res = ef_init(g)
    total_sent = jnp.zeros(64)
    for i in range(20):
        q, scale, res = ef_compress(g, res)
        total_sent = total_sent + ef_decompress(q, scale)["w"]
    # after n rounds of the SAME gradient, sum of sent ~= n*g (residual bounded)
    np.testing.assert_allclose(np.asarray(total_sent / 20),
                               np.asarray(g["w"]), atol=0.02)


def test_ef_compression_wire_dtype():
    g = {"w": jnp.linspace(-3, 3, 128)}
    q, scale, res = ef_compress(g, ef_init(g))
    assert q["w"].dtype == jnp.int8                    # 4x smaller than f32
    rec = ef_decompress(q, scale)["w"]
    assert float(jnp.max(jnp.abs(rec - g["w"]))) < 3.0 / 127 + 1e-6


# --- data pipeline -------------------------------------------------------------------

def test_synthetic_task_deterministic_resume():
    t = SyntheticTask(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    b1 = t.batch(41)
    b2 = t.batch(41)                      # same step -> identical batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(t.batch(42)["tokens"], b1["tokens"])


def test_markov_task_is_learnable_structure():
    t = MarkovTask(vocab_size=64, seq_len=32, global_batch=8, seed=0)
    b = t.batch(0)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # next-token is one of `branching` successors of current token
    nxt = t._transitions()
    tok = np.asarray(b["tokens"])
    lab = np.asarray(b["labels"])
    ok = [(lab[i, j] in nxt[tok[i, j]]) for i in range(8) for j in range(31)]
    assert all(ok)


# --- checkpointing -----------------------------------------------------------------------

def _tree(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": {"c": jnp.arange(5)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(2.5))
    step, restored = mgr.restore_latest(_tree(0.0))
    assert step == 7
    np.testing.assert_allclose(restored["a"], 2.5)
    np.testing.assert_array_equal(restored["b"]["c"], np.arange(5))


def test_checkpoint_corruption_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    # corrupt the newest checkpoint's first leaf
    leaf = os.path.join(str(tmp_path), "step_0000000002", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    step, restored = mgr.restore_latest(_tree(0.0))
    assert step == 1                                    # fell back past corruption
    np.testing.assert_allclose(restored["a"], 1.0)


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, _tree(5.0))
    mgr.wait()
    step, restored = mgr.restore_latest(_tree(0.0))
    assert step == 5 and float(restored["a"][0, 0]) == 5.0


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    with pytest.raises(Exception):
        mgr.restore(1, {"a": jnp.zeros((2, 2)), "b": {"c": jnp.arange(5)}})


# --- profiler -----------------------------------------------------------------------------

def test_flops_matmul_exact():
    f = lambda a, b: a @ b
    cats = flops_by_category(f, jnp.zeros((8, 16)), jnp.zeros((16, 32)))
    assert cats["matmul"] == pytest.approx(2 * 8 * 16 * 32)


def test_flops_scan_multiplier():
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x,
                            None, length=7)[0]
    cats = flops_by_category(f, jnp.zeros((16, 16)))
    assert cats["matmul"] == pytest.approx(7 * 2 * 16 ** 3)


def test_flops_fft_and_conv_categories():
    cats = flops_by_category(lambda x: jnp.fft.fft2(x), jnp.zeros((32, 32)))
    assert cats.get("fft", 0) > 0
    f = lambda x, k: jax.lax.conv_general_dilated(x, k, (1, 1), "SAME")
    cats = flops_by_category(f, jnp.zeros((1, 3, 8, 8)), jnp.zeros((4, 3, 3, 3)))
    assert cats.get("conv", 0) == pytest.approx(2 * 4 * 8 * 8 * 3 * 9)
