"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, dtype)


# --- optical DFT pipeline -------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128), (256, 128), (128, 384),
                                   (8, 128), (256, 256)])
@pytest.mark.parametrize("dac_bits", [0, 6, 8])
def test_optical_dft_intensity_sweep(shape, dac_bits):
    a = _rand(1, shape)
    got = ops.optical_dft2_intensity(a, dac_bits=dac_bits)
    want = ref.optical_dft2_intensity_ref(a, dac_bits=dac_bits)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * float(want.max()))


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (8, 256, 128)])
def test_dft_stage1_matches_ref(m, k, n):
    wr, wi = ops.dft_matrix_factors(k)
    wr = wr[:m] if m <= k else jnp.tile(wr, (m // k, 1))
    wi = wi[:m] if m <= k else jnp.tile(wi, (m // k, 1))
    a = _rand(2, (k, n))
    tr, ti = ops.dft_stage1(wr, wi, a, dac_bits=8)
    rr, ri = ref.dft_stage1_ref(wr, wi, a, dac_bits=8)
    np.testing.assert_allclose(tr, rr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ti, ri, rtol=1e-4, atol=1e-5)


def test_dft_stage2_matches_ref():
    tr, ti = _rand(3, (128, 256)), _rand(4, (128, 256))
    wr, wi = ops.dft_matrix_factors(256)
    wr, wi = wr[:128], wi[:128]
    got = ops.dft_stage2(tr, ti, wr, wi)
    want = ref.dft_stage2_ref(tr, ti, wr, wi)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batch,shape", [(1, (128, 128)), (3, (128, 256)),
                                         (5, (64, 64))])
def test_dft_batched_stages_match_looped_single_frame(batch, shape):
    """The batched Pallas kernels (batch on the leading grid axis) must
    reproduce the single-frame kernels frame by frame."""
    h, w = shape
    a = _rand(11, (batch, h, w))
    whr, whi = ops.dft_matrix_factors(h)
    wwr, wwi = ops.dft_matrix_factors(w)
    tr, ti = ops.dft_stage1_batched(whr, whi, a, dac_bits=8)
    for i in range(batch):
        tr1, ti1 = ops.dft_stage1(whr, whi, a[i], dac_bits=8)
        np.testing.assert_allclose(tr[i], tr1, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ti[i], ti1, rtol=1e-5, atol=1e-6)
    out = ops.dft_stage2_batched(tr, ti, wwr, wwi)
    for i in range(batch):
        one = ops.dft_stage2(tr[i], ti[i], wwr, wwi)
        np.testing.assert_allclose(out[i], one, rtol=1e-5,
                                   atol=1e-5 * float(one.max()))


@pytest.mark.parametrize("dac_bits", [0, 8])
def test_optical_dft_batched_pipeline_matches_oracle(dac_bits):
    a = _rand(12, (4, 128, 128))
    from repro.kernels.optical_dft import optical_dft2_intensity_batched
    for use_pallas in (True, False):  # Pallas grid path and XLA fused path
        got = optical_dft2_intensity_batched(a, dac_bits=dac_bits,
                                             use_pallas=use_pallas)
        for i in range(4):
            want = ref.optical_dft2_intensity_ref(a[i], dac_bits=dac_bits)
            np.testing.assert_allclose(got[i], want, rtol=2e-4,
                                       atol=2e-4 * float(want.max()))


def test_optical_dft_matches_physics_sim():
    """Kernel pipeline == the core physics model (amplitude encoding)."""
    from repro.core.optical import OpticalSimParams, optical_fft2_magnitude
    a = _rand(5, (128, 128))
    intensity = ops.optical_dft2_intensity(a, dac_bits=8)
    mag = optical_fft2_magnitude(a, OpticalSimParams(dac_bits=8, adc_bits=16))
    # the core sim additionally ADC-quantizes the intensity (16-bit,
    # auto-ranged to the DC peak), so compare at that quantizer's step size
    step = float(jnp.max(mag) ** 2) / (2 ** 16 - 1)
    np.testing.assert_allclose(intensity, mag ** 2, rtol=1e-3, atol=2 * step)


# --- converter boundary -----------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (64, 256), (256, 512), (16, 384)])
@pytest.mark.parametrize("bits", [(6, 8), (8, 8), (4, 12)])
def test_converter_boundary_sweep(shape, bits):
    dac, adc = bits
    x = _rand(6, shape)
    nz = jax.random.normal(jax.random.PRNGKey(7), shape)
    got = ops.converter_boundary(x, nz, dac_bits=dac, adc_bits=adc,
                                 noise_std=0.02)
    want = ref.converter_boundary_ref(x, nz, dac_bits=dac, adc_bits=adc,
                                      noise_std=0.02)
    # fp association order can flip round-to-nearest ties by one ADC step
    np.testing.assert_allclose(got, want, rtol=1e-6,
                               atol=1.5 / ((1 << adc) - 1))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_converter_boundary_dtypes(dtype):
    x = _rand(8, (32, 128)).astype(dtype)
    got = ops.converter_boundary(x, dac_bits=8, adc_bits=8)
    want = ref.converter_boundary_ref(x, dac_bits=8, adc_bits=8)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2, atol=1e-2)


# --- flash attention ----------------------------------------------------------------

@pytest.mark.parametrize("lq,lk,d", [(128, 128, 64), (256, 128, 32),
                                     (128, 256, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(lq, lk, d, causal, window):
    if causal and lq > lk:
        pytest.skip("causal alignment assumes lq <= lk")
    q = jax.random.normal(jax.random.PRNGKey(1), (4, lq, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (2, lk, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (2, lk, d))
    got = ops.local_flash_attention(q, k, v, causal=causal, window=window,
                                    kv_groups=2, block_q=64, block_k=64)
    want = ref.local_attention_ref(q, k, v, causal=causal, window=window,
                                   kv_groups=2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(5), (2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(6), (2, 128, 64), jnp.bfloat16)
    got = ops.local_flash_attention(q, k, v, causal=True)
    want = ref.local_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_gqa_4d_wrapper():
    q = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(8), (2, 2, 128, 32))
    v = jax.random.normal(jax.random.PRNGKey(9), (2, 2, 128, 32))
    got = ops.gqa_flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.local_attention_ref(
        q.reshape(16, 128, 32), k.reshape(4, 128, 32), v.reshape(4, 128, 32),
        causal=True, kv_groups=4).reshape(2, 8, 128, 32)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
