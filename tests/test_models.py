"""Per-arch smoke + decode-equivalence + MoE semantics (reduced configs)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, input_specs, SHAPES
from repro.models import LM, init_params, param_counts
from repro.models.params import param_pspecs


def _batch(cfg, b=2, s=16, labels=True, key=0):
    k = jax.random.PRNGKey(key)
    s_text = s - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    out = {"tokens": jax.random.randint(k, (b, s_text), 0, cfg.vocab_size)}
    if labels:
        out["labels"] = jax.random.randint(k, (b, s_text), 0, cfg.vocab_size)
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(k, (b, s // 2, cfg.d_model),
                                          cfg.activation_dtype)
    if cfg.frontend == "vision":
        out["patches"] = jax.random.normal(
            k, (b, cfg.frontend_tokens, cfg.d_model), cfg.activation_dtype)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    assert float(metrics["n_tokens"]) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_grads_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, _batch(cfg))
    finite = jax.tree_util.tree_map(
        lambda a: bool(jnp.all(jnp.isfinite(a))), g)
    assert all(jax.tree_util.tree_leaves(finite)), arch


@pytest.mark.parametrize("arch", ["qwen2-72b", "recurrentgemma-9b",
                                  "deepseek-v3-671b", "xlstm-125m",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode == one-shot prefill at the same length."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:   # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s + 3), 0, 100)
    extra = {k: v for k, v in _batch(cfg, b, s, labels=False).items()
             if k not in ("tokens",)}
    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=s + 8))
    cache, _ = prefill(params, dict(tokens=toks[:, :s], **extra))
    step = jax.jit(model.decode_step)
    for i in range(3):
        lg, cache = step(params, cache, toks[:, s + i][:, None])
    _, lg_full = prefill(params, dict(tokens=toks[:, :s + 3], **extra))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               rtol=5e-2, atol=5e-2)


def test_param_counts_match_published():
    expected = {
        "qwen2-72b": 72.7e9, "qwen2.5-32b": 32.8e9, "nemotron-4-340b": 341e9,
        "deepseek-v3-671b": 671e9, "qwen2-moe-a2.7b": 14.3e9,
        "llava-next-34b": 34.5e9, "stablelm-1.6b": 1.6e9,
    }
    for arch, want in expected.items():
        total, _ = param_counts(get_config(arch))
        assert abs(total - want) / want < 0.05, (arch, total, want)


def test_moe_active_params():
    total, active = param_counts(get_config("deepseek-v3-671b"))
    assert 35e9 < active < 40e9              # paper: 37B activated
    total, active = param_counts(get_config("qwen2-moe-a2.7b"))
    assert 2.0e9 < active < 3.5e9            # model card: 2.7B activated


def test_moe_capacity_drops_are_bounded():
    """Dropped tokens fall through the residual: output stays finite and
    close to the no-drop output in norm."""
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    loose = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(loose, jax.random.PRNGKey(0))
    bt = _batch(loose, 2, 16)
    l_tight, _ = jax.jit(LM(tight).loss)(params, bt)
    l_loose, _ = jax.jit(LM(loose).loss)(params, bt)
    assert np.isfinite(float(l_tight)) and np.isfinite(float(l_loose))
    assert abs(float(l_tight) - float(l_loose)) < 1.0


def test_long_window_ring_cache():
    """Windowed decode far past the window: ring buffer stays O(window)."""
    cfg = get_smoke_config("recurrentgemma-9b")   # window 8
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    b, s = 1, 24                                   # 3x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 4), 0, 100)
    cache, _ = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=s + 8))(
        params, {"tokens": toks[:, :s]})
    # attn caches must be window-sized, not seq-sized
    k_shapes = [v.shape for pth, v in
                jax.tree_util.tree_flatten_with_path(cache)[0]
                if pth and getattr(pth[-1], "key", "") == "k"]
    assert all(sh[-2] == cfg.local_window for sh in k_shapes), k_shapes
    step = jax.jit(model.decode_step)
    for i in range(3):
        lg, cache = step(params, cache, toks[:, s + i][:, None])
    _, lg_full = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=s + 8))(
        params, {"tokens": toks[:, :s + 3]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               rtol=5e-2, atol=5e-2)


def test_vocab_padding_masked():
    """Padded vocab columns never win the argmax / contribute to CE."""
    cfg = get_smoke_config("stablelm-1.6b")       # vocab 500, padded to 512
    assert cfg.padded_vocab > cfg.vocab_size
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    cache, logits = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=32))(
        params, {"tokens": jnp.ones((2, 8), jnp.int32)})
    assert logits.shape[-1] == cfg.vocab_size      # sliced to true vocab


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_input_specs_are_abstract(shape_name):
    for arch in ("qwen2-72b", "seamless-m4t-large-v2", "llava-next-34b"):
        cfg = get_config(arch)
        specs = input_specs(cfg, shape_name)
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        sh = SHAPES[shape_name]
        if sh.kind != "decode" and not cfg.is_encdec \
                and cfg.frontend == "vision":
            total = specs["tokens"].shape[1] + specs["patches"].shape[1]
            assert total == sh.seq_len


def test_fsdp_pspecs_divisible():
    cfg = get_config("qwen2-72b")
    ps = param_pspecs(cfg, fsdp_size=16, tp_size=16)
    from repro.models.params import param_shape_structs
    sds = param_shape_structs(cfg)
    flat_ps = jax.tree_util.tree_leaves(
        ps, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_sd = jax.tree_util.tree_leaves(sds)
    for spec, leaf in zip(flat_ps, flat_sd):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax == "model":
                assert dim % 16 == 0, (leaf.shape, spec)
            if ax == "data":
                assert dim % 16 == 0, (leaf.shape, spec)
