"""End-to-end behaviour tests: training convergence, fault tolerance with
bit-exact recovery, serving-vs-offline equivalence, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import MarkovTask
from repro.distributed.fault import FaultTolerantRunner
from repro.launch.train import train_loop
from repro.models import LM, init_params
from repro.optim import adamw
from repro.serving import Request, ServingEngine
from repro.train import make_train_step


def test_training_reduces_loss(tmp_path):
    """~60 steps on a small Markov task must visibly reduce CE."""
    cfg = get_smoke_config("stablelm-1.6b")
    model = LM(cfg)
    task = MarkovTask(vocab_size=100, seq_len=32, global_batch=8, seed=2,
                      branching=4)
    opt = adamw(5e-3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(60):
        params, state, m = step(params, state, task.batch(i),
                                jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    # must at least collapse onto the used-vocab marginal (ln 500 -> ~ln 100)
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
    assert losses[-1] > task.entropy_floor_nats - 0.2  # can't beat the floor


def test_fault_recovery_bit_exact(tmp_path):
    """A crash mid-run + restore-from-checkpoint must reproduce the exact
    same final state as an uninterrupted run (step-keyed data pipeline +
    deterministic step function)."""
    cfg = get_smoke_config("stablelm-1.6b")
    model = LM(cfg)
    task = MarkovTask(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                      seed=5)
    opt = adamw(1e-3)
    step_jit = jax.jit(make_train_step(model, opt))

    def make_step_fn():
        def one(state, step):
            p, s = state
            b = task.batch(step)
            p, s, _ = step_jit(p, s, b, jnp.asarray(step, jnp.int32))
            return (p, s)
        return one

    def fresh_state():
        p = init_params(cfg, jax.random.PRNGKey(1))
        return (p, opt.init(p))

    # run A: uninterrupted
    mgr_a = CheckpointManager(str(tmp_path / "a"), keep=5)
    runner_a = FaultTolerantRunner(make_step_fn(), mgr_a, checkpoint_every=4)
    state_a, rep_a = runner_a.run(fresh_state(), 0, 12)
    assert rep_a.failures_recovered == 0

    # run B: crash at step 9 (after the step-8 checkpoint)
    mgr_b = CheckpointManager(str(tmp_path / "b"), keep=5)
    runner_b = FaultTolerantRunner(make_step_fn(), mgr_b, checkpoint_every=4)
    crashed = {"done": False}

    def fault(step):
        if step == 9 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected preemption")

    state_b, rep_b = runner_b.run(fresh_state(), 0, 12, fault_hook=fault)
    assert rep_b.failures_recovered == 1

    pa = jax.tree_util.tree_leaves(state_a[0])
    pb = jax.tree_util.tree_leaves(state_b[0])
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    """A persistently slow step is detected and triggers recovery."""
    import time
    mgr = CheckpointManager("/tmp/_straggler_ckpt_test", keep=1)
    calls = {"n": 0}

    def slow_after_6(state, step):
        calls["n"] += 1
        if step >= 6 and calls["n"] < 40:
            time.sleep(0.12)
        else:
            time.sleep(0.002)
        return state

    runner = FaultTolerantRunner(slow_after_6, mgr, checkpoint_every=100,
                                 straggler_factor=3.0, straggler_patience=3,
                                 max_restarts=50)
    _, report = runner.run({"x": 0}, 0, 12)
    assert report.stragglers_detected >= 3
    assert report.failures_recovered >= 1


def test_serving_matches_offline_greedy():
    """Engine continuous batching == offline prefill+greedy decode."""
    cfg = get_smoke_config("qwen2-72b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    prompts = [[5, 9, 2, 7], [11, 3, 8, 1]]
    new = 5

    # offline: one prompt at a time
    offline = []
    for pr in prompts:
        cache, logits = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=64))(
            params, {"tokens": jnp.asarray(pr, jnp.int32)[None]})
        toks = [int(jnp.argmax(logits[0]))]
        step = jax.jit(model.decode_step)
        for _ in range(new - 1):
            lg, cache = step(params, cache,
                             jnp.asarray([[toks[-1]]], jnp.int32))
            toks.append(int(jnp.argmax(lg[0])))
        offline.append(toks)

    engine = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    for rid, pr in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=pr, max_new_tokens=new))
    done = sorted(engine.run_to_completion(), key=lambda r: r.rid)
    assert [r.out_tokens for r in done] == offline


def test_elastic_checkpoint_restore_new_sharding(tmp_path):
    """A checkpoint restores under a different sharding layout."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(3, tree)
    from repro.distributed.compat import make_auto_mesh
    mesh = make_auto_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    step, restored = mgr.restore_latest(tree, shardings={"w": sh})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh
