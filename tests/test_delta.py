"""Delta-encoded DAC staging: pay only for the bits that flip.

The lever this file locks down (PR 10): a changed operand re-staged into a
known dispatch slot pays a *partial* write priced by its measured LSB-flip
fraction — strictly between a residency hit (free write side) and a full
re-stage — while retiring bit-equal to the re-staged path (classification
never touches the staged bytes, only their price).  Covered here:

  * the code-signature flip model (``repro.core.conversion``): exact XOR
    popcount when full codes are retained, per-plane independence upper
    bound otherwise, and the ``delta_write_scale`` floor of ``1/bits``
    (a re-assert still strobes one ladder slot — only a hit is free);
  * ``batched_step_cost(delta_fractions=...)`` on BOTH spec families:
    defaults bit-equal, hit <= delta <= full guaranteed, invalid
    fractions and overflowing frame accounting rejected;
  * the content-key memo aliasing fix (mutable buffers re-hash);
  * dispatch/model agreement: the delta-staged flush's cost IS
    ``batched_step_cost(resident_frames=R, delta_fractions=...)``;
  * placed re-stage donating the stale device buffer;
  * the router weighing the observed delta rate into deadline pricing.

Runs under hypothesis when installed (nightly CI uses the ``nightly``
profile); the tier-1 anchor grid runs always.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import (
    ConverterSpec,
    CodeSignature,
    code_signature,
    delta_write_scale,
    expected_flip_fraction,
    quantized_codes,
)
from repro.runtime import (
    DELTA_THRESHOLD,
    BackendContext,
    OffloadExecutor,
    PlanRouter,
    ResidencyCache,
    ShardedOpticalBackend,
    operating_point,
)

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6,
    device_sync_s=1.0e-5)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)

SPEC = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)

BITS = SPEC.dac.bits


def _imgs(n, shape=(32, 32), seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _drift(img, i, scale=0.01):
    """A small correlated perturbation: the drifting-sensor regime whose
    flip fraction sits well under ``DELTA_THRESHOLD`` at 6 DAC bits."""
    key = jax.random.fold_in(jax.random.PRNGKey(1234), i)
    return img + scale * jax.random.uniform(key, img.shape)


def _flush(ex, category, imgs, **kw):
    hs = [ex.submit(category, im, **kw) for im in imgs]
    ex.flush()
    return [np.asarray(h.value) for h in hs], [h.cost for h in hs]


# --- the flip model ---------------------------------------------------------------

def test_quantized_codes_affine_map():
    codes = quantized_codes(np.linspace(0.0, 1.0, 64), 6)
    assert codes.dtype == np.uint16
    assert codes.min() == 0 and codes.max() == 63
    # a constant operand spans zero range: every code collapses to 0
    assert not quantized_codes(np.full(16, 3.7), 6).any()
    with pytest.raises(ValueError):
        quantized_codes(np.ones(4), 0)


def test_code_signature_retains_codes_only_when_small():
    a = np.linspace(0.0, 1.0, 32)
    small = code_signature(a, BITS)
    assert small.codes is not None and small.n == 32
    assert len(small.plane_counts) == BITS
    big = code_signature(a, BITS, full_code_max=16)
    assert big.codes is None
    assert big.plane_counts == small.plane_counts


def test_expected_flip_fraction_exact_and_estimate():
    rng = np.random.default_rng(0)
    a, b = rng.random(1024), rng.random(1024)
    sa, sb = code_signature(a, BITS), code_signature(b, BITS)
    # identical codes flip nothing; a changed operand flips something
    assert expected_flip_fraction(sa, sa) == 0.0
    exact = expected_flip_fraction(sa, sb)
    assert 0.0 < exact <= 1.0
    # uncorrelated operands flip ~half their code bits
    assert 0.35 < exact < 0.65
    # the plane-count estimate (codes dropped) never undercharges
    ea = CodeSignature(sa.bits, sa.n, sa.plane_counts)
    eb = CodeSignature(sb.bits, sb.n, sb.plane_counts)
    assert expected_flip_fraction(ea, eb) >= exact - 1e-12
    # incomparable signatures are a full rewrite by definition
    assert expected_flip_fraction(sa, code_signature(b, BITS + 1)) == 1.0
    assert expected_flip_fraction(sa, code_signature(b[:512], BITS)) == 1.0


def test_delta_write_scale_floor_and_cap():
    assert delta_write_scale(0.0, BITS) == pytest.approx(1.0 / BITS)
    assert delta_write_scale(1e-9, BITS) == pytest.approx(1.0 / BITS)
    assert delta_write_scale(0.5, BITS) == 0.5
    assert delta_write_scale(2.0, BITS) == 1.0
    with pytest.raises(ValueError):
        delta_write_scale(0.5, 0)


# --- the cost model ---------------------------------------------------------------

@pytest.mark.parametrize("spec", [LANED_4F, ANDERSON_MVM],
                         ids=["4f", "mvm"])
def test_batched_step_cost_delta_defaults_and_ordering(spec):
    """Defaults reproduce the historical prices bit for bit, and the
    write-side price is ordered hit <= delta <= full — a delta write can
    never beat a hit (the ladder still strobes) nor cost more than the
    full rewrite it replaces."""
    base = spec.batched_step_cost(4096, batch=8)
    again = spec.batched_step_cost(4096, batch=8, delta_fractions=())
    assert base == again
    hit = spec.batched_step_cost(4096, batch=8, resident_frames=8)
    delta = spec.batched_step_cost(4096, batch=8,
                                   delta_fractions=(0.2,) * 8)
    assert hit.dac_s < delta.dac_s < base.dac_s
    assert hit.total_s < delta.total_s < base.total_s
    # all-1.0 scales ARE the full write, bit for bit
    unity = spec.batched_step_cost(4096, batch=8,
                                   delta_fractions=(1.0,) * 8)
    assert unity == base
    # resident frames and delta frames compose: the remaining writes price
    mixed = spec.batched_step_cost(4096, batch=8, resident_frames=4,
                                   delta_fractions=(0.2,) * 4)
    part = spec.batched_step_cost(4096, batch=8, resident_frames=4)
    assert hit.total_s < mixed.total_s < part.total_s


@pytest.mark.parametrize("spec", [LANED_4F, ANDERSON_MVM],
                         ids=["4f", "mvm"])
def test_batched_step_cost_delta_validation(spec):
    with pytest.raises(ValueError):
        spec.batched_step_cost(4096, batch=8, delta_fractions=(0.0,))
    with pytest.raises(ValueError):
        spec.batched_step_cost(4096, batch=8, delta_fractions=(1.5,))
    with pytest.raises(ValueError):
        spec.batched_step_cost(4096, batch=8, resident_frames=6,
                               delta_fractions=(0.5,) * 3)


def test_delta_price_monotone_grid():
    """Tier-1 anchor grid (the hypothesis sweep below is nightly/slow):
    the delta price is monotone in the write scale and pinned between the
    hit and full prices at the extremes."""
    for spec in (LANED_4F, ANDERSON_MVM):
        hit = spec.batched_step_cost(4096, batch=8,
                                     resident_frames=8).total_s
        full = spec.batched_step_cost(4096, batch=8).total_s
        prev = hit
        for s in (0.05, 0.1, 0.25, 0.5, 0.75, 1.0):
            c = spec.batched_step_cost(4096, batch=8,
                                       delta_fractions=(s,) * 8).total_s
            assert prev <= c
            prev = c
        assert prev == full


if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(deadline=None)
    @given(scales=st.lists(st.floats(min_value=1e-6, max_value=1.0),
                           min_size=1, max_size=8),
           resident=st.integers(min_value=0, max_value=7))
    def test_delta_price_between_hit_and_full_property(scales, resident):
        batch = 8
        resident = min(resident, batch - len(scales))
        for spec in (LANED_4F, ANDERSON_MVM):
            hit = spec.batched_step_cost(4096, batch=batch,
                                         resident_frames=batch)
            full = spec.batched_step_cost(4096, batch=batch)
            same_res = spec.batched_step_cost(4096, batch=batch,
                                              resident_frames=resident)
            delta = spec.batched_step_cost(4096, batch=batch,
                                           resident_frames=resident,
                                           delta_fractions=tuple(scales))
            assert hit.total_s <= delta.total_s <= same_res.total_s
            assert delta.total_s <= full.total_s
            assert hit.dac_s <= delta.dac_s <= same_res.dac_s


# --- the memo aliasing fix --------------------------------------------------------

def test_content_key_never_memoizes_writeable_buffers():
    """Regression: an id-keyed digest memo served a stale key when a
    writeable numpy buffer was mutated in place between submits — same
    object, same id, different bytes.  Mutable operands now re-hash every
    time; immutable ones (jax arrays, read-only ndarrays) still memoize."""
    ctx = BackendContext(spec=SPEC)
    buf = np.zeros((8, 8), dtype=np.float32)
    k1 = ctx.content_key(buf)
    assert id(buf) not in ctx._digest_memo
    buf[0, 0] = 1.0
    assert ctx.content_key(buf) != k1
    ro = np.ones((4, 4))
    ro.setflags(write=False)
    kr = ctx.content_key(ro)
    assert id(ro) in ctx._digest_memo
    assert ctx.content_key(ro) == kr
    arr = jnp.ones((4, 4))
    ctx.content_key(arr)
    assert id(arr) in ctx._digest_memo


# --- slot classification ----------------------------------------------------------

def test_classify_operand_hit_delta_full():
    cache = ResidencyCache(capacity_bytes=1 << 20)
    slot = ("host", "fft", "frame", operating_point(SPEC), ((32, 32),
                                                           "float32"), 0)
    img = _imgs(1)[0]
    ck = ("k", 0)
    # never seen: full write, ledger seeded
    assert cache.classify_operand(slot, ck, img, SPEC,
                                  category="fft") == ("full", 1.0)
    # unchanged content key: hit, no signature recomputed
    assert cache.classify_operand(slot, ck, img, SPEC,
                                  category="fft") == ("hit", 0.0)
    # small drift: delta at the measured flip fraction's write scale
    label, scale = cache.classify_operand(slot, ("k", 1), _drift(img, 0),
                                          SPEC, category="fft")
    assert label == "delta"
    assert 1.0 / BITS <= scale <= delta_write_scale(DELTA_THRESHOLD, BITS)
    assert cache.counts["fft"]["delta"] == 1
    # an unrelated frame flips ~half its bits: full re-stage
    other = _imgs(1, seed=77)[0]
    assert cache.classify_operand(slot, ("k", 2), other, SPEC,
                                  category="fft") == ("full", 1.0)


def test_invalidate_device_drops_slot_signatures():
    cache = ResidencyCache(capacity_bytes=1 << 20)
    img = _imgs(1)[0]
    slot = (("device", 1), "fft", "frame", operating_point(SPEC),
            ((32, 32), "float32"), 0)
    cache.classify_operand(slot, ("k", 0), img, SPEC, category="fft")
    cache.invalidate_device(("device", 1))
    # the quarantined device's codes are gone: a drifted re-stage is a
    # full write again, not a delta against untrustworthy bytes
    assert cache.classify_operand(slot, ("k", 1), _drift(img, 0), SPEC,
                                  category="fft") == ("full", 1.0)


# --- dispatch/model agreement and equivalence -------------------------------------

def test_delta_staged_flush_priced_by_measured_flip():
    """The acceptance criterion on the cost model: a correlated-drift
    flush prices write-side DAC strictly between a hit and a full
    re-stage, and the dispatched cost IS
    ``batched_step_cost(resident_frames=R, delta_fractions=...)`` at the
    measured flip fractions."""
    imgs = _imgs(6)
    drift = list(imgs)
    for i in (0, 3):
        drift[i] = _drift(imgs[i], i)
    fracs = [expected_flip_fraction(
        code_signature(np.asarray(imgs[i]), BITS),
        code_signature(np.asarray(drift[i]), BITS)) for i in (0, 3)]
    assert all(0.0 < f <= DELTA_THRESHOLD for f in fracs)
    scales = tuple(delta_write_scale(f, BITS) for f in fracs)

    ex = OffloadExecutor(SPEC, max_batch=8, residency=True)
    _flush(ex, "fft", imgs)                       # full stage, slots seeded
    _, costs = _flush(ex, "fft", drift)           # 4 resident, 2 delta
    n = imgs[0].size
    want = ex.spec.batched_step_cost(n, n, batch=len(drift),
                                     pipeline_depth=ex.pipeline_depth,
                                     resident_frames=4,
                                     delta_fractions=scales)
    full = ex.spec.batched_step_cost(n, n, batch=len(drift),
                                     pipeline_depth=ex.pipeline_depth)
    got = costs[0]  # per-call share of the invocation's modeled cost
    np.testing.assert_allclose(got.total_s, want.total_s / len(drift),
                               rtol=1e-12)
    np.testing.assert_allclose(got.dac_s * len(drift), want.dac_s,
                               rtol=1e-9)
    assert 0.0 < got.dac_s * len(drift) < full.dac_s
    # the ledger saw 6 full writes then 2 deltas, at the measured flips
    assert ex.residency.counts["fft"]["delta"] == 2
    assert ex.telemetry.delta_rate("fft") == pytest.approx(2 / 8)
    assert ex.telemetry.mean_flip_fraction("fft") == \
        pytest.approx(sum(fracs) / 2)


@pytest.mark.parametrize("backend", ["host", "optical-sim"])
def test_delta_staged_equals_restaged(backend):
    """The equivalence invariant, one more axis: delta-staged == re-staged
    bit-equal (classification prices the write, it never alters the
    staged bytes)."""
    imgs = _imgs(6)
    drift = [_drift(im, i) if i % 3 == 0 else im
             for i, im in enumerate(imgs)]
    plain = OffloadExecutor(SPEC, max_batch=8, default_backend=backend)
    restaged, _ = _flush(plain, "fft", drift)
    ex = OffloadExecutor(SPEC, max_batch=8, default_backend=backend,
                         residency=True)
    _flush(ex, "fft", imgs)
    delta_staged, _ = _flush(ex, "fft", drift)
    for d, r in zip(delta_staged, restaged):
        np.testing.assert_array_equal(d, r)
    # a repeat of the drifted group is a group-grain hit: write side free
    _, costs = _flush(ex, "fft", drift)
    if backend == "optical-sim":
        assert costs[0].dac_s == 0.0


# --- placed re-stage donates the stale buffer -------------------------------------

def test_commit_placement_donates_changed_frames(monkeypatch):
    import repro.runtime.sharded as sh
    dev = jax.devices()[0]
    monkeypatch.setattr(sh, "shard_devices", lambda n: [dev] * n)
    be = ShardedOpticalBackend(inner="host")
    ctx = BackendContext(spec=SPEC, n_devices=2)
    ctx.residency = ResidencyCache(capacity_bytes=1 << 22)
    imgs = _imgs(4)
    assert be.commit_placement("fft", imgs, ctx) is not None
    be.run("fft", imgs, ctx)
    op = operating_point(SPEC)
    dead_key = ("frame-shard", op, (ctx.content_key(imgs[0]),))
    assert dead_key in ctx.residency.resident_keys()

    drift = [_drift(imgs[0], 0)] + imgs[1:]
    be.commit_placement("fft", drift, ctx)
    # the stale device buffer was donated at commit, before the re-stage
    assert ctx.residency.counts["fft"]["donation"] == 1
    assert dead_key not in ctx.residency.resident_keys()
    be.run("fft", drift, ctx)
    # never two copies of a frame against the budget: 4 frames, 4 shards
    frame_shards = [k for k in ctx.residency.resident_keys()
                    if k[0] == "frame-shard"]
    assert len(frame_shards) == 4
    # unchanged frames kept their resident entries (only frame 0 re-shipped)
    for im in imgs[1:]:
        assert ("frame-shard", op,
                (ctx.content_key(im),)) in frame_shards


# --- the router weighs the delta rate ---------------------------------------------

def test_router_replan_weighs_delta_rate():
    """The deadline-halving loop prices the observed delta rate in: the
    same traffic sustains a deeper batch when most writes are partial."""
    def _router(flip):
        ex = OffloadExecutor(SPEC, max_batch=16)
        ex.telemetry.record("fft", "optical-sim", calls=16,
                            samples_in=16 * 4096, samples_out=16 * 4096,
                            wall_s=0.01)
        for _ in range(8):
            ex.telemetry.note_delta("fft", flip_fraction=flip)
        return PlanRouter(ex)

    scale = delta_write_scale(0.05, BITS)
    priced = SPEC.batched_step_cost(4096, 4096, batch=16, pipeline_depth=2,
                                    n_devices=1, tile_k=16,
                                    delta_fractions=(scale,) * 16)
    full = SPEC.batched_step_cost(4096, 4096, batch=16, pipeline_depth=2,
                                  n_devices=1, tile_k=16)
    # a deadline only the delta-priced write side meets at full depth
    deadline = (priced.total_s + full.total_s) / 2
    hot = _router(flip=0.05)    # delta rate 1.0, mean flip 0.05
    cold = _router(flip=None)   # every write full: delta rate 0
    k_hot = hot.choose_sharding(deadline)["fft"][0]
    k_cold = cold.choose_sharding(deadline)["fft"][0]
    assert k_hot == 16
    assert k_cold < 16
