"""Admission-controlled continuous batching: the scheduler property harness.

The invariant this file locks down (the ISSUE's acceptance criterion):

    scheduler-held execution == eager flush == looped per-frame

on all three backends *including sharded*, for ragged tails and
deadline-forced partial releases: holding a partially filled group open
across flushes, releasing it early on a deadline, or splitting one
submission stream across several admission passes must never change a
result — only when the boundary is crossed and how many frames share the
crossing.  All timing rides a ``ManualClock``, so every admission decision
(ages, arrival rates, deadlines) is deterministic.

Runs under hypothesis when installed (nightly CI uses the ``nightly``
profile for more examples); falls back to a fixed example grid otherwise.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.core.planner import CategoryProfile, plan_offload
from repro.runtime import (
    FidelityChecker,
    ManualClock,
    OffloadExecutor,
    OffloadScheduler,
    PlanRouter,
    RuntimeTelemetry,
)

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6,
    device_sync_s=1.0e-5)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)

SPEC = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)
MVM = dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC)

# inner backend -> its registered sharded wrapper (group sharding: tight)
SHARDED_OF = {"host": "sharded-host", "optical-sim": "sharded",
              "ideal": "sharded-ideal"}

DEADLINE = 0.1
# Inter-arrival pattern cycled over the submissions: two quick arrivals,
# then a pause longer than the deadline — the pre-arrival poll() then
# force-releases whatever is held (a deadline-forced partial release),
# while the quick pairs exercise accumulation and rule-(a) full releases.
GAPS = (0.01, 0.01, 0.25)


def _imgs(n, shape, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _kernel(shape):
    h, w = shape
    return (jnp.zeros(shape)
            .at[0, 0].set(0.5).at[1, 2 % w].set(0.25)
            .at[h - 1, 1 % w].set(0.15))


def _run_eager(backend, category, imgs, spec, *, max_batch, n_devices=1,
               kernel=None, weights=None, tile_k=None):
    ex = OffloadExecutor(spec, max_batch=max_batch, n_devices=n_devices,
                         default_backend=backend, tile_k=tile_k)
    kw = {k: v for k, v in (("kernel", kernel), ("weights", weights))
          if v is not None}
    hs = [ex.submit(category, im, **kw) for im in imgs]
    ex.flush()
    return hs, ex


def _run_scheduled(backend, category, imgs, spec, *, max_batch, n_devices=1,
                   kernel=None, weights=None, gaps=GAPS, deadline=DEADLINE,
                   tile_k=None):
    """Drive the same submissions through an admission-controlled stream:
    clock-advance, event-loop poll (may deadline-release held groups
    *before* the new arrival joins them — a genuinely partial release),
    then submit (whose own poll fires rule (a) full releases)."""
    clk = ManualClock()
    ex = OffloadExecutor(spec, max_batch=max_batch, n_devices=n_devices,
                         default_backend=backend, clock=clk, tile_k=tile_k)
    sched = OffloadScheduler(ex, deadline_s=deadline, clock=clk)
    kw = {k: v for k, v in (("kernel", kernel), ("weights", weights))
          if v is not None}
    hs = []
    for i, im in enumerate(imgs):
        clk.advance(gaps[i % len(gaps)])
        sched.poll()
        hs.append(sched.submit(category, im, **kw))
    clk.advance(2 * deadline)
    sched.poll()          # due-release the tail the event loop still holds
    ex.drain()            # belt and braces: nothing may stay pending
    return hs, ex


def check_scheduled_equivalence(backend, category, shape, calls, max_batch,
                                n_devices=1, tile_k=None):
    imgs = _imgs(calls, shape)
    kernel = _kernel(shape) if category == "conv" else None
    name = SHARDED_OF[backend] if n_devices > 1 else backend
    held, hex_ = _run_scheduled(name, category, imgs, SPEC,
                                max_batch=max_batch, n_devices=n_devices,
                                kernel=kernel, tile_k=tile_k)
    eager, _ = _run_eager(backend, category, imgs, SPEC, max_batch=max_batch,
                          kernel=kernel)
    looped, _ = _run_eager(backend, category, imgs, SPEC, max_batch=1,
                           kernel=kernel)
    # Digital backends are bit-stable across groupings; the optical sim
    # quantizes, and XLA lowers batch-1 vs batch-K reductions differently,
    # so a borderline sample may legitimately snap one converter level
    # (~2^-12 here) apart.  Tolerance = a few quantizer steps, far below
    # any real divergence — batch *composition* is verified bit-tight by
    # the scheduled-vs-eager comparison whenever chunks coincide.
    atol = 1e-3 if backend == "optical-sim" else 1e-5
    for hh, he, hl in zip(held, eager, looped):
        np.testing.assert_allclose(hh.value, he.value, rtol=1e-4, atol=atol)
        np.testing.assert_allclose(he.value, hl.value, rtol=1e-4, atol=atol)
    st = hex_.telemetry.stats[(category, name)]
    assert st.calls == calls                      # nothing lost or doubled
    assert st.invocations >= math.ceil(calls / max_batch)
    assert hex_.pending == 0 and hex_.in_flight == 0
    if tile_k is not None:
        # admission-held releases honored the tile ceiling too
        assert max(hex_.telemetry.tile_sizes_observed(category)) \
            <= max(1, min(tile_k, max_batch))


SCHED_CASES = [
    # (backend, category, shape, calls, max_batch, n_devices, tile_k) —
    # ragged tails (calls % max_batch != 0) and deadline-forced partial
    # releases (the GAPS pause) throughout; n_devices > 1 routes via the
    # sharded wrapper (the held queue feeding the fleet); tile_k forces
    # memory-budgeted tiled dispatch of the released groups.
    ("host", "fft", (16, 12), 7, 3, 1, None),
    ("host", "conv", (16, 12), 5, 4, 1, None),
    ("optical-sim", "fft", (16, 12), 8, 3, 1, None),
    ("optical-sim", "conv", (12, 8), 7, 4, 1, None),
    ("ideal", "fft", (16, 12), 6, 4, 1, None),
    ("ideal", "conv", (16, 12), 4, 3, 1, None),
    ("host", "fft", (16, 12), 7, 4, 2, None),
    ("optical-sim", "fft", (16, 12), 9, 4, 4, None),
    ("optical-sim", "conv", (16, 12), 7, 3, 2, None),
    ("ideal", "conv", (12, 8), 6, 4, 4, None),
    # scheduler-held + tiled (+ sharded): a deadline-released partial
    # group still streams through the tile ceiling, ragged tiles included
    ("optical-sim", "fft", (16, 12), 8, 5, 1, 2),
    ("optical-sim", "conv", (12, 8), 7, 4, 2, 3),
    ("host", "fft", (16, 12), 6, 6, 1, 1),
    ("ideal", "fft", (12, 8), 7, 4, 4, 2),
]


@pytest.mark.parametrize(
    "backend,category,shape,calls,max_batch,n_devices,tile_k", SCHED_CASES)
def test_scheduled_equivalence_fixed(backend, category, shape, calls,
                                     max_batch, n_devices, tile_k):
    """Tier-1 anchor grid (the hypothesis sweep below is nightly/slow)."""
    check_scheduled_equivalence(backend, category, shape, calls, max_batch,
                                n_devices, tile_k)


if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(deadline=None)
    @given(backend=st.sampled_from(["host", "optical-sim", "ideal"]),
           category=st.sampled_from(["fft", "conv"]),
           h=st.integers(min_value=4, max_value=20),
           w=st.integers(min_value=4, max_value=20),
           calls=st.integers(min_value=1, max_value=9),
           max_batch=st.integers(min_value=1, max_value=5),
           n_devices=st.sampled_from([1, 2, 4]),
           tile_k=st.one_of(st.none(), st.integers(min_value=1, max_value=6)))
    def test_scheduled_equivalence_property(backend, category, h, w, calls,
                                            max_batch, n_devices, tile_k):
        check_scheduled_equivalence(backend, category, (h, w), calls,
                                    max_batch, n_devices, tile_k)


def test_scheduled_matmul_equivalence():
    key = jax.random.PRNGKey(5)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (12, 16))
          for i in range(7)]
    w = jax.random.normal(jax.random.fold_in(key, 99), (16, 8))
    held, _ = _run_scheduled("optical-sim", "matmul", xs, MVM, max_batch=3,
                             weights=w)
    eager, _ = _run_eager("optical-sim", "matmul", xs, MVM, max_batch=3,
                          weights=w)
    looped, _ = _run_eager("optical-sim", "matmul", xs, MVM, max_batch=1,
                           weights=w)
    for hh, he, hl in zip(held, eager, looped):
        np.testing.assert_allclose(hh.value, he.value, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(he.value, hl.value, rtol=1e-4, atol=1e-3)


# --- the admission rules, one by one ------------------------------------------


def _sched(max_batch=4, deadline=0.1, **kw):
    clk = ManualClock()
    ex = OffloadExecutor(SPEC, max_batch=max_batch, clock=clk, **kw)
    return clk, ex, OffloadScheduler(ex, deadline_s=deadline, clock=clk)


def test_rule_full_group_releases_on_submit():
    """(a) a group reaching max_batch dispatches on the spot — no poll
    pump needed — and the ragged tail stays held."""
    clk, ex, sched = _sched(max_batch=2)
    imgs = _imgs(3, (8, 8))
    sched.submit("fft", imgs[0])
    assert sched.held == 1 and ex.in_flight == 0
    sched.submit("fft", imgs[1])       # group full: dispatched by submit
    assert sched.held == 0 and ex.in_flight == 1
    sched.submit("fft", imgs[2])       # tail: held again
    assert sched.held == 1
    ex.drain()
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.invocations == 2 and st.calls == 3


def test_rule_deadline_releases_partial_group():
    """(b) the oldest held call's age reaching the deadline forces the
    group out, whatever its occupancy."""
    clk, ex, sched = _sched(max_batch=8, deadline=0.1)
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    assert sched.held == 1
    clk.advance(0.09)
    assert sched.poll() == [] and sched.held == 1    # not due yet
    clk.advance(0.02)                                 # age 0.11 > deadline
    released = sched.poll()
    assert [r for r in released] == [h] and sched.held == 0
    ex.drain()
    assert h.done()


def test_rule_arrival_rate_futility_releases_early():
    """(c) when the measured arrival rate says the next arrival lands past
    the deadline, holding buys latency without occupancy: release now,
    well before the deadline itself expires."""
    clk, ex, sched = _sched(max_batch=8, deadline=1.0)
    imgs = _imgs(3, (8, 8))
    # establish a sparse arrival history: ~0.45 s between submits
    clk.advance(0.45)
    sched.submit("fft", imgs[0])
    clk.advance(0.45)
    sched.submit("fft", imgs[1])
    # rate ~2.2/s -> expected next arrival in ~0.45 s; oldest age 0.45;
    # 0.45 + 0.45 < 1.0 -> still worth holding
    assert sched.held == 2
    clk.advance(0.45)
    sched.submit("fft", imgs[2])
    # oldest age 0.9; 0.9 + ~0.45 > 1.0 -> futile to keep holding: the
    # submit's own poll released the group 0.1 s before its deadline
    assert sched.held == 0 and ex.in_flight == 1
    ex.drain()
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 1


def test_unknown_rate_holds_until_deadline():
    """One arrival = no rate estimate: the scheduler holds optimistically
    (rule (c) stays quiet) and only the deadline can release."""
    clk, ex, sched = _sched(max_batch=8, deadline=0.5)
    sched.submit("fft", _imgs(1, (8, 8))[0])
    assert ex.telemetry.arrival_rate("fft") == 0.0
    clk.advance(0.4)
    assert sched.poll() == [] and sched.held == 1
    clk.advance(0.2)
    assert len(sched.poll()) == 1


def test_burst_arrivals_estimate_infinite_rate_and_hold():
    """Simultaneous submits (span ~0) estimate an infinite rate: the next
    arrival is expected immediately, so the scheduler keeps holding."""
    clk, ex, sched = _sched(max_batch=8, deadline=0.5)
    imgs = _imgs(3, (8, 8))
    for im in imgs:
        sched.submit("fft", im)       # no clock advance: a burst
    assert ex.telemetry.arrival_rate("fft") == math.inf
    assert sched.held == 3            # held: occupancy is still climbing
    clk.advance(1.0)
    sched.poll()
    ex.drain()
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 1


def test_hold_time_priced_into_invocation_cost():
    """The modeled wall honestly charges the queueing delay holding spent
    (StepCost.hold_s) — and eager executors price zero hold."""
    clk, ex, sched = _sched(max_batch=4, deadline=0.2)
    imgs = _imgs(2, (8, 8))
    sched.submit("fft", imgs[0])
    clk.advance(0.05)
    sched.submit("fft", imgs[1])
    clk.advance(0.30)
    (h, h2) = sched.poll()
    ex.drain()
    # oldest member waited 0.35; the per-call share splits it across the 2
    assert h.cost.hold_s == pytest.approx(0.35 / 2)
    assert h.cost.total_s > h.cost.conversion_s + h.cost.interface_s
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.modeled.hold_s == pytest.approx(0.35)
    eager, eex = _run_eager("optical-sim", "fft", imgs, SPEC, max_batch=4)
    assert eager[0].cost.hold_s == 0.0
    assert eex.telemetry.stats[("fft", "optical-sim")].modeled.hold_s == 0.0


def test_batched_step_cost_hold_term():
    """The cost model's hold_s term is additive, scales, and survives the
    sharded max-over-devices recursion exactly once."""
    base = SPEC.batched_step_cost(4096, batch=4)
    held = SPEC.batched_step_cost(4096, batch=4, hold_s=0.25)
    assert held.hold_s == 0.25
    assert held.total_s == pytest.approx(base.total_s + 0.25)
    assert held.conversion_s == base.conversion_s
    sharded = SPEC.batched_step_cost(4096, batch=4, n_devices=2, hold_s=0.25)
    assert sharded.hold_s == 0.25
    mvm = MVM.batched_step_cost(512, 512, batch=8, hold_s=0.1)
    assert mvm.hold_s == pytest.approx(0.1)
    assert mvm.scaled(0.5).hold_s == pytest.approx(0.05)
    assert (mvm + mvm).hold_s == pytest.approx(0.2)


def test_force_flush_escape_hatches_release_held_groups():
    """flush / get / drain / the context manager are the force-release
    path: held work dispatches immediately through every one of them."""
    # executor.flush()
    clk, ex, sched = _sched()
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    ex.flush()
    assert h.done() and sched.held == 0
    # result.get()
    clk, ex, sched = _sched()
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    _ = h.get()
    assert h.done()
    # drain() alone (the satellite: drain releases scheduler-held groups)
    clk, ex, sched = _sched()
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    ex.drain()
    assert h.done() and ex.pending == 0 and ex.in_flight == 0
    # scheduler context manager
    clk, ex, sched = _sched()
    with sched:
        h = sched.submit("fft", _imgs(1, (8, 8))[0])
    assert h.done()


def test_executor_context_manager_drains_everything():
    """``with OffloadExecutor(...)`` cannot leak pending, held, or
    in-flight work — even when the body raises."""
    imgs = _imgs(5, (8, 8))
    with OffloadExecutor(SPEC, max_batch=2) as ex:
        hs = [ex.submit("fft", im) for im in imgs]
        ex.flush_async()              # some in flight, none retired
        hs.append(ex.submit("fft", imgs[0]))   # and one still queued
    assert ex.pending == 0 and ex.in_flight == 0
    assert all(h.done() for h in hs)
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.calls == 6
    # exception path: handles still materialize
    with pytest.raises(RuntimeError):
        with OffloadExecutor(SPEC, max_batch=4) as ex2:
            h = ex2.submit("fft", imgs[0])
            raise RuntimeError("boom")
    assert h.done() and ex2.pending == 0 and ex2.in_flight == 0


def test_scheduler_routes_through_plan_router():
    """A scheduler wrapping a PlanRouter paces release while the router's
    table picks the backend."""
    clk = ManualClock()
    ex = OffloadExecutor(SPEC, max_batch=4, clock=clk)
    router = PlanRouter(ex)           # all-host profiling mode
    sched = OffloadScheduler(router, deadline_s=0.1, clock=clk)
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    assert sched.held == 1
    clk.advance(0.2)
    sched.poll()
    ex.drain()
    assert h.backend == "host"
    assert ("fft", "host") in ex.telemetry.stats


def test_held_groups_diagnostics_and_summary():
    clk, ex, sched = _sched(max_batch=4, deadline=0.1)
    sched.submit("fft", _imgs(1, (8, 8))[0])
    clk.advance(0.03)
    (row,) = sched.held_groups()
    assert row["category"] == "fft" and row["held"] == 1
    assert row["oldest_age_s"] == pytest.approx(0.03)
    assert "held=1" in sched.summary()
    ex.drain()


# --- telemetry: the arrival process -------------------------------------------


def test_telemetry_arrival_rate_estimation():
    t = RuntimeTelemetry()
    assert t.arrival_rate("fft") == 0.0           # no arrivals
    t.note_submit("fft", 1.0)
    assert t.arrival_rate("fft") == 0.0           # one arrival: no estimate
    for ts in (1.5, 2.0, 2.5):
        t.note_submit("fft", ts)
    assert t.arrival_rate("fft") == pytest.approx(2.0)   # 3 gaps / 1.5 s
    assert t.arrival_rate("conv") == 0.0          # per category
    t.note_submit("conv", 3.0)
    t.note_submit("conv", 3.0)
    assert t.arrival_rate("conv") == math.inf     # burst
    t.reset()
    assert t.arrival_rate("fft") == 0.0


def test_telemetry_arrival_rate_merge():
    a, b = RuntimeTelemetry(), RuntimeTelemetry()
    a.note_submit("fft", 0.0)
    b.note_submit("fft", 1.0)
    a.merge(b)
    assert a.arrival_rate("fft") == pytest.approx(1.0)


# --- fidelity-gated planning (the acceptance criterion) -----------------------


def test_plan_offload_fidelity_gate_vetoes_fast_offload():
    """A category whose observed rel_err blows the ENOB budget must NOT be
    offloaded even when category_speedup > 1 (ISSUE acceptance)."""
    prof = CategoryProfile("fft", host_s=10.0, calls=16,
                           samples_in=16 * 4096, samples_out=16 * 4096)
    clean = plan_offload([prof], SPEC, max_batch=16)
    d_clean = clean.decisions[0]
    assert d_clean.offload and d_clean.category_speedup > 1  # sanity: fast
    bad = dataclasses.replace(prof, rel_err=0.9)   # over the ENOB budget
    # (the limiting converter here is the 5-ENOB DAC: budget 16 * 2^-5 = 0.5)
    gated = plan_offload([bad], SPEC, max_batch=16)
    d = gated.decisions[0]
    assert d.accel_s < d.host_s                    # still faster on paper...
    assert not d.offload and d.fidelity_bound      # ...and still vetoed
    assert gated.fidelity_bound and not clean.fidelity_bound
    assert "FIDELITY-GATED" in gated.summary()
    # the plan's bottom line prices the veto honestly: fft stays on host
    assert gated.total_planned_s == pytest.approx(gated.total_host_s)
    # an in-budget rel_err sails through the gate
    enob = min(SPEC.dac.effective_bits, SPEC.adc.effective_bits)
    ok = dataclasses.replace(prof, rel_err=0.5 * 16.0 * 2.0 ** (-enob))
    assert plan_offload([ok], SPEC, max_batch=16).decisions[0].offload


def test_replan_threads_fidelity_reports_and_falls_back_to_host():
    """The loop-closer: a VIOLATION report observed while serving flips the
    category's route back to host on the next replan, even though the spec
    is fast enough that speed alone would keep it offloaded."""
    # near-free boundary: speed strongly favors offload...
    fast = dataclasses.replace(
        SPEC, name="fast-4f", interface_latency_s=0.0, slm_settle_s=0.0,
        exposure_s=0.0, dac_lanes=4096, adc_lanes=4096,
        # ...but the write path is a deliberately mis-ranged 1-bit DAC
        # whose claimed ENOB (8 bits) its actual resolution cannot honor:
        # the shadow run scores a rel_err far outside the 2^-8 budget.
        dac=ConverterSpec(name="dac1", kind="dac", bits=1, rate_hz=1e9,
                          power_w=0.05, enob=8.0))
    checker = FidelityChecker(slack=1.0)
    ex = OffloadExecutor(fast, fidelity=checker, max_batch=4)
    router = PlanRouter(ex)
    imgs = _imgs(4, (32, 32))
    ex.telemetry.start()
    for im in imgs:                   # measured host baseline
        router.run("fft", im)
    ex.telemetry.stop()
    plan1 = router.replan()
    # no fidelity evidence yet (host traffic is never shadowed): the
    # fast spec wins on speed and fft routes to the optical engine
    assert router.backend_for("fft") == "optical-sim"
    assert not plan1.fidelity_bound
    for im in imgs:                   # offloaded traffic is shadow-scored
        router.run("fft", im)
    assert not checker.all_ok         # the VIOLATION the gate needs
    plan2 = router.replan()
    d = next(d for d in plan2.decisions if d.category == "fft")
    assert d.fidelity_bound and not d.offload
    assert d.accel_s < d.host_s       # speed still says offload; gate wins
    assert router.backend_for("fft") == "host"   # fallen back


# --- serving-engine hook ------------------------------------------------------


def test_serving_engine_polls_scheduler_across_decode_steps():
    """With an OffloadScheduler as the engine's offload hook, the decode
    step runs an admission poll instead of a forced flush: a partially
    filled aux group survives decode steps and coalesces submissions made
    *between* steps into one boundary crossing once due."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    clk = ManualClock()
    ex = OffloadExecutor(SPEC, max_batch=8, default_backend="host",
                         clock=clk)
    sched = OffloadScheduler(ex, deadline_s=0.5, clock=clk)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=32,
                           offload=sched)
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    imgs = _imgs(3, (8, 8), seed=9)
    h0 = engine.submit_aux("fft", imgs[0])
    engine.step()
    # pre-scheduler the engine would have flushed here; now the group is
    # held (age < deadline, no rate evidence says waiting is futile)
    assert engine.pending_aux == 1 and not h0.ready
    clk.advance(0.01)
    engine.submit_aux("fft", imgs[1])
    engine.step()
    assert engine.pending_aux == 2          # still riding across steps
    clk.advance(0.01)
    engine.submit_aux("fft", imgs[2])
    clk.advance(1.0)                        # deadline expires
    engine.step()                           # this step's poll releases
    assert engine.pending_aux == 0
    ex.drain()
    st = ex.telemetry.stats[("fft", "host")]
    assert st.invocations == 1 and st.calls == 3   # ONE crossing for all 3
    engine.run_to_completion(max_steps=8)


def test_replan_gates_with_the_checkers_own_slack():
    """The gate must judge with the attached checker's slack, not the
    default: a rel_err the strict checker flags as VIOLATION must flip the
    plan even though the default slack would wave it through."""
    checker = FidelityChecker(slack=2.0)
    ex = OffloadExecutor(SPEC, fidelity=checker, max_batch=4)
    router = PlanRouter(ex)
    enob = min(SPEC.dac.effective_bits, SPEC.adc.effective_bits)
    # between the strict bound (2 * 2^-enob) and the default (16 * 2^-enob)
    rel_err = 4.0 * 2.0 ** (-enob)
    ex.telemetry.record("fft", "host", calls=8, samples_in=8 * 4096,
                        samples_out=8 * 4096, wall_s=10.0)
    profiles = [dataclasses.replace(p, rel_err=rel_err)
                for p in ex.telemetry.profiles(include_other=False)]
    default_plan = plan_offload(profiles, SPEC, max_batch=8)
    assert not default_plan.decisions[0].fidelity_bound   # 16x slack: passes
    # hand the checker a report carrying that same rel_err and replan
    checker.check("fft", "optical-sim",
                  [jnp.ones((4, 4)) * (1.0 + rel_err)], [jnp.ones((4, 4))],
                  enob=enob)
    assert not checker.all_ok                              # 2x slack: VIOLATION
    plan = router.replan(apply=False, max_batch=8)
    d = next(d for d in plan.decisions if d.category == "fft")
    assert d.fidelity_bound and not d.offload


def test_scheduler_held_fidelity_shadowing_still_scores():
    """Held groups released by the scheduler flow through the same shadow
    scoring as eager flushes (validation mode stays synchronous)."""
    clk = ManualClock()
    checker = FidelityChecker()
    ex = OffloadExecutor(SPEC, fidelity=checker, max_batch=4, clock=clk)
    sched = OffloadScheduler(ex, deadline_s=0.1, clock=clk)
    for im in _imgs(3, (16, 16)):
        clk.advance(0.01)
        sched.submit("fft", im)
    clk.advance(0.2)
    (h, *_rest) = sched.poll()
    assert h.fidelity is not None and h.fidelity.batch == 3
    assert ex.in_flight == 0          # shadow batches retire synchronously


def test_run_and_get_force_release_held_groups_under_manual_clock():
    """The blocking path with a scheduler attached: ``OffloadResult.get``
    (and ``OffloadExecutor.run``, which is submit + get) must force-release
    a held group rather than block on a deadline the ManualClock will
    never reach on its own."""
    # result.get() on a submission held in a partially filled group
    clk, ex, sched = _sched(max_batch=4)
    h = sched.submit("fft", _imgs(1, (8, 8))[0])
    clk.advance(0.01)                 # deadline (0.1s) nowhere near due
    assert sched.held == 1
    v = h.get()                       # returns promptly: flush force-releases
    assert h.done() and sched.held == 0
    ref = OffloadExecutor(SPEC, max_batch=1).run("fft", _imgs(1, (8, 8))[0])
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ref))
    # executor.run() while another submission sits held: the eager call's
    # flush sweeps the held group along with it
    clk, ex, sched = _sched(max_batch=4)
    held = sched.submit("fft", _imgs(1, (8, 8))[0])
    out = ex.run("fft", _imgs(1, (8, 8), seed=1)[0])
    assert held.done() and sched.held == 0 and ex.pending == 0
    np.testing.assert_array_equal(np.asarray(held.value), np.asarray(ref))
    ref1 = OffloadExecutor(SPEC, max_batch=1).run(
        "fft", _imgs(1, (8, 8), seed=1)[0])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref1))


# --- adaptive per-engine pipeline windows (router) -----------------------------


def test_replan_collapses_window_to_observed_overlap():
    """Traffic that never overlapped in flight earns no pipelined-hiding
    credit: replan writes the category's window down to its measured
    in-flight-at-dispatch occupancy.  One group per flush means occupancy
    1 at every dispatch, so the chosen window is 1."""
    ex = OffloadExecutor(SPEC, max_batch=8, pipeline_depth=2)
    router = PlanRouter(ex)
    imgs = _imgs(8, (16, 16))
    ex.telemetry.start()
    for im in imgs:
        ex.submit("fft", im, backend="host")
        ex.flush()          # one invocation per flush: no overlap ever
    ex.telemetry.stop()
    assert router.choose_windows() == {"fft": 1}
    router.replan()
    assert ex.pipeline_window_for("fft") == 1
    # deep traffic keeps the global depth: four invocations in one flush
    ex2 = OffloadExecutor(SPEC, max_batch=2, pipeline_depth=2)
    router2 = PlanRouter(ex2)
    ex2.telemetry.start()
    for im in imgs:
        ex2.submit("fft", im, backend="host")
    ex2.flush()             # 4 invocations ride the two-deep window
    ex2.telemetry.stop()
    assert router2.choose_windows()["fft"] == 2
    router2.replan()
    assert ex2.pipeline_window_for("fft") == 2


def test_operator_window_pin_bounds_adaptive_choice():
    """A window the operator pinned is a ceiling replan never exceeds —
    and never destroys: the snapshot survives the router's own writes."""
    ex = OffloadExecutor(SPEC, max_batch=2, pipeline_depth=3)
    router = PlanRouter(ex)
    ex.set_pipeline_window("fft", 1)   # operator pin below the global 3
    imgs = _imgs(6, (16, 16))
    ex.telemetry.start()
    for im in imgs:
        ex.submit("fft", im, backend="host")
    ex.flush()
    ex.telemetry.stop()
    router.replan()
    assert ex.pipeline_window_for("fft") == 1   # pin respected
    router.replan()                             # router's own write is not
    assert ex.pipeline_window_for("fft") == 1   # mistaken for an operator pin
