"""Offload runtime: backends, batching executor, telemetry loop, fidelity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import ANDERSON_MVM, PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.core.planner import CategoryProfile, plan_offload
from repro.core.profiler import OpProfiler
from repro.runtime import (
    FidelityChecker,
    OffloadExecutor,
    PlanRouter,
    RuntimeTelemetry,
    available_backends,
    get_backend,
)

# Lane-parallel converters, fast links, and a per-invocation link latency:
# the §6 levers the batching executor amortizes.  4096-sample frames
# deliberately do not divide the lane count, so even pure conversion time
# amortizes (ceil residue), and the fixed handshake dominates the streaming
# interface term so batching visibly wins.
LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)


def _imgs(n, shape=(64, 64), seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


# --- registry -------------------------------------------------------------------

def test_registry_has_three_backends():
    assert set(available_backends()) >= {"host", "optical-sim", "ideal"}
    for name in ("host", "optical-sim", "ideal"):
        assert get_backend(name).name == name
    with pytest.raises(KeyError):
        get_backend("quantum")


def test_backend_category_support_follows_spec():
    ex = OffloadExecutor(PROTOTYPE_4F)
    with pytest.raises(ValueError):
        ex.submit("matmul", jnp.ones((8, 8)), weights=jnp.ones((8, 8)))
    ex_mvm = OffloadExecutor(ANDERSON_MVM)
    with pytest.raises(ValueError):
        ex_mvm.submit("fft", jnp.ones((8, 8)))


# --- backend correctness ---------------------------------------------------------

def test_host_and_ideal_fft_match_oracle():
    (a,) = _imgs(1)
    want = jnp.abs(jnp.fft.fft2(a, norm="ortho")) ** 2
    ex = OffloadExecutor(PROTOTYPE_4F)
    np.testing.assert_array_equal(ex.run("fft", a, backend="host"), want)
    r = ex.submit("fft", a, backend="ideal")
    ex.flush()
    np.testing.assert_array_equal(r.value, want)
    # the ideal bound is exactly the zero-conversion-cost accelerator
    assert r.cost.conversion_s == 0.0
    assert r.cost.interface_s == 0.0
    assert r.cost.analog_s > 0.0


def test_optical_sim_fft_approximates_host():
    (a,) = _imgs(1)
    spec = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)
    ex = OffloadExecutor(spec)
    got = ex.run("fft", a)
    want = jnp.abs(jnp.fft.fft2(a, norm="ortho")) ** 2
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel


def test_optical_sim_conv_approximates_host():
    (a,) = _imgs(1)
    k = jnp.zeros((64, 64)).at[0, 0].set(0.6).at[0, 1].set(0.3).at[2, 3].set(0.1)
    spec = dataclasses.replace(
        LANED_4F,
        dac=ConverterSpec(name="d8", kind="dac", bits=8, rate_hz=1e9,
                          power_w=0.05, enob=7.0),
        adc=HI_FI_ADC)
    ex = OffloadExecutor(spec)
    got = ex.run("conv", a, kernel=k)
    want = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(k)))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel


def test_optical_sim_conv_handles_signed_inputs():
    """The SLM can't encode negatives: the backend must affine-map signed
    inputs onto the aperture and undo the map (regression: zero-centered
    or all-negative inputs used to come back as garbage/zeros)."""
    key = jax.random.PRNGKey(11)
    k = jnp.zeros((64, 64)).at[:3, :3].set(0.2).at[0, 0].add(0.4)
    spec = dataclasses.replace(
        LANED_4F,
        dac=ConverterSpec(name="d8", kind="dac", bits=8, rate_hz=1e9,
                          power_w=0.05, enob=7.0),
        adc=HI_FI_ADC)
    ex = OffloadExecutor(spec)
    # pre-fix: 0.71 rel error (centered) and 1.0 (all-negative -> zeros);
    # the centered case legitimately costs more bits (a +/-4 sigma signal
    # fills the DAC range sparsely), hence the looser bound
    for a, tol in ((jax.random.normal(key, (64, 64)), 0.15),
                   (-1.0 - jax.random.uniform(key, (64, 64)), 0.05)):
        got = ex.run("conv", a, kernel=k)
        want = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(k)))
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < tol, rel


def test_optical_sim_matmul_approximates_host():
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    ex = OffloadExecutor(dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC))
    got = ex.run("matmul", a, weights=w)
    want = a @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel


# --- the batching lever ----------------------------------------------------------

def test_batched_results_identical_and_boundary_cheaper():
    """Coalescing K same-shape calls must not change a single bit of the
    results while strictly reducing the modeled per-call conversion and
    conversion+interface time (ISSUE acceptance criterion)."""
    imgs = _imgs(8)
    batched = OffloadExecutor(LANED_4F, max_batch=8)
    handles = [batched.submit("fft", im) for im in imgs]
    batched.flush()

    serial = OffloadExecutor(LANED_4F, max_batch=1)
    serial_handles = [serial.submit("fft", im) for im in imgs]
    serial.flush()

    for hb, hs in zip(handles, serial_handles):
        np.testing.assert_array_equal(hb.value, hs.value)
        assert hb.batch == 8 and hs.batch == 1
        # pure conversion amortizes the converter-lane ceil residue
        assert hb.cost.conversion_s < hs.cost.conversion_s
        # conversion + interface amortizes the per-invocation handshake too
        boundary_b = hb.cost.conversion_s + hb.cost.interface_s
        boundary_s = hs.cost.conversion_s + hs.cost.interface_s
        assert boundary_b < 0.5 * boundary_s
    assert batched.telemetry.stats[("fft", "optical-sim")].invocations == 1
    assert serial.telemetry.stats[("fft", "optical-sim")].invocations == 8


def test_batched_step_cost_reduces_to_step_cost():
    c1 = LANED_4F.batched_step_cost(4096, batch=1)
    c0 = LANED_4F.step_cost(4096)
    assert c1.total_s == pytest.approx(c0.total_s)
    assert c1.conversion_s == pytest.approx(c0.conversion_s)
    # batch=1 on the MVM engine too
    m1 = ANDERSON_MVM.batched_step_cost(512, 512, batch=1)
    m0 = ANDERSON_MVM.step_cost(512, 512)
    assert m1.total_s == pytest.approx(m0.total_s)


def test_planner_batched_pricing_monotone():
    prof = CategoryProfile("fft", host_s=1.0, calls=16,
                           samples_in=16 * 4096, samples_out=16 * 4096)
    serial = plan_offload([prof], LANED_4F)
    batched = plan_offload([prof], LANED_4F, max_batch=16)
    d_s = serial.decisions[0]
    d_b = batched.decisions[0]
    assert d_b.accel_s < d_s.accel_s
    assert d_b.conversion_s <= d_s.conversion_s


# --- truly-batched execution ------------------------------------------------------

@pytest.mark.parametrize("backend", ["host", "optical-sim", "ideal"])
@pytest.mark.parametrize("category", ["fft", "conv"])
def test_batched_matches_per_item_reference_ragged(backend, category):
    """ONE batched invocation per group must reproduce the per-item path on
    every backend — including the ragged tail (K=7, max_batch=3 -> 3+3+1)."""
    imgs = _imgs(7)
    k = jnp.zeros((64, 64)).at[0, 0].set(0.5).at[1, 2].set(0.25)
    spec = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)
    kw = dict(kernel=k) if category == "conv" else {}
    bat = OffloadExecutor(spec, max_batch=3, default_backend=backend)
    hs = [bat.submit(category, im, **kw) for im in imgs]
    bat.flush()
    ser = OffloadExecutor(spec, max_batch=1, default_backend=backend)
    ss = [ser.submit(category, im, **kw) for im in imgs]
    ser.flush()
    for hb, hsr in zip(hs, ss):
        np.testing.assert_allclose(hb.value, hsr.value, rtol=1e-5, atol=1e-5)
    st = bat.telemetry.stats[(category, backend)]
    assert st.invocations == 3 and st.calls == 7
    assert ser.telemetry.stats[(category, backend)].invocations == 7


@pytest.mark.parametrize("backend", ["host", "optical-sim"])
def test_batched_matmul_matches_per_item_reference(backend):
    key = jax.random.PRNGKey(5)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (16, 32))
          for i in range(5)]
    w = jax.random.normal(jax.random.fold_in(key, 99), (32, 8))
    spec = dataclasses.replace(ANDERSON_MVM, adc=HI_FI_ADC)
    bat = OffloadExecutor(spec, max_batch=2, default_backend=backend)
    hs = [bat.submit("matmul", x, weights=w) for x in xs]
    bat.flush()
    ser = OffloadExecutor(spec, max_batch=1, default_backend=backend)
    ss = [ser.submit("matmul", x, weights=w) for x in xs]
    ser.flush()
    for hb, hsr in zip(hs, ss):
        np.testing.assert_allclose(hb.value, hsr.value, rtol=1e-5, atol=1e-5)
    assert bat.telemetry.stats[("matmul", backend)].invocations == 3  # 2+2+1


def test_flush_async_readiness_ordering_and_drain():
    imgs = _imgs(10)
    ex = OffloadExecutor(LANED_4F, max_batch=4, pipeline_depth=2)
    hs = [ex.submit("fft", im) for im in imgs]
    done = ex.flush_async()
    # handles fill immediately (async values), in submission order
    assert done == hs
    assert all(h.ready for h in hs)
    # at most pipeline_depth invocations remain unretired
    assert ex.in_flight <= 2
    ex.drain()
    assert ex.in_flight == 0
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.invocations == 3 and st.calls == 10  # 4+4+2: ragged tail
    ser = OffloadExecutor(LANED_4F, max_batch=1, pipeline_depth=1)
    ss = [ser.submit("fft", im) for im in imgs]
    ser.flush()
    for hb, hsr in zip(hs, ss):
        np.testing.assert_allclose(hb.value, hsr.value, rtol=1e-5, atol=1e-7)


def test_flush_async_wait_and_done():
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, max_batch=2, pipeline_depth=2)
    hs = [ex.submit("fft", im) for im in imgs]
    ex.flush_async()
    h = hs[-1]
    h.wait()             # retires its invocation: telemetry recorded
    assert h.done()
    assert ex.in_flight == 0
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 2
    # get() on an already-filled async result also lands its telemetry
    hs2 = [ex.submit("fft", im) for im in imgs[:2]]
    ex.flush_async()
    _ = hs2[0].get()
    assert hs2[0].done()


def test_flush_async_empty_flush():
    """Flushing an empty queue is a no-op: no handles, no dispatches, no
    telemetry — and it must not disturb invocations already in flight."""
    ex = OffloadExecutor(LANED_4F, max_batch=4, pipeline_depth=2)
    assert ex.flush_async() == [] and ex.flush() == []
    assert ex.in_flight == 0 and not ex.telemetry.stats
    hs = [ex.submit("fft", im) for im in _imgs(2)]
    ex.flush_async()
    inflight_before = ex.in_flight
    assert ex.flush_async() == []           # empty: in-flight untouched
    assert ex.in_flight == inflight_before
    ex.drain()
    assert all(h.done() for h in hs)


def test_drain_called_twice_is_idempotent():
    ex = OffloadExecutor(LANED_4F, max_batch=2, pipeline_depth=2)
    [ex.submit("fft", im) for im in _imgs(4)]
    ex.flush_async()
    ex.drain()
    st = ex.telemetry.stats[("fft", "optical-sim")]
    calls, invocations = st.calls, st.invocations
    ex.drain()                               # nothing left: pure no-op
    assert ex.in_flight == 0
    assert st.calls == calls and st.invocations == invocations


def test_wait_on_already_retired_result():
    """wait() on a result whose invocation already retired must be a
    cheap no-op: no re-blocking of the pipeline, no double telemetry."""
    ex = OffloadExecutor(LANED_4F, max_batch=4, pipeline_depth=2)
    hs = [ex.submit("fft", im) for im in _imgs(4)]
    ex.flush()                               # everything retired
    st = ex.telemetry.stats[("fft", "optical-sim")]
    recorded = (st.calls, st.invocations, st.wall_s)
    for h in hs:
        assert h.wait() is h and h.done()    # idempotent, still done
        assert h.wait().value is h.value
    assert (st.calls, st.invocations, st.wall_s) == recorded


def test_interleaved_submit_during_inflight_pipeline():
    """Submitting while earlier invocations are still in flight must not
    lose, reorder, or double-retire anything."""
    imgs = _imgs(6)
    ex = OffloadExecutor(LANED_4F, max_batch=2, pipeline_depth=2)
    first = [ex.submit("fft", im) for im in imgs[:4]]
    ex.flush_async()                         # 2 invocations, <= 2 in flight
    assert ex.in_flight == 2
    # interleave: new submits while the pipeline is full
    second = [ex.submit("fft", im) for im in imgs[4:]]
    assert ex.pending == 2 and all(not h.ready for h in second)
    ex.flush_async()                         # dispatching retires the oldest
    assert ex.in_flight <= 2
    ex.drain()
    assert ex.in_flight == 0
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.calls == 6 and st.invocations == 3
    ser = OffloadExecutor(LANED_4F, max_batch=1, pipeline_depth=1)
    ss = [ser.submit("fft", im) for im in imgs]
    ser.flush()
    for hb, hsr in zip(first + second, ss):
        np.testing.assert_allclose(hb.value, hsr.value, rtol=1e-5, atol=1e-7)


def test_pipeline_depth_one_is_serial():
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, max_batch=1, pipeline_depth=1)
    hs = [ex.submit("fft", im) for im in imgs]
    ex.flush_async()
    # depth 1: every dispatch retired the previous one; at most 1 in flight
    assert ex.in_flight <= 1
    ex.drain()
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 4
    for h in hs:
        assert h.done()


def test_per_category_max_batch_and_warm_batched():
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, max_batch=8)
    ex.set_max_batch("fft", 2)
    assert ex.max_batch_for("fft") == 2
    assert ex.max_batch_for("conv") == 8
    with pytest.raises(ValueError):
        ex.set_max_batch("fft", 0)
    # warm primes BOTH the single-item and the batched stack shapes
    # without recording telemetry (the satellite fix: the first real
    # batched flush must not pay compilation)
    ex.warm("fft", imgs[0])
    assert not ex.telemetry.stats
    hs = [ex.submit("fft", im) for im in imgs]
    ex.flush()
    assert ex.telemetry.stats[("fft", "optical-sim")].invocations == 2


# --- the pipelined cost model -----------------------------------------------------

def test_batched_step_cost_pipeline_overlap():
    n = LANED_4F.usable_pixels  # one full aperture frame per call
    plain = LANED_4F.batched_step_cost(n, batch=4)
    piped = LANED_4F.batched_step_cost(n, batch=4, pipeline_depth=2)
    # overlap strictly helps across 4 frames, but can never beat either
    # side running alone
    assert piped.total_s < plain.total_s
    write = plain.dac_s
    read = plain.adc_s + plain.analog_s
    assert piped.total_s > max(write, read)
    # nothing to overlap within a single frame; batch=1 is untouched
    one = LANED_4F.batched_step_cost(4096, batch=1, pipeline_depth=2)
    assert one.total_s == pytest.approx(LANED_4F.step_cost(4096).total_s)
    # MVM engine: double-buffered streaming beats the serial sum too
    m_plain = ANDERSON_MVM.batched_step_cost(512, 512, batch=8)
    m_piped = ANDERSON_MVM.batched_step_cost(512, 512, batch=8,
                                             pipeline_depth=2)
    assert m_piped.total_s < m_plain.total_s


# --- the telemetry -> plan loop ---------------------------------------------------

def test_telemetry_profiles_reproduce_hand_profiled_plan():
    """Executing through the runtime's host backend must yield profiles
    whose plan matches the seed repo's manual OpProfiler methodology."""
    imgs = _imgs(6)

    def host_fft(x):
        return jnp.abs(jnp.fft.fft2(x, norm="ortho")) ** 2

    # hand path (seed methodology)
    prof = OpProfiler()
    prof.start()
    for im in imgs:
        prof.run("fft", host_fft, im)
    prof.stop()
    hand = [CategoryProfile("fft", host_s=prof.seconds["fft"],
                            calls=prof.calls["fft"],
                            samples_in=prof.samples_in["fft"],
                            samples_out=prof.samples_out["fft"]),
            CategoryProfile("other",
                            host_s=prof.total_s - prof.seconds["fft"])]
    hand_plan = plan_offload(hand, PROTOTYPE_4F)

    # runtime path (telemetry as a side effect of execution)
    ex = OffloadExecutor(PROTOTYPE_4F, default_backend="host")
    ex.telemetry.start()
    for im in imgs:
        ex.run("fft", im)
    ex.telemetry.stop()
    measured = ex.telemetry.profiles()
    measured_plan = plan_offload(measured, PROTOTYPE_4F)

    # same observed traffic...
    by_name = {p.name: p for p in measured}
    assert by_name["fft"].calls == hand[0].calls
    assert by_name["fft"].samples_in == hand[0].samples_in
    assert by_name["fft"].samples_out == hand[0].samples_out
    # ...and the same offload verdict per category (the prototype's honest
    # conversion costs decline offload in both, the paper's conclusion)
    hand_d = {d.category: d.offload for d in hand_plan.decisions}
    measured_d = {d.category: d.offload for d in measured_plan.decisions}
    assert hand_d == measured_d
    assert measured_d["fft"] is False


def test_router_applies_plan_and_replans_from_telemetry():
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, max_batch=4)
    router = PlanRouter(ex)
    assert router.routes == {"fft": "host", "conv": "host", "matmul": "host"}
    ex.telemetry.start()
    for im in imgs:
        router.run("fft", im)
    ex.telemetry.stop()
    plan = router.replan()
    # routing table mirrors the plan's decisions exactly
    for d in plan.decisions:
        if d.category in router.routes:
            want = "optical-sim" if d.offload else "host"
            assert router.backend_for(d.category) == want
    # executing after the replan hits the routed backends
    for im in imgs:
        router.run("fft", im)
    executed = {b for (c, b) in ex.telemetry.stats if c == "fft"}
    fft_offloaded = any(d.category == "fft" and d.offload
                        for d in plan.decisions)
    assert ("optical-sim" in executed) == fft_offloaded


def test_replan_prices_at_observed_occupancy():
    """Serial traffic earns no batching credit: replan must not divide the
    per-invocation handshake by max_batch the workload never reached."""
    imgs = _imgs(6)
    ex = OffloadExecutor(LANED_4F, default_backend="host", max_batch=16)
    router = PlanRouter(ex)
    for im in imgs:            # one call per flush -> occupancy 1
        router.run("fft", im)
    assert ex.telemetry.observed_occupancy() == 1
    serial_plan = router.replan(apply=False)
    batched_plan = router.replan(apply=False, max_batch=16)
    d1 = next(d for d in serial_plan.decisions if d.category == "fft")
    d16 = next(d for d in batched_plan.decisions if d.category == "fft")
    assert d1.accel_s > d16.accel_s  # no amortization credit when serial


def test_adaptive_replan_deadline_caps_coalescing():
    """With no deadline the adaptive ceiling follows the global cap; a
    latency deadline lowers it until the modeled batched invocation fits."""
    imgs = _imgs(8)
    ex = OffloadExecutor(LANED_4F, default_backend="host", max_batch=16)
    router = PlanRouter(ex)
    for im in imgs:
        router.run("fft", im)
    router.replan()
    assert ex.max_batch_for("fft") == 16
    n_in, n_out = ex.telemetry.samples_per_call("fft")
    assert n_in == 64 * 64
    # deadline between the batch-4 and batch-8 invocation cost: halving
    # from 16 must stop at 4
    c4 = ex.spec.batched_step_cost(n_in, n_out, batch=4,
                                   pipeline_depth=2).total_s
    c8 = ex.spec.batched_step_cost(n_in, n_out, batch=8,
                                   pipeline_depth=2).total_s
    assert c4 < c8
    deadline = 0.5 * (c4 + c8)
    chosen = router.choose_max_batch(deadline_s=deadline)
    assert chosen["fft"] == 4
    router.replan(deadline_s=deadline)
    assert ex.max_batch_for("fft") == 4
    # apply=False prices without touching the executor's ceilings
    ex2 = OffloadExecutor(LANED_4F, default_backend="host", max_batch=16)
    r2 = PlanRouter(ex2)
    for im in imgs:
        r2.run("fft", im)
    r2.replan(apply=False, deadline_s=deadline)
    assert ex2.max_batch_for("fft") == 16


def test_adaptive_replan_respects_operator_caps():
    """A per-category ceiling the operator set directly is an upper bound
    replan must not clobber back to the global cap — and must survive a
    deadline-lowered replan so a later relaxed replan can restore it."""
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, default_backend="host", max_batch=16)
    router = PlanRouter(ex)
    ex.set_max_batch("fft", 8)   # operator latency bound
    for im in imgs:
        router.run("fft", im)
    router.replan()              # no deadline: adaptive pick starts at 16
    assert ex.max_batch_for("fft") == 8
    # tight deadline lowers below the operator bound...
    n_in, n_out = ex.telemetry.samples_per_call("fft")
    c2 = ex.spec.batched_step_cost(n_in, n_out, batch=2,
                                   pipeline_depth=2).total_s
    c4 = ex.spec.batched_step_cost(n_in, n_out, batch=4,
                                   pipeline_depth=2).total_s
    router.replan(deadline_s=0.5 * (c2 + c4))
    assert ex.max_batch_for("fft") == 2
    # ...and relaxing the deadline restores the operator's bound, not 16
    router.replan()
    assert ex.max_batch_for("fft") == 8


def test_choose_max_batch_prices_conv_at_four_captures():
    """The deadline check must charge conv's interferometric capture cost
    the way the backend prices it (4 reads), not the base spec's 1."""
    imgs = _imgs(4)
    ex = OffloadExecutor(LANED_4F, default_backend="host", max_batch=16)
    router = PlanRouter(ex)
    k = jnp.zeros((64, 64)).at[0, 0].set(1.0)
    for im in imgs:
        router.run("conv", im, kernel=k)
    n_in, n_out = ex.telemetry.samples_per_call("conv")
    spec4 = dataclasses.replace(LANED_4F, phase_shift_captures=4)
    # a deadline the 1-capture pricing would accept at batch 16 but the
    # true 4-capture invocation blows: the chosen depth must fit spec4
    deadline = 0.5 * (spec4.batched_step_cost(
        n_in, n_out, batch=4, pipeline_depth=2).total_s
        + spec4.batched_step_cost(n_in, n_out, batch=8,
                                  pipeline_depth=2).total_s)
    chosen = router.choose_max_batch(deadline_s=deadline)
    assert spec4.batched_step_cost(
        n_in, n_out, batch=chosen["conv"],
        pipeline_depth=2).total_s <= deadline
    assert chosen["conv"] == 4


def test_flush_async_host_results_have_valid_cost():
    """Host-routed results must honor the 'attributes valid once ready'
    contract between flush_async and drain (provisional dispatch-share
    cost, refined to the measured wall at retire)."""
    imgs = _imgs(3)
    ex = OffloadExecutor(LANED_4F, default_backend="host", max_batch=4)
    hs = [ex.submit("fft", im) for im in imgs]
    ex.flush_async()
    assert all(h.ready and h.cost is not None for h in hs)
    provisional = hs[0].cost.host_s
    assert provisional >= 0.0 and hs[0].cost.conversion_s == 0.0
    ex.drain()
    assert hs[0].cost.host_s >= provisional  # refined to full wall share


def test_deferred_retirement_does_not_bill_idle_time():
    """Host work between flush_async and drain must not be charged to the
    invocation's telemetry wall (it would poison replanning profiles)."""
    import time as _time
    imgs = _imgs(2)
    ex = OffloadExecutor(LANED_4F, max_batch=2)
    ex.warm("fft", imgs[0])  # compile time is billed to dispatch otherwise
    for im in imgs:
        ex.submit("fft", im)
    ex.flush_async()
    _time.sleep(0.05)            # unrelated host work; compute finishes
    ex.drain()
    st = ex.telemetry.stats[("fft", "optical-sim")]
    assert st.wall_s < 0.04, st.wall_s


def test_occupancy_is_per_category():
    """One category's deep batches must not credit another's serial calls
    with amortization (and vice versa)."""
    t = RuntimeTelemetry()
    for _ in range(16):   # serial: 16 invocations of 1
        t.record("matmul", "host", calls=1, samples_in=4, samples_out=4,
                 wall_s=0.001)
    t.record("fft", "host", calls=16, samples_in=64, samples_out=64,
             wall_s=0.016)  # one deep batch
    assert t.observed_occupancy("matmul") == 1
    assert t.observed_occupancy("fft") == 16


def test_warm_validates_like_submit():
    from repro.core.accelerator import ANDERSON_MVM as MVM
    ex = OffloadExecutor(MVM)
    with pytest.raises(ValueError):
        ex.warm("fft", jnp.ones((8, 8)))
    ex2 = OffloadExecutor(LANED_4F)
    with pytest.raises(ValueError):
        ex2.warm("conv", jnp.ones((8, 8)))  # kernel missing


def test_telemetry_host_rate_extrapolation():
    """A category that later ran offloaded is priced at the measured host
    rate for ALL observed calls, not just the host-executed ones."""
    t = RuntimeTelemetry()
    t.record("fft", "host", calls=4, samples_in=40, samples_out=40,
             wall_s=0.04)
    t.record("fft", "optical-sim", calls=4, samples_in=40, samples_out=40,
             wall_s=0.5, modeled=LANED_4F.step_cost(10))
    (prof,) = t.profiles(include_other=False)
    assert prof.calls == 8
    assert prof.host_s == pytest.approx(0.08)  # 0.01 s/call x 8 calls


def test_telemetry_other_bucket_ignores_post_window_traffic():
    import time as _time
    t = RuntimeTelemetry()
    t.start()
    t.record("fft", "host", calls=1, samples_in=4, samples_out=4,
             wall_s=0.005)
    _time.sleep(0.03)
    t.stop()
    # offloaded execution after the window must not eat the 'other' bucket
    t.record("fft", "optical-sim", calls=8, samples_in=32, samples_out=32,
             wall_s=5.0, modeled=LANED_4F.step_cost(4))
    other = [p for p in t.profiles() if p.name == "other"]
    assert other and other[0].host_s >= 0.02


def test_telemetry_merge_and_summary():
    a, b = RuntimeTelemetry(), RuntimeTelemetry()
    a.record("fft", "host", calls=2, samples_in=10, samples_out=10, wall_s=0.1)
    b.record("fft", "host", calls=3, samples_in=15, samples_out=15, wall_s=0.2)
    b.record("conv", "optical-sim", calls=1, samples_in=5, samples_out=5,
             wall_s=0.05, modeled=LANED_4F.step_cost(5))
    a.merge(b)
    st = a.stats[("fft", "host")]
    assert st.calls == 5 and st.samples_in == 25
    assert st.wall_s == pytest.approx(0.3)
    assert a.stats[("conv", "optical-sim")].modeled.total_s > 0
    assert "fft" in a.summary() and "conv" in a.summary()
    assert a.host_timed("fft") and not a.host_timed("conv")


# --- fidelity ---------------------------------------------------------------------

def test_fidelity_error_shrinks_with_dac_bits():
    """ISSUE acceptance: checker error is monotone nonincreasing (and
    overall strictly shrinking) as DAC resolution grows."""
    (a,) = _imgs(1)
    # 16-bit read path so the ADC error floor does not mask the DAC sweep
    adc16 = ConverterSpec(name="adc16", kind="adc", bits=16, rate_hz=1e8,
                          power_w=0.060, enob=15.0)
    errs = []
    for bits in (2, 4, 6, 8):
        dac = ConverterSpec(name=f"dac{bits}", kind="dac", bits=bits,
                            rate_hz=1e9, power_w=0.05, enob=bits - 1.0)
        spec = dataclasses.replace(LANED_4F, dac=dac, adc=adc16)
        checker = FidelityChecker()
        ex = OffloadExecutor(spec, fidelity=checker)
        ex.run("fft", a)
        errs.append(checker.reports[-1].rel_err)
    assert all(e2 <= e1 * 1.05 for e1, e2 in zip(errs, errs[1:])), errs
    assert errs[-1] < errs[0] / 4, errs


def test_fidelity_report_pairs_speedup_with_accuracy():
    (a,) = _imgs(1)
    checker = FidelityChecker()
    ex = OffloadExecutor(dataclasses.replace(LANED_4F, adc=HI_FI_ADC),
                         fidelity=checker, max_batch=4)
    handles = [ex.submit("fft", a) for _ in range(4)]
    ex.flush()
    r = handles[0]
    assert r.fidelity is not None
    assert r.fidelity.batch == 4
    assert r.fidelity.rel_err >= 0.0
    assert r.fidelity.bound > 0.0
    assert r.cost.conversion_s > 0.0  # cost and accuracy, side by side
    w = checker.worst("fft")
    assert w is not None and w.rel_err == checker.reports[0].rel_err


def test_fidelity_flags_budget_violation():
    # a 1-bit DAC cannot stay inside an 8-ENOB budget
    dac1 = ConverterSpec(name="dac1", kind="dac", bits=1, rate_hz=1e9,
                         power_w=0.05, enob=8.0)
    spec = dataclasses.replace(LANED_4F, dac=dac1, adc=HI_FI_ADC)
    checker = FidelityChecker(slack=1.0)
    ex = OffloadExecutor(spec, fidelity=checker)
    ex.run("fft", _imgs(1)[0])
    assert not checker.all_ok


def test_fidelity_vectorized_matches_per_frame_norms():
    """The batch scores in ONE reduction; the number must equal the worst
    per-frame ||got-ref|| / ||ref|| a Python loop would compute."""
    key = jax.random.PRNGKey(2)
    refs = [jax.random.uniform(jax.random.fold_in(key, i), (8, 8)) + 0.5
            for i in range(4)]
    gots = [r + 1e-3 * jax.random.normal(jax.random.fold_in(key, 50 + i),
                                         (8, 8))
            for i, r in enumerate(refs)]
    checker = FidelityChecker()
    report = checker.check("fft", "optical-sim", gots, refs, enob=8.0)
    want = max(float(np.linalg.norm(np.asarray(g - r).ravel())
                     / np.linalg.norm(np.asarray(r).ravel()))
               for g, r in zip(gots, refs))
    assert report.rel_err == pytest.approx(want, rel=1e-5)
    assert report.batch == 4


def test_fidelity_zero_norm_reference_frames():
    """A zero reference reproduced exactly scores 0 (ok); any nonzero
    output against a zero reference scores inf (always a VIOLATION) —
    neither divides by zero or reports clamp-denominator garbage."""
    z = jnp.zeros((4, 4))
    checker = FidelityChecker()
    ok = checker.check("fft", "optical-sim", [z], [z], enob=8.0)
    assert ok.rel_err == 0.0 and ok.ok
    bad = checker.check("fft", "optical-sim", [jnp.ones((4, 4))], [z],
                        enob=8.0)
    assert bad.rel_err == float("inf") and not bad.ok
    # mixed batch: the zero-norm frame must not mask the fabricated one
    mixed = checker.check("fft", "optical-sim",
                          [z, jnp.ones((4, 4))], [z, z], enob=8.0)
    assert mixed.rel_err == float("inf")


def test_fidelity_nonpositive_enob_infinite_bound():
    """enob <= 0 promises nothing: the budget is infinite and even a
    garbage result is 'within' it (the gate then never vetoes)."""
    from repro.core.conversion import enob_error_bound
    assert enob_error_bound(0.0) == float("inf")
    assert enob_error_bound(-3.0) == float("inf")
    checker = FidelityChecker()
    r = checker.check("fft", "optical-sim", [jnp.ones((4, 4))],
                      [2.0 * jnp.ones((4, 4))], enob=0.0)
    assert r.bound == float("inf") and r.ok
    # ...including the fabricated-signal inf: inf <= inf
    r2 = checker.check("fft", "optical-sim", [jnp.ones((4, 4))],
                       [jnp.zeros((4, 4))], enob=-1.0)
    assert r2.ok


def test_fidelity_sample_every_bounds_shadowing():
    """sample_every=N scores every Nth shadowed batch per category; the
    skipped batches keep the async pipeline (no forced sync retire)."""
    (a,) = _imgs(1)
    checker = FidelityChecker(sample_every=3)
    ex = OffloadExecutor(dataclasses.replace(LANED_4F, adc=HI_FI_ADC),
                         fidelity=checker, max_batch=2, pipeline_depth=2)
    handles = []
    for _ in range(6):           # 6 flushes -> 6 shadowed-batch candidates
        h = ex.submit("fft", a)
        ex.flush_async()
        handles.append(h)
    ex.drain()
    assert len(checker.reports) == 2          # batches 0 and 3 scored
    assert handles[0].fidelity is not None
    assert handles[1].fidelity is None        # skipped: no report attached
    assert handles[3].fidelity is not None
    with pytest.raises(ValueError):
        FidelityChecker(sample_every=0)


def test_fidelity_sampling_is_per_category():
    checker = FidelityChecker(sample_every=2)
    assert checker.should_check("fft")        # fft #0 -> scored
    assert checker.should_check("conv")       # conv #0 -> scored
    assert not checker.should_check("fft")    # fft #1 -> skipped
    assert checker.should_check("fft")        # fft #2 -> scored


# --- lazy handles and caches ------------------------------------------------------

def test_result_get_triggers_flush():
    ex = OffloadExecutor(LANED_4F, max_batch=8)
    h = ex.submit("fft", _imgs(1)[0])
    assert not h.ready and ex.pending == 1
    value = h.get()
    assert h.ready and ex.pending == 0
    assert value is h.value


def test_factor_and_mask_caches_are_shared():
    imgs = _imgs(2, shape=(64, 32))
    ex = OffloadExecutor(LANED_4F)
    # factor matrices are cached per (shape, resolved block layout) —
    # consumed by the batched Pallas fft path on TPU; off-TPU the backend
    # takes the fused XLA route and never touches them, so exercise the
    # cache directly.  Same size + same layout -> one shared entry; a
    # different block layout is a fresh cache KEY (the stale-kernel fix:
    # replanning tile_k must never pair a recompiled kernel with factors
    # cached under the old layout) but aliases the same arrays — the
    # values depend only on n, so layouts share one O(n^2) pair.
    blocks = (1, 64, 32, 32)
    a = ex.ctx.factors(64, blocks)
    b = ex.ctx.factors(32, blocks)
    assert ex.ctx.factors(64, blocks) is a and ex.ctx.factors(32, blocks) is b
    assert (64,) + blocks in ex.ctx.factor_cache
    assert (32,) + blocks in ex.ctx.factor_cache
    other = ex.ctx.factors(64, (2, 64, 32, 32))
    assert (64, 2, 64, 32, 32) in ex.ctx.factor_cache
    assert other is a                    # aliased, never recomputed
    k = jnp.zeros((64, 32)).at[0, 0].set(1.0)
    ex.run("conv", imgs[0], kernel=k)
    ex.run("conv", imgs[1], kernel=k)
    assert len(ex.ctx.mask_cache) == 1


# --- serving-engine hook ----------------------------------------------------------

def test_serving_engine_batches_aux_offload_work():
    """The opt-in serving hook coalesces aux FFT submissions from different
    requests into one boundary crossing per decode step."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ex = OffloadExecutor(LANED_4F, max_batch=8)
    router = PlanRouter(ex)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=32,
                           offload=router)
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2))
    handles = [engine.submit_aux("fft", im) for im in _imgs(3, seed=9)]
    assert engine.pending_aux == 3
    assert not engine.idle()
    engine.run_to_completion(max_steps=8)
    assert all(h.ready for h in handles)
    # all three aux calls shared one host-backend invocation (batched)
    assert ex.telemetry.stats[("fft", "host")].invocations == 1
    assert ex.telemetry.stats[("fft", "host")].calls == 3


def test_serving_engine_rejects_aux_without_runtime():
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=16)
    with pytest.raises(RuntimeError):
        engine.submit_aux("fft", jnp.ones((8, 8)))


def test_warm_restores_runtime_context_n_devices():
    """warm() writes the category's device fan-out into the shared context
    for shard-shape priming; like the tracer and watchdog it suppresses,
    it must put the context back exactly as it found it."""
    ex = OffloadExecutor(LANED_4F, max_batch=4, n_devices=1)
    ex.set_n_devices("fft", 3)
    before = ex.ctx.n_devices
    ex.warm("fft", _imgs(1)[0])
    assert ex.ctx.n_devices == before


def test_telemetry_merge_reset_cover_every_field():
    """Field-by-field round-trip: merging a populated telemetry into a
    fresh one must reproduce EVERY attribute, and reset() must return to
    the pristine state.  The explicit name list is the tripwire — adding
    a field to RuntimeTelemetry without teaching merge()/reset() (and
    this list) about it fails here, not silently in production."""
    import collections

    expected = sorted([
        "stats", "device_stats", "_submits", "_latency", "fault_counts",
        "_recovery", "residency_counts", "delta_stats", "engine_windows",
        "_t0", "_window_s", "_in_window_s",
    ])
    tel = RuntimeTelemetry()
    assert sorted(vars(tel)) == expected, (
        "RuntimeTelemetry grew a field this test (and likely merge/reset) "
        "does not cover")

    # populate every field through the public API
    tel.start()
    tel.note_submit("fft", t=0.0)
    tel.note_submit("fft", t=0.5)
    tel.record("fft", "optical-sim", calls=2, samples_in=8192,
               samples_out=8192, wall_s=0.5,
               modeled=LANED_4F.batched_step_cost(4096, batch=2),
               per_device=[(4096, 4096), (4096, 4096)],
               bytes_in=32768, bytes_out=32768)
    tel.record("conv", "host", calls=1, samples_in=4096, samples_out=4096,
               wall_s=0.1)
    tel.note_fault("fft", "error")
    tel.note_fault("fft", "straggle")
    tel.note_recovery("fft", 0.25)
    tel.note_residency("fft", "hit")
    tel.note_residency("fft", "miss")
    tel.note_residency("conv", "eviction")
    tel.note_delta("fft", flip_fraction=0.125)
    tel.note_delta("fft")
    tel.note_delta("conv", flip_fraction=0.25)
    tel.note_window("fft", "optical-sim", in_flight=2, depth=2)
    tel.note_window("conv", "host", in_flight=1, depth=3)
    tel.stop()

    def norm(v):
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            return {f.name: norm(getattr(v, f.name))
                    for f in dataclasses.fields(v)}
        if isinstance(v, dict):
            return {k: norm(x) for k, x in sorted(v.items(), key=repr)}
        if isinstance(v, (collections.deque, list, tuple)):
            return [norm(x) for x in v]
        if hasattr(v, "__dict__") and not isinstance(v, (int, float, str)):
            return norm(vars(v))
        return v

    def snapshot(t):
        return {name: norm(val) for name, val in vars(t).items()}

    merged = RuntimeTelemetry()
    merged.merge(tel)
    assert snapshot(merged) == snapshot(tel)

    # and a second merge doubles the additive fields (spot-check)
    merged.merge(tel)
    assert merged.stats[("fft", "optical-sim")].calls == 4
    assert merged.fault_counts["fft"]["error"] == 2
    assert merged.residency_counts["fft"]["hit"] == 2
    assert merged.delta_stats["fft"].frames == 2
    assert merged.delta_stats["fft"].full == 2
    assert merged.delta_stats["fft"].flip_sum == pytest.approx(0.25)

    tel.reset()
    assert snapshot(tel) == snapshot(RuntimeTelemetry())


# --- engines= composition mode (per-engine pipeline windows, priced) -----------


@pytest.mark.parametrize("spec,n_in", [(LANED_4F, 4096),
                                       (ANDERSON_MVM, 512)])
def test_single_engine_composition_equals_pipelined_price(spec, n_in):
    """One engine composed alone IS the pipelined price: the cross-engine
    collapse and the pipeline_depth collapse share one overlap discipline
    (`_compose_sides`), so a degenerate engines= call must agree exactly."""
    for depth in (1, 2):
        direct = spec.batched_step_cost(n_in, batch=8, pipeline_depth=depth)
        composed = spec.batched_step_cost(n_in, engines={
            "only": {"n_in": n_in, "batch": 8, "pipeline_depth": depth}})
        assert composed.total_s == pytest.approx(direct.total_s, rel=1e-12)
        assert composed.dac_s + composed.adc_s == \
            pytest.approx(direct.dac_s + direct.adc_s, rel=1e-12)


@pytest.mark.parametrize("spec,n_in", [(LANED_4F, 4096),
                                       (ANDERSON_MVM, 512)])
def test_multi_engine_composition_bounds(spec, n_in):
    """Two engines composed overlap reads behind writes: the composed wall
    is never more than the serial sum and never less than either engine
    alone (writes serialize on the shared host staging resource)."""
    kw_a = {"n_in": n_in, "batch": 8, "pipeline_depth": 2}
    kw_b = {"n_in": n_in, "batch": 4, "pipeline_depth": 2}
    a = spec.batched_step_cost(n_in, batch=8, pipeline_depth=2)
    b = spec.batched_step_cost(n_in, batch=4, pipeline_depth=2)
    both = spec.batched_step_cost(n_in, engines={"a": kw_a, "b": kw_b})
    assert both.total_s <= a.total_s + b.total_s + 1e-15
    assert both.total_s >= max(a.total_s, b.total_s) - 1e-15
    # pre-priced StepCost entries compose too (the executor's path when
    # the per-engine prices were already computed at dispatch)
    pre = spec.batched_step_cost(n_in, engines={"a": a, "b": b})
    assert pre.total_s <= a.total_s + b.total_s + 1e-15


def test_engines_mode_validation():
    with pytest.raises(ValueError):
        LANED_4F.batched_step_cost(4096, engines={})
    with pytest.raises(ValueError):
        LANED_4F.batched_step_cost(4096, engines={
            "a": {"n_in": 4096, "warp_factor": 9}})
    with pytest.raises(ValueError):
        ANDERSON_MVM.batched_step_cost(512, engines={
            "a": {"n_in": 512, "warp_factor": 9}})


# --- per-engine pipeline windows: executor accessors ---------------------------


def test_pipeline_window_accessors_and_validation():
    ex = OffloadExecutor(LANED_4F, pipeline_depth=3)
    assert ex.pipeline_window_for("fft") == 3     # global default
    ex.set_pipeline_window("fft", 1)
    assert ex.pipeline_window_for("fft") == 1
    assert ex.pipeline_window_for("conv") == 3    # untouched category
    assert ex.category_windows() == {"fft": 1}
    with pytest.raises(ValueError):
        ex.set_pipeline_window("fft", 0)


def test_window_occupancy_telemetry_recorded_per_engine():
    """Every dispatch notes its engine's in-flight occupancy; two engines
    in one flush land separate WindowStats rows."""
    imgs = _imgs(4, (16, 16))
    k = jnp.zeros((16, 16)).at[0, 0].set(1.0)
    ex = OffloadExecutor(LANED_4F, max_batch=2, pipeline_depth=2)
    for im in imgs:
        ex.submit("fft", im, backend="host")
        ex.submit("conv", im, kernel=k, backend="host")
    ex.flush()
    tel = ex.telemetry
    assert tel.engine_windows[("fft", "host")].dispatches == 2
    assert tel.engine_windows[("conv", "host")].dispatches == 2
    assert 1.0 <= tel.window_occupancy("fft") <= 2.0
    assert tel.engine_windows[("fft", "host")].depth == 2
