"""Boundary-attributed tracing: span-tree invariants, drift, export.

The invariants this file locks down (the ISSUE's acceptance criteria):

* every submitted call lands in exactly **one** invocation span
  (``call_ids`` partition the submission index space) under looped,
  batched, pipelined (``flush_async``), scheduler-held, sharded, and
  memory-budgeted tiled dispatch;
* sync leaf spans (stage / compute / fidelity-shadow) nest inside their
  invocation's window, and charged compute spans never overlap within the
  device lane — the charged decomposition satisfies
  ``stage + compute == wall`` exactly;
* under a shared ``ManualClock`` the scheduler's hold is traced *exactly*
  (a group held 30 ms yields a held span of exactly 0.030 s) with the
  release reason (full / due / futile) on the span;
* the Perfetto export is well-formed (metadata per lane, matched ``b``/``e``
  async ids, durations on ``X`` slices) and a traced 512x512 tiled+sharded
  flush reconciles its per-stage charged sums with the measured flush wall
  to within 10%;
* histograms: empty -> NaN, single sample -> exact, merge is associative
  and layout-checked; telemetry percentiles survive merge/reset and
  ``stop()`` is idempotent.
"""

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import PROTOTYPE_4F
from repro.core.conversion import ConverterSpec
from repro.runtime import (
    Counter,
    FidelityChecker,
    Histogram,
    ManualClock,
    MemoryBudget,
    MetricsRegistry,
    OffloadExecutor,
    OffloadScheduler,
    PlanRouter,
    RuntimeTelemetry,
    Span,
    Tracer,
    drift_report,
    reconcile,
    stage_sums,
    summarize,
    to_trace_events,
    write_trace,
)

LANED_4F = dataclasses.replace(
    PROTOTYPE_4F, name="laned-4f", interface_latency_s=1.0e-3,
    dac_lanes=48, adc_lanes=48,
    slm_interface_hz=100e6, camera_interface_hz=100e6)

HI_FI_ADC = ConverterSpec(name="hifi-adc", kind="adc", bits=12,
                          rate_hz=5.0e8, power_w=0.060, enob=10.5)


def _imgs(n, shape=(32, 32), seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape)
            for i in range(n)]


def _invocations(spans):
    return [s for s in spans if s.name == "invocation"]


def _assert_tree_invariants(spans, n_calls):
    """The span-tree contract every dispatch mode must satisfy."""
    by_id = {s.span_id: s for s in spans}
    invs = _invocations(spans)
    # every call in exactly one invocation: call_ids partition 1..n
    ids = [cid for s in invs for cid in s.attrs["call_ids"]]
    assert sorted(ids) == list(range(1, n_calls + 1)), ids
    for s in spans:
        assert s.t1 is not None and s.t1 >= s.t0
        if s.parent_id is not None and s.parent_id in by_id:
            # children inherit the root's trace id
            assert s.trace_id == by_id[s.parent_id].trace_id
    for inv in invs:
        kids = [s for s in spans if s.parent_id == inv.span_id]
        names = {s.name for s in kids}
        assert "stage" in names and "compute" in names, names
        for k in kids:
            if k.kind == "sync":  # leaf spans nest inside the container
                assert k.t0 >= inv.t0 - 1e-9 and k.t1 <= inv.t1 + 1e-9, \
                    (k.name, k.t0, k.t1, inv.t0, inv.t1)
        # the charged decomposition is exact, not approximate
        assert inv.attrs["stage_s"] + inv.attrs["compute_s"] == \
            pytest.approx(inv.attrs["wall_s"], abs=1e-12)
    # charged compute spans never overlap within the device lane
    comps = sorted((s for s in spans
                    if s.name == "compute" and s.lane == "device"),
                   key=lambda s: s.t0)
    for a, b in zip(comps, comps[1:]):
        assert b.t0 >= a.t1 - 1e-12, (a.t1, b.t0)


# --- span-tree invariants across dispatch modes ---------------------------------

def test_batched_flush_span_tree():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=8, tracer=tracer)
    imgs = _imgs(8)
    ex.warm("fft", imgs[0], batch=8)
    tracer.clear()  # warm must not leave orphan spans behind
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    spans = tracer.spans()
    invs = _invocations(spans)
    assert len(invs) == 1 and invs[0].attrs["batch"] == 8
    assert invs[0].attrs["reason"] == "flush"
    assert len([s for s in spans if s.name == "submit"]) == 8
    _assert_tree_invariants(spans, 8)
    # the invocation carries the modeled decomposition the drift joins
    assert invs[0].attrs["modeled_total_s"] > 0.0


def test_looped_flushes_one_tree_per_call():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=8, tracer=tracer)
    imgs = _imgs(4)
    ex.warm("fft", imgs[0])
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
        ex.flush()
    spans = tracer.spans()
    invs = _invocations(spans)
    assert len(invs) == 4 and all(s.attrs["batch"] == 1 for s in invs)
    _assert_tree_invariants(spans, 4)


def test_pipelined_flush_async_span_tree():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=4, pipeline_depth=2,
                         tracer=tracer)
    imgs = _imgs(12)
    ex.warm("fft", imgs[0], batch=4)
    tracer.clear()
    handles = [ex.submit("fft", im) for im in imgs]
    ex.flush_async()
    ex.drain()
    assert all(h.done() for h in handles)
    spans = tracer.spans()
    invs = _invocations(spans)
    assert len(invs) == 3  # 12 calls through max_batch=4
    _assert_tree_invariants(spans, 12)


def test_sharded_dispatch_emits_per_device_children():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=8, n_devices=4,
                         default_backend="sharded", tracer=tracer)
    imgs = _imgs(8)
    ex.warm("fft", imgs[0], batch=8)
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    spans = tracer.spans()
    _assert_tree_invariants(spans, 8)
    scatters = [s for s in spans if s.name == "scatter"]
    assert sorted(s.lane for s in scatters) == \
        ["device0", "device1", "device2", "device3"]
    assert sum(s.attrs["frames"] for s in scatters) == 8
    # scatter spans nest under the stage span of THE invocation
    by_id = {s.span_id: s for s in spans}
    for sc in scatters:
        stage = by_id[sc.parent_id]
        assert stage.name == "stage"
        assert by_id[stage.parent_id].name == "invocation"
    # and the drift report attributes their staging per device
    rep = drift_report(spans)
    assert set(rep.per_device_s) == {0, 1, 2, 3}
    assert all(v > 0.0 for v in rep.per_device_s.values())


def test_tiled_dispatch_one_invocation_per_tile():
    imgs = _imgs(8, shape=(64, 64))
    # budget sized to 2-frame tiles: the 8-call group streams as 4 tiles
    budget = MemoryBudget(2 * 2 * 64 * 64 * 4, source="manual", reserve=1.0)
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=8, mem_budget=budget,
                         tracer=tracer)
    ex.warm("fft", imgs[0], batch=8)
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    spans = tracer.spans()
    invs = _invocations(spans)
    assert len(invs) > 1, "budget did not split the group"
    tiles = sorted(s.attrs["tile"] for s in invs)
    assert tiles == list(range(len(invs)))
    assert all(s.attrs["tiles"] == len(invs) for s in invs)
    _assert_tree_invariants(spans, 8)


def test_fidelity_shadow_span_recorded():
    tracer = Tracer()
    spec = dataclasses.replace(LANED_4F, adc=HI_FI_ADC)
    ex = OffloadExecutor(spec, fidelity=FidelityChecker(), max_batch=4,
                         tracer=tracer)
    imgs = _imgs(4)
    ex.warm("fft", imgs[0], batch=4)
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    spans = tracer.spans()
    (inv,) = _invocations(spans)
    shadows = [s for s in spans if s.name == "fidelity-shadow"]
    assert len(shadows) == 1 and shadows[0].parent_id == inv.span_id
    assert inv.attrs["shadow_s"] > 0.0
    _assert_tree_invariants(spans, 4)


def test_warm_does_not_trace():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=4, tracer=tracer)
    ex.warm("fft", _imgs(1)[0], batch=4)
    assert tracer.spans() == []


def test_untraced_executor_has_no_tracer_anywhere():
    ex = OffloadExecutor(LANED_4F, max_batch=4)
    assert ex.tracer is None and ex.ctx.tracer is None
    for im in _imgs(4):
        ex.submit("fft", im)
    ex.flush()  # no-op path: nothing to assert beyond not crashing


# --- scheduler: exact holds and release reasons under a ManualClock -------------

def test_held_span_exact_duration_and_due_reason():
    clk = ManualClock()
    tracer = Tracer(clock=clk)
    ex = OffloadExecutor(LANED_4F, max_batch=8, clock=clk, tracer=tracer)
    sched = OffloadScheduler(ex, deadline_s=0.03, clock=clk)
    imgs = _imgs(2)
    ex.warm("fft", imgs[0], batch=2)
    tracer.clear()
    sched.submit("fft", imgs[0])
    sched.submit("fft", imgs[1])
    clk.advance(0.03)
    sched.poll()          # deadline reached: due release
    (rel,) = [s for s in tracer.spans() if s.name == "release"]
    assert rel.attrs["reason"] == "due"
    (held,) = [s for s in tracer.spans() if s.name == "held"]
    # exact under the shared ManualClock: held precisely one deadline
    assert held.duration_s == pytest.approx(0.03, abs=1e-12)
    assert held.lane == "sched" and held.attrs["reason"] == "due"
    ex.drain()                   # retire: closes the invocation container
    (inv,) = _invocations(tracer.spans())
    assert held.parent_id == inv.span_id
    assert inv.attrs["hold_s"] == pytest.approx(0.03, abs=1e-12)
    assert tracer.metrics.counter("release", reason="due").value == 1


def test_release_reason_full_when_group_fills():
    clk = ManualClock()
    tracer = Tracer(clock=clk)
    ex = OffloadExecutor(LANED_4F, max_batch=2, clock=clk, tracer=tracer)
    sched = OffloadScheduler(ex, deadline_s=10.0, clock=clk)
    imgs = _imgs(2)
    ex.warm("fft", imgs[0], batch=2)
    tracer.clear()
    sched.submit("fft", imgs[0])
    clk.advance(0.01)
    sched.submit("fft", imgs[1])   # group full: released by submit
    (rel,) = [s for s in tracer.spans() if s.name == "release"]
    assert rel.attrs["reason"] == "full"
    (held,) = [s for s in tracer.spans() if s.name == "held"]
    assert held.duration_s == pytest.approx(0.01, abs=1e-12)


def test_release_reason_futile_when_arrivals_too_sparse():
    clk = ManualClock()
    tracer = Tracer(clock=clk)
    ex = OffloadExecutor(LANED_4F, max_batch=8, clock=clk, tracer=tracer)
    sched = OffloadScheduler(ex, deadline_s=0.5, clock=clk)
    imgs = _imgs(8)
    ex.warm("fft", imgs[0])
    tracer.clear()
    # teach the rate estimator arrivals are ~10x slower than the deadline
    for im in imgs[:6]:
        clk.advance(5.0)
        sched.submit("fft", im)
        sched.poll()
    reasons = {s.attrs["reason"]
               for s in tracer.spans() if s.name == "release"}
    assert "futile" in reasons, reasons


# --- tracer mechanics ------------------------------------------------------------

def test_ring_buffer_drops_oldest_and_counts():
    tr = Tracer(capacity=3)
    for i in range(5):
        tr.instant(f"e{i}")
    assert tr.dropped == 2
    assert [s.name for s in tr.spans()] == ["e2", "e3", "e4"]
    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0


def test_tracer_rejects_bad_capacity():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_lexical_nesting_and_trace_id_inheritance():
    clk = ManualClock()
    tr = Tracer(clock=clk)
    with tr.span("outer") as outer:
        clk.advance(1.0)
        with tr.span("inner", lane="device") as inner:
            clk.advance(0.5)
        assert tr.current() is outer
    assert tr.current() is None
    assert inner.parent_id == outer.span_id
    assert inner.trace_id == outer.trace_id == outer.span_id
    assert inner.duration_s == pytest.approx(0.5)
    assert outer.duration_s == pytest.approx(1.5)
    # completion order: inner closes first
    assert [s.name for s in tr.spans()] == ["inner", "outer"]


def test_end_clamps_reversed_clock():
    tr = Tracer(clock=ManualClock())
    s = tr.begin("x")
    done = tr.end(s, t1=s.t0 - 5.0)
    assert done.t1 == done.t0 and done.duration_s == 0.0


def test_record_clamps_and_commits():
    tr = Tracer()
    s = tr.record("w", 2.0, 1.0)
    assert s.t0 == 2.0 and s.t1 == 2.0
    assert tr.find("w") == [s]


# --- histograms -------------------------------------------------------------------

def test_histogram_empty_is_nan():
    h = Histogram()
    assert math.isnan(h.percentile(50)) and math.isnan(h.mean)


def test_histogram_single_sample_is_exact():
    h = Histogram()
    h.record(3.7e-4)
    # clamped to the observed [min, max]: one sample reports itself
    for p in (0.0, 50.0, 99.0, 100.0):
        assert h.percentile(p) == pytest.approx(3.7e-4, rel=0, abs=0)


def test_histogram_percentile_within_one_bin():
    h = Histogram()
    vals = [1e-4 * (1 + 0.01 * i) for i in range(100)]
    for v in vals:
        h.record(v)
    rel_err_bound = 10 ** (1 / h.bins_per_decade) - 1
    exact = sorted(vals)[49]
    assert h.percentile(50) == pytest.approx(exact, rel=rel_err_bound)
    with pytest.raises(ValueError):
        h.percentile(101.0)


def test_histogram_merge_associative_and_exact():
    rng = np.random.default_rng(7)
    samples = [rng.uniform(1e-6, 1e-2, 50) for _ in range(3)]
    hs = []
    for chunk in samples:
        h = Histogram()
        for v in chunk:
            h.record(float(v))
        hs.append(h)
    ab_c = hs[0].copy()
    ab_c.merge(hs[1])
    ab_c.merge(hs[2])
    bc = hs[1].copy()
    bc.merge(hs[2])
    a_bc = hs[0].copy()
    a_bc.merge(bc)
    assert ab_c.counts == a_bc.counts
    assert ab_c.n == a_bc.n == 150
    assert ab_c.min == a_bc.min and ab_c.max == a_bc.max
    one = Histogram()
    for chunk in samples:
        for v in chunk:
            one.record(float(v))
    assert one.counts == ab_c.counts  # merge == having seen all samples


def test_histogram_merge_rejects_layout_mismatch():
    a, b = Histogram(), Histogram(bins_per_decade=8)
    with pytest.raises(ValueError):
        a.merge(b)
    with pytest.raises(ValueError):
        Histogram(lo=1.0, hi=0.5)


def test_metrics_registry_merge_and_reset():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("release", reason="full").inc(2)
    b.counter("release", reason="full").inc(3)
    b.counter("release", reason="due").inc()
    b.histogram("wall").record(1e-3)
    a.merge(b)
    assert a.counter("release", reason="full").value == 5
    assert a.counter("release", reason="due").value == 1
    assert a.histogram("wall").n == 1
    # merged histograms are copies: mutating the source must not alias
    b.histogram("wall").record(1e-3)
    assert a.histogram("wall").n == 1
    a.reset()
    assert a.counters() == {} and a.histograms() == {}


# --- telemetry: idempotent stop + percentile round trips --------------------------

def test_stop_without_start_is_idempotent():
    t = RuntimeTelemetry()
    assert t.stop() == 0.0        # never started: no RuntimeError
    assert t.stop() == 0.0        # and again
    t.start()
    w = t.stop()
    assert w >= 0.0
    assert t.stop() == pytest.approx(w)  # repeated stop keeps the window


def test_reset_mid_window_then_stop():
    t = RuntimeTelemetry()
    t.start()
    t.reset()                     # reset while the window is open
    assert t.stop() == 0.0        # the open window died with the reset


def test_telemetry_percentiles_per_category_backend():
    t = RuntimeTelemetry()
    for w in (1e-3, 2e-3, 3e-3):
        t.record("fft", "optical-sim", calls=1, samples_in=64,
                 samples_out=64, wall_s=w)
    t.record("conv", "host", calls=1, samples_in=64, samples_out=64,
             wall_s=5e-3)
    pct = t.percentiles("fft", "optical-sim")
    assert set(pct) == {50.0, 95.0, 99.0}
    assert pct[50.0] == pytest.approx(2e-3, rel=0.2)
    assert pct[50.0] <= pct[95.0] <= pct[99.0]
    # no traffic for this pair: NaN, not KeyError
    assert math.isnan(t.percentiles("fft", "ideal")[50.0])
    # backend=None folds backends together
    assert t.latency_histogram("fft").n == 3


def test_telemetry_percentiles_merge_and_reset_round_trip():
    a, b = RuntimeTelemetry(), RuntimeTelemetry()
    for w in (1e-3, 2e-3):
        a.record("fft", "optical-sim", calls=1, samples_in=4,
                 samples_out=4, wall_s=w)
    for w in (3e-3, 4e-3):
        b.record("fft", "optical-sim", calls=1, samples_in=4,
                 samples_out=4, wall_s=w)
    a.merge(b)
    assert a.latency_histogram("fft", "optical-sim").n == 4
    assert a.percentiles("fft")[99.0] == pytest.approx(4e-3, rel=0.2)
    # merge copies: b's histogram stays 2 samples and survives a's reset
    a.reset()
    assert math.isnan(a.percentiles("fft")[50.0])
    assert b.latency_histogram("fft", "optical-sim").n == 2
    # summary mentions the percentile line once there are samples
    assert "p95" in b.summary()


def test_executor_records_latency_histograms():
    ex = OffloadExecutor(LANED_4F, max_batch=4)
    imgs = _imgs(8)
    ex.warm("fft", imgs[0], batch=4)
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    h = ex.telemetry.latency_histogram("fft", "optical-sim")
    assert h.n == 2              # two invocations of batch 4
    assert all(v > 0.0 for v in ex.telemetry.percentiles("fft").values())


# --- drift report -----------------------------------------------------------------

def _mk_inv(tr, *, modeled=True, stage_s=0.5, compute_s=1.0, hold_s=0.0,
            category="fft", backend="optical-sim"):
    inv = tr.begin("invocation", category=category, backend=backend)
    attrs = dict(wall_s=stage_s + compute_s, stage_s=stage_s,
                 compute_s=compute_s, hold_s=hold_s, shadow_s=0.0)
    if modeled:
        attrs.update(modeled_dac_s=1.0, modeled_interface_s=0.0,
                     modeled_analog_s=0.25, modeled_adc_s=0.25,
                     modeled_host_s=0.0, modeled_hold_s=hold_s,
                     modeled_total_s=1.5 + hold_s)
    inv.annotate(**attrs)
    tr.end(inv)
    return inv


def test_drift_report_ratios_and_worst():
    tr = Tracer(clock=ManualClock())
    _mk_inv(tr)                  # stage 0.5/1.0, compute 1.0/0.5
    rep = drift_report(tr.spans())
    assert rep.invocations == 1 and rep.unmodeled == 0
    assert rep.stages["stage"].drift == pytest.approx(0.5)
    assert rep.stages["compute"].drift == pytest.approx(2.0)
    assert rep.stages["total"].drift == pytest.approx(1.0)
    # stage and compute tie on |log|; worst never reports 'total'
    assert rep.worst.stage in ("stage", "compute")
    assert math.isnan(rep.stages["hold"].drift)
    assert "drift" in rep.table()


def test_drift_report_filters_and_unmodeled():
    tr = Tracer(clock=ManualClock())
    _mk_inv(tr, category="fft")
    _mk_inv(tr, category="conv", backend="host", modeled=False)
    rep = drift_report(tr.spans())
    assert rep.invocations == 1 and rep.unmodeled == 1
    only_conv = drift_report(tr.spans(), category="conv")
    assert only_conv.invocations == 0 and only_conv.unmodeled == 1


def test_drift_inf_and_nan_serialization():
    tr = Tracer(clock=ManualClock())
    inv = tr.begin("invocation", category="fft", backend="optical-sim")
    inv.annotate(wall_s=1.0, stage_s=1.0, compute_s=0.0, hold_s=0.0,
                 shadow_s=0.0, modeled_dac_s=0.0, modeled_interface_s=0.0,
                 modeled_analog_s=0.0, modeled_adc_s=0.0, modeled_host_s=0.0,
                 modeled_hold_s=0.0, modeled_total_s=1.0)
    tr.end(inv)
    rep = drift_report(tr.spans())
    assert math.isinf(rep.stages["stage"].drift)   # measured, unmodeled
    assert math.isnan(rep.stages["compute"].drift)  # 0 / 0
    j = rep.to_json()
    assert j["stages"]["stage"]["drift"] == "inf"
    assert j["stages"]["compute"]["drift"] is None
    assert j["worst_stage"] == "stage"


def test_router_replan_snapshots_drift():
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=4, tracer=tracer)
    router = PlanRouter(ex)
    imgs = _imgs(4)
    ex.warm("fft", imgs[0], batch=4)
    ex.telemetry.start()
    for h in [ex.submit("fft", im) for im in imgs]:
        h.get()
    ex.telemetry.stop()
    router.replan()
    assert router.drift is not None and router.drift.invocations >= 1
    assert "drift" in router.summary()


def test_router_replan_without_tracer_keeps_drift_none():
    ex = OffloadExecutor(LANED_4F, max_batch=4)
    router = PlanRouter(ex)
    imgs = _imgs(4)
    ex.telemetry.start()
    for h in [router.submit("fft", im) for im in imgs]:
        h.get()
    ex.telemetry.stop()
    router.replan()
    assert router.drift is None


# --- Perfetto export --------------------------------------------------------------

def test_trace_events_well_formed():
    clk = ManualClock()
    tracer = Tracer(clock=clk)
    ex = OffloadExecutor(LANED_4F, max_batch=4, clock=clk, tracer=tracer)
    imgs = _imgs(4)
    ex.warm("fft", imgs[0], batch=4)
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    ex.flush()
    events = to_trace_events(tracer.spans())
    phases = {e["ph"] for e in events}
    assert phases == {"M", "X", "b", "e", "i"}
    # one thread_name metadata event per lane, sched first
    metas = [e for e in events if e["ph"] == "M"]
    assert [m["args"]["name"] for m in metas][:2] == ["sched", "host"]
    assert all(m["name"] == "thread_name" for m in metas)
    # async b/e pairs match on (cat, id)
    begins = {(e["cat"], e["id"]) for e in events if e["ph"] == "b"}
    ends = {(e["cat"], e["id"]) for e in events if e["ph"] == "e"}
    assert begins == ends and begins
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] != "M":
            assert e["ts"] >= 0.0  # rebased to the earliest span
    # args survive the JSON flattening with ids attached
    inv_ev = [e for e in events
              if e["ph"] == "b" and e["name"] == "invocation"]
    assert inv_ev and "span_id" in inv_ev[0]["args"]


def test_to_trace_events_empty_and_summarize_empty():
    assert to_trace_events([]) == []
    assert "no spans" in summarize([])


def test_write_trace_round_trips(tmp_path):
    tr = Tracer(clock=ManualClock())
    with tr.span("stage"):
        pass
    path = tmp_path / "trace.json"
    payload = write_trace(str(path), tr.spans())
    import json
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert on_disk["traceEvents"] and on_disk["displayTimeUnit"] == "ms"


# --- acceptance: traced 512x512 tiled + sharded flush -----------------------------

@pytest.mark.slow
def test_traced_tiled_sharded_flush_reconciles(tmp_path):
    """The ISSUE's acceptance scenario: a traced 512x512 tiled+sharded
    flush exports valid Perfetto JSON whose per-stage charged sums
    reconcile with the measured flush wall to within 10% and join against
    the modeled decomposition per stage."""
    imgs = _imgs(8, shape=(512, 512))
    # budget admits 4-frame tiles: the group streams as 2 sub-invocations,
    # each scattered across 2 devices
    budget = MemoryBudget(2 * 4 * 512 * 512 * 4, source="manual",
                          reserve=1.0)
    tracer = Tracer()
    ex = OffloadExecutor(LANED_4F, max_batch=8, n_devices=2,
                         default_backend="sharded", mem_budget=budget,
                         tracer=tracer)
    ex.warm("fft", imgs[0], batch=8)
    tracer.clear()
    for im in imgs:
        ex.submit("fft", im)
    t0 = time.perf_counter()
    ex.flush()
    wall = time.perf_counter() - t0
    spans = tracer.spans()
    invs = _invocations(spans)
    assert len(invs) > 1, "budget did not tile the group"
    assert all(s.attrs["tiles"] == len(invs) for s in invs)
    _assert_tree_invariants(spans, 8)
    assert any(s.name == "scatter" for s in spans)
    # per-stage charged sums reconcile with the measured wall (10% gate)
    rec = reconcile(spans, wall)
    assert rec["coverage"] == pytest.approx(1.0, abs=0.10), rec
    sums = stage_sums(spans)
    assert sums["stage"] + sums["compute"] == pytest.approx(sums["wall"])
    # the modeled join is populated for every invocation
    rep = drift_report(spans)
    assert rep.invocations == len(invs) and rep.unmodeled == 0
    for st in ("stage", "compute", "total"):
        assert rep.stages[st].modeled_s > 0.0
        assert rep.stages[st].measured_s > 0.0
        assert rep.stages[st].drift > 0.0
    # and the export is loadable trace-event JSON
    path = tmp_path / "trace.json"
    payload = write_trace(str(path), spans)
    assert {e["ph"] for e in payload["traceEvents"]} >= {"M", "X", "b", "e"}


def test_traced_results_match_untraced():
    """Attaching a tracer must never change results — only observe them."""
    imgs = _imgs(6)
    ex0 = OffloadExecutor(LANED_4F, max_batch=6)
    h0 = [ex0.submit("fft", im) for im in imgs]
    ex0.flush()
    ex1 = OffloadExecutor(LANED_4F, max_batch=6, tracer=Tracer())
    h1 = [ex1.submit("fft", im) for im in imgs]
    ex1.flush()
    for a, b in zip(h0, h1):
        np.testing.assert_array_equal(np.asarray(a.value),
                                      np.asarray(b.value))
        assert a.cost.total_s == b.cost.total_s
