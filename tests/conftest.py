"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and
the subprocess-based distributed tests) force a placeholder device count.
"""

import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
