"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and
the subprocess-based distributed tests) force a placeholder device count.
"""

import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

try:  # property-test effort profiles; the nightly CI job selects "nightly"
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "default", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "nightly", max_examples=200, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # tier-1 runs fixed-example fallbacks instead
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
