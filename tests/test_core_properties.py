"""Hypothesis property tests on the paper-model invariants."""

import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import amdahl, complexity
from repro.core.accelerator import (
    ANDERSON_MVM,
    IDEAL_4F,
    PROTOTYPE_4F,
    OpticalFourierAcceleratorSpec,
)
from repro.core.conversion import ConverterSpec, frontier_gap, pareto_fom_fj
from repro.core.planner import CategoryProfile, plan_offload

FRACS = st.floats(min_value=0.0, max_value=1.0, allow_nan=False)
POS = st.floats(min_value=1e-6, max_value=1e6, allow_nan=False)


# --- Amdahl (Eq. 2/3) ------------------------------------------------------------

@given(FRACS)
def test_ideal_speedup_bounds(f):
    s = amdahl.ideal_speedup(f)
    assert s >= 1.0
    if f < 1.0:
        assert math.isclose(s, 1.0 / (1.0 - f), rel_tol=1e-9)


@given(FRACS, st.floats(min_value=1.0, max_value=1e9))
def test_finite_p_below_ideal(f, p):
    assert amdahl.speedup(f, p) <= amdahl.ideal_speedup(f) + 1e-9
    assert amdahl.speedup(f, 1.0) == pytest.approx(1.0)


@given(st.floats(min_value=0.0, max_value=0.999),
       st.floats(min_value=0.0, max_value=0.999))
def test_speedup_monotonic_in_fraction(f1, f2):
    lo, hi = sorted((f1, f2))
    assert amdahl.ideal_speedup(hi) >= amdahl.ideal_speedup(lo) - 1e-12


@given(st.floats(min_value=1.0, max_value=1e6))
def test_required_fraction_inverts_speedup(s):
    f = amdahl.required_fraction(s)
    assert 0.0 <= f <= 1.0
    assert amdahl.ideal_speedup(f) == pytest.approx(s, rel=1e-6)


def test_paper_ten_x_rule():
    assert amdahl.required_fraction(10.0) == pytest.approx(0.9)


# --- converters ---------------------------------------------------------------------

@given(st.integers(min_value=2, max_value=16), POS, POS)
def test_converter_derived_quantities(bits, rate_mhz, power_mw):
    spec = ConverterSpec("t", "adc", bits, rate_mhz * 1e6, power_mw * 1e-3)
    assert spec.energy_per_sample_j == pytest.approx(
        spec.power_w / spec.rate_hz)
    assert spec.walden_fom_j > 0
    assert spec.time_for(1000) >= spec.time_for(1000, lanes=10)
    assert spec.energy_for(2000) == pytest.approx(2 * spec.energy_for(1000))


@given(st.floats(min_value=1e6, max_value=1e11),
       st.floats(min_value=1e6, max_value=1e11))
def test_pareto_envelope_monotone_in_rate(r1, r2):
    lo, hi = sorted((r1, r2))
    assert pareto_fom_fj(hi, "adc") >= pareto_fom_fj(lo, "adc") - 1e-12


@given(st.floats(min_value=1.1, max_value=1000.0))
def test_frontier_gap_scales_with_required_energy(factor):
    from repro.core.conversion import LIU_2022_ADC
    import dataclasses
    better = dataclasses.replace(LIU_2022_ADC, power_w=LIU_2022_ADC.power_w / factor)
    assert frontier_gap(better) == pytest.approx(
        frontier_gap(LIU_2022_ADC) * factor, rel=1e-6)


# --- step costs -----------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=10_000_000))
def test_step_cost_components_nonnegative(n):
    c = PROTOTYPE_4F.step_cost(n)
    assert c.dac_s >= 0 and c.adc_s >= 0 and c.interface_s >= 0
    assert c.total_s >= c.conversion_s
    assert 0.0 <= c.data_movement_fraction <= 1.0


@given(st.integers(min_value=1, max_value=1_000_000),
       st.integers(min_value=1, max_value=1_000_000))
def test_step_cost_monotone_in_samples(n1, n2):
    lo, hi = sorted((n1, n2))
    assert PROTOTYPE_4F.step_cost(hi).total_s >= \
        PROTOTYPE_4F.step_cost(lo).total_s - 1e-12


def test_phase_shifting_costs_four_reads():
    import dataclasses
    four = dataclasses.replace(PROTOTYPE_4F, phase_shift_captures=4)
    one = PROTOTYPE_4F.step_cost(1000)
    c4 = four.step_cost(1000)
    assert c4.adc_s == pytest.approx(4 * one.adc_s)
    assert c4.dac_s == pytest.approx(one.dac_s)  # write path unchanged


# --- complexity (Fig. 3) -----------------------------------------------------------------

def test_linear_class_never_crosses():
    assert complexity.crossover_n("elementwise O(N)", 1.0) is None


def test_superlinear_classes_cross():
    for name in ("fft O(N log N)", "matvec O(N^2)", "ising O(2^N)"):
        assert complexity.crossover_n(name, 1.0) is not None


@given(st.floats(min_value=4, max_value=1e6))
def test_matvec_advantage_grows(n):
    assert complexity.advantage("matvec O(N^2)", 2 * n) > \
        complexity.advantage("matvec O(N^2)", n)


# --- planner (§4-§6) -------------------------------------------------------------------

@given(st.floats(min_value=1e-6, max_value=100.0),
       st.floats(min_value=1e-6, max_value=100.0),
       st.integers(min_value=1, max_value=10_000_000))
@settings(max_examples=50)
def test_plan_never_slower_and_bounded_by_amdahl(host_fft, host_other, n):
    profs = [
        CategoryProfile("fft", host_s=host_fft, calls=1, samples_in=n,
                        samples_out=n),
        CategoryProfile("other", host_s=host_other),
    ]
    plan = plan_offload(profs, PROTOTYPE_4F)
    assert plan.end_to_end_speedup >= 1.0 - 1e-9          # never offload a loss
    assert plan.end_to_end_speedup <= plan.ideal_speedup + 1e-9


def test_ideal_accelerator_reaches_amdahl_bound():
    profs = [CategoryProfile("fft", host_s=9.0, calls=1, samples_in=100,
                             samples_out=100),
             CategoryProfile("other", host_s=1.0)]
    plan = plan_offload(profs, IDEAL_4F)
    assert plan.end_to_end_speedup == pytest.approx(plan.ideal_speedup, rel=1e-3)
    assert plan.ideal_speedup == pytest.approx(10.0, rel=1e-3)


def test_mvm_accelerator_ignores_fft_category():
    profs = [CategoryProfile("fft", host_s=10.0, calls=1, samples_in=100,
                             samples_out=100)]
    plan = plan_offload(profs, ANDERSON_MVM)
    assert plan.end_to_end_speedup == pytest.approx(1.0)
