"""Physics-sim correctness: the 4f accelerator model vs FFT oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optical import (
    OpticalSimParams,
    dac_quantize,
    adc_quantize,
    fourier_mask_for_kernel,
    macro_pixel_aggregate,
    optical_conv2d,
    optical_fft2_complex,
    optical_fft2_magnitude,
    slm_crosstalk,
)

HI_FI = OpticalSimParams(dac_bits=16, adc_bits=16)


def test_magnitude_matches_fft():
    a = jax.random.uniform(jax.random.PRNGKey(0), (64, 64))
    got = optical_fft2_magnitude(a, HI_FI)
    want = jnp.abs(jnp.fft.fft2(a, norm="ortho"))
    np.testing.assert_allclose(got, want, atol=0.1)  # sqrt near 0 is touchy
    # intensity comparison is the physically-meaningful one
    np.testing.assert_allclose(got ** 2, want ** 2, rtol=1e-2,
                               atol=1e-3 * float((want ** 2).max()))


def test_complex_recovery_matches_fft():
    a = jax.random.uniform(jax.random.PRNGKey(1), (64, 64))
    got = optical_fft2_complex(a, HI_FI)
    want = jnp.fft.fft2(a, norm="ortho")
    np.testing.assert_allclose(jnp.abs(got - want).max(), 0.0, atol=2e-2)


def test_optical_conv_matches_circular_conv():
    a = jax.random.uniform(jax.random.PRNGKey(2), (64, 64))
    k = jnp.zeros((64, 64)).at[0, 0].set(0.6).at[0, 1].set(0.3).at[2, 3].set(0.1)
    mask = fourier_mask_for_kernel(k, params=HI_FI)
    got = optical_conv2d(a, mask, HI_FI)
    want = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(k)))
    np.testing.assert_allclose(got, want, atol=5e-2)


def test_quantization_bits_monotonic():
    """More converter bits => lower reconstruction error (physics sanity)."""
    a = jax.random.uniform(jax.random.PRNGKey(3), (64, 64))
    oracle = jnp.abs(jnp.fft.fft2(a, norm="ortho")) ** 2
    errs = []
    for bits in (2, 4, 8, 12):
        p = OpticalSimParams(dac_bits=bits, adc_bits=bits)
        got = optical_fft2_magnitude(a, p) ** 2
        errs.append(float(jnp.mean(jnp.abs(got - oracle))))
    assert errs == sorted(errs, reverse=True), errs


def test_dac_quantize_levels():
    x = jnp.linspace(0, 1, 1000)
    q = dac_quantize(x, 3)
    assert len(np.unique(np.asarray(q))) <= 8
    np.testing.assert_allclose(q, x, atol=1.0 / (2 * 7) + 1e-6)


def test_adc_quantize_autorange():
    x = jnp.asarray([0.0, 5.0, 10.0])
    q = adc_quantize(x, 8)
    np.testing.assert_allclose(q, x, atol=10.0 / 255 + 1e-6)


def test_macro_pixel_reduces_resolution():
    x = jax.random.uniform(jax.random.PRNGKey(4), (66, 66))
    y = macro_pixel_aggregate(x, 3)
    assert y.shape == (22, 22)
    np.testing.assert_allclose(y[0, 0], x[:3, :3].mean(), rtol=1e-6)


def test_crosstalk_preserves_mean():
    x = jax.random.uniform(jax.random.PRNGKey(5), (32, 32))
    y = slm_crosstalk(x, 0.05)
    np.testing.assert_allclose(y.mean(), x.mean(), rtol=1e-5)
    assert not np.allclose(y, x)


def test_noise_changes_output_and_stays_nonnegative():
    p = OpticalSimParams(dac_bits=8, adc_bits=8, shot_noise=0.01,
                         read_noise=0.001)
    a = jax.random.uniform(jax.random.PRNGKey(6), (32, 32))
    m1 = optical_fft2_magnitude(a, p, key=jax.random.PRNGKey(1))
    m2 = optical_fft2_magnitude(a, p, key=jax.random.PRNGKey(2))
    assert not np.allclose(m1, m2)
    assert float(m1.min()) >= 0.0


def test_differentiable_through_pipeline():
    """STE quantizers keep the whole accelerator differentiable."""
    a = jax.random.uniform(jax.random.PRNGKey(7), (16, 16))
    p = OpticalSimParams(dac_bits=6, adc_bits=6)
    g = jax.grad(lambda x: jnp.sum(optical_fft2_magnitude(x, p) ** 2))(a)
    assert g.shape == a.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).max()) > 0.0
