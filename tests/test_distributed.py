"""Distributed lowering tests — run in a subprocess so the forced device
count never leaks into the rest of the suite (conftest keeps 1 device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed.specs import batch_pspecs, opt_pspecs
from repro.models import LM, init_params, param_pspecs, param_shape_structs
from repro.optim import adamw
from repro.train import make_train_step

out = {}
from repro.distributed.compat import enter_mesh, make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
enter_mesh(mesh)

# 1. constraint liveness (regression for the with-mesh no-op bug)
from repro.distributed.sharding import current_axis_names
def probe(x):
    out["axes_in_trace"] = list(current_axis_names())
    return x
jax.jit(probe).lower(jax.ShapeDtypeStruct((8,), jnp.float32))
assert out["axes_in_trace"] == ["data", "model"], out

# 2. sharded end-to-end train step on a smoke config
cfg = get_smoke_config("qwen2-72b")
model = LM(cfg)
opt = adamw(1e-3)
params = init_params(cfg, jax.random.PRNGKey(0))
opt_state = opt.init(params)
p_ps = param_pspecs(cfg, fsdp_size=0, tp_size=4)
o_ps = opt_pspecs(jax.eval_shape(opt.init, params), p_ps)
named = lambda t: jax.tree_util.tree_map(
    lambda ps: NamedSharding(mesh, ps), t,
    is_leaf=lambda x: isinstance(x, P))
params = jax.device_put(params, named(p_ps))
opt_state = jax.device_put(opt_state, named(o_ps))
batch = {"tokens": jnp.ones((8, 16), jnp.int32),
         "labels": jnp.ones((8, 16), jnp.int32)}
batch = jax.device_put(batch, named(batch_pspecs(batch, ("data", "model"),
                                                 dp_total=2)))
step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
params2, opt2, metrics = step(params, opt_state, batch,
                              jnp.asarray(0, jnp.int32))
out["loss"] = float(metrics["loss"])
out["loss_finite"] = bool(jnp.isfinite(metrics["loss"]))

# 3. sharded arrays keep their sharding through the step
leaf = jax.tree_util.tree_leaves(params2)[1]
out["params_sharded"] = len(leaf.sharding.device_set) > 1 or True

# 4. replicated-vs-sharded numeric equivalence: same loss on 1-device mesh
mesh1 = make_auto_mesh((1, 1), ("data", "model"))
enter_mesh(mesh1)
params_r = init_params(cfg, jax.random.PRNGKey(0))
opt_r = opt.init(params_r)
batch_r = jax.device_get(batch)  # re-place on the 1-device mesh
batch_r = {k: jnp.asarray(v) for k, v in batch_r.items()}
step_r = jax.jit(make_train_step(model, opt))
_, _, metrics_r = step_r(params_r, opt_r, batch_r, jnp.asarray(0, jnp.int32))
out["loss_replicated"] = float(metrics_r["loss"])
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_train_step_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["loss_finite"]
    # 8-way sharded step == single-device step (SPMD is semantics-preserving)
    assert abs(out["loss"] - out["loss_replicated"]) < 5e-2, out
