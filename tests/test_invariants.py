"""Model-level invariants (system properties, not golden numbers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import LM, init_params
from repro.models.layers import rope


@pytest.mark.parametrize("arch", ["qwen2-72b", "recurrentgemma-9b",
                                  "deepseek-v3-671b", "xlstm-125m"])
def test_causality(arch):
    """Perturbing a future token must not change logits at earlier
    positions (covers causal attention, windowed attention, MLA, and the
    recurrent families in one property)."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    b, s, cut = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, 100)
    toks2 = toks.at[:, cut:].set((toks[:, cut:] + 17) % 100)

    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=s + 4))
    # compare the cut-1 position's next-token logits via prefix prefill
    _, lg_a = prefill(params, {"tokens": toks[:, :cut]})
    _, lg_b = prefill(params, {"tokens": toks2[:, :cut]})
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))

    # and through the full sequence: loss gradient wrt future-only change
    full_a, _ = prefill(params, {"tokens": toks})
    full_b, _ = prefill(params, {"tokens": toks2})
    # caches at positions < cut must agree for attention caches
    def pick_kv(tree):
        return [np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(tree)[0]
                if p and getattr(p[-1], "key", "") in ("k", "v", "latent")]
    for a, bb in zip(pick_kv(full_a), pick_kv(full_b)):
        if a.ndim == 4:          # (B, Hk, S, hd) or stacked (n, B, Hk, S, hd)
            np.testing.assert_allclose(a[..., :cut, :], bb[..., :cut, :],
                                       atol=1e-5)


def test_rope_relative_shift():
    """RoPE scores depend only on relative offsets: shifting all positions
    by a constant leaves q.k inner products unchanged."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 64))
    pos = jnp.arange(8)
    def scores(shift):
        qr = rope(q, pos + shift)
        kr = rope(k, pos + shift)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)
    np.testing.assert_allclose(scores(0), scores(1000), rtol=2e-3, atol=2e-3)


def test_partial_rotary_passthrough():
    """rope_pct < 1 must leave the non-rotary dims untouched (StableLM-2)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 2, 64))
    y = rope(x, jnp.arange(4), pct=0.25)
    np.testing.assert_array_equal(np.asarray(y[..., 16:]),
                                  np.asarray(x[..., 16:]))


def test_batch_order_invariance():
    """Per-sequence results don't depend on batch position (no cross-lane
    leakage through MoE dispatch, chunked CE, or caches)."""
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = LM(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, 100)
    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=16))
    _, lg = prefill(params, {"tokens": toks})
    _, lg_swapped = prefill(params, {"tokens": toks[::-1]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_swapped[::-1]),
                               atol=1e-4)
