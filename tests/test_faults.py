"""Fault-injected offload boundary: the chaos/equivalence harness.

The invariant this file locks down (the ISSUE's acceptance criterion):

    faulted execution == fault-free execution == looped host baseline

under every injected fault kind — transient dispatch errors, latency-spike
stragglers, ENOB drift, hard device loss mid-sharded-dispatch — at the
level the backend can guarantee: bit-for-bit on digital backends and for
host-degraded frames, within the converters' ENOB error bound for frames
the optical backend served.  Faults change *when and where* a frame
executes (retries, backoff, host fallback, survivor re-scatter), never
*what* it returns, and never whether it retires.

All fault schedules are seeded and all timing rides a ``ManualClock``
(injected straggles advance manual time, retry backoffs sleep through it),
so every failure in this file is reproducible to the dispatch index.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.distributed.straggler import TrailingMedianDeadline
from repro.runtime import (
    BATCHED_4F,
    ChaosBackend,
    Fault,
    FaultSchedule,
    FidelityChecker,
    ManualClock,
    OffloadExecutor,
    OffloadScheduler,
    Quarantine,
    RetryPolicy,
    Tracer,
    TransientDispatchError,
    enob_error_bound,
    reconcile,
    register_backend,
    register_chaos,
)

RTOL = 1e-5
ATOL = 1e-6


def _images(n, shape=(32, 32), seed=0):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.rand(*shape), jnp.float32) for _ in range(n)]


def _run_all(ex, imgs, category="fft"):
    with ex:
        handles = [ex.submit(category, im) for im in imgs]
    return handles


def _values(handles):
    return [np.asarray(h.value) for h in handles]


def _optical_reference(imgs, **kw):
    ex = OffloadExecutor(BATCHED_4F, default_backend="optical-sim",
                         clock=ManualClock(), **kw)
    return _values(_run_all(ex, imgs))


def _host_reference(imgs):
    ex = OffloadExecutor(BATCHED_4F, default_backend="host", max_batch=1)
    return _values(_run_all(ex, imgs))


# -- the schedule: deterministic injection --------------------------------


def test_fault_schedule_is_deterministic_and_fresh_rewinds():
    sched = FaultSchedule(0.4, seed=11)
    first = [sched.draw() for _ in range(64)]
    replay = [sched.fresh().draw() for _ in range(1)]  # fresh starts at 0
    again = sched.fresh()
    assert [again.draw() for _ in range(64)] == first
    assert replay[0] == first[0]
    assert any(f is not None for f in first)  # 40% over 64 draws must hit
    other = [FaultSchedule(0.4, seed=12).draw() for _ in range(64)]
    assert other != first


def test_fault_schedule_script_pins_indices_without_shifting_stream():
    script = {3: Fault("error")}
    a = FaultSchedule(0.5, seed=3, script=script)
    b = FaultSchedule(0.5, seed=3)
    for i in range(16):
        fa, fb = a.draw(), b.draw()
        if i == 3:
            assert fa == Fault("error")
        else:
            assert fa == fb  # scripted entry didn't shift later draws
    assert FaultSchedule(rate=0.0).draw() is None


def test_fault_kind_validation():
    with pytest.raises(ValueError):
        Fault("meteor-strike")
    with pytest.raises(ValueError):
        FaultSchedule(rate=1.5)


# -- the chaos wrapper -----------------------------------------------------


def test_chaos_backend_transparent_at_rate_zero():
    imgs = _images(6)
    name = register_chaos("optical-sim", name="chaos-t0", rate=0.0)
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=3,
                         clock=ManualClock())
    got = _values(_run_all(ex, imgs))
    ref = _optical_reference(imgs, max_batch=3)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)  # bit-equal: pure delegation
    assert ex.telemetry.faults_total() == 0
    assert not ex.quarantine.events


def test_transient_error_is_retried_on_same_backend():
    imgs = _images(4)
    name = register_chaos("optical-sim", name="chaos-err",
                          script={0: Fault("error")})
    clk = ManualClock()
    tr = Tracer(clock=clk)
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=4,
                         clock=clk, tracer=tr)
    handles = _run_all(ex, imgs)
    ref = _optical_reference(imgs, max_batch=4)
    for h, r in zip(_values(handles), ref):
        np.testing.assert_array_equal(h, r)
    assert handles[0].backend == "chaos-err"      # retried, not degraded
    assert ex.telemetry.fault_counts["fft"]["error"] == 1
    names = {s.name for s in tr.spans()}
    assert "fault" in names and "retry" in names
    assert tr.metrics.counter("retries", category="fft",
                              backend="chaos-err").value == 1
    # the backoff elapsed on the injected clock, not a real sleep
    assert clk() > 0.0


def test_retry_exhaustion_degrades_to_host_in_submit_order():
    imgs = _images(5)
    name = register_chaos("optical-sim", name="chaos-dead",
                          script={i: Fault("error") for i in range(3)})
    clk = ManualClock()
    tr = Tracer(clock=clk)
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=8,
                         clock=clk, tracer=tr)
    handles = _run_all(ex, imgs)
    refs = _host_reference(imgs)
    for h, r in zip(_values(handles), refs):
        np.testing.assert_array_equal(h, r)   # digital fallback: bit-equal
    assert all(h.backend == "host" for h in handles)
    assert ex.telemetry.fault_counts["fft"]["error"] == 3
    assert ex.telemetry.fault_counts["fft"]["fallback"] == 1
    assert ex.telemetry.recovery_stats("fft")["n"] == 1
    assert ex.quarantine.is_quarantined(("category", "fft"), ex.now())
    names = {s.name for s in tr.spans()}
    assert {"fault", "retry", "fallback", "quarantine"} <= names


def test_quarantine_reroutes_then_readmits_after_probation():
    imgs = _images(12)
    name = register_chaos("optical-sim", name="chaos-q",
                          script={i: Fault("error") for i in range(3)})
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=4,
                         clock=clk)
    # batch 1: exhausts retries, falls back, quarantines the category
    first = [ex.submit("fft", im) for im in imgs[:4]]
    ex.flush()
    assert all(h.backend == "host" for h in first)
    # batch 2: rerouted straight to host — the chaos backend is not even
    # consulted (its schedule index is frozen at the 3 consumed draws)
    second = [ex.submit("fft", im) for im in imgs[4:8]]
    ex.flush()
    assert all(h.backend == "host" for h in second)
    assert ex.telemetry.fault_counts["fft"]["reroute"] == 1
    be = ex._backend(name)
    assert be.schedule.index == 3
    # past window + probation: re-admitted, optical serves again
    clk.advance(ex.retry.quarantine_s + ex.retry.probation_s + 1e-3)
    assert not ex.quarantine.is_quarantined(("category", "fft"), ex.now())
    third = [ex.submit("fft", im) for im in imgs[8:]]
    ex.flush()
    assert all(h.backend == name for h in third)
    ref = _optical_reference(imgs[8:], max_batch=4)
    for h, r in zip(_values(third), ref):
        np.testing.assert_array_equal(h, r)


def test_straggler_detected_but_not_retried():
    imgs = _images(8)
    name = register_chaos("optical-sim", name="chaos-slow",
                          script={1: Fault("straggle", delay_s=2.0)})
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=4,
                         clock=clk)
    handles = _run_all(ex, imgs)
    ref = _optical_reference(imgs, max_batch=4)
    for h, r in zip(_values(handles), ref):
        np.testing.assert_array_equal(h, r)   # slow, not wrong
    assert all(h.backend == name for h in handles)
    assert ex.telemetry.fault_counts["fft"]["straggle"] == 1
    assert "fallback" not in ex.telemetry.fault_counts["fft"]
    assert clk() >= 2.0  # the injected spike elapsed on the manual clock


def test_device_loss_mid_sharded_dispatch_recovers_on_survivor():
    imgs = _images(8)
    name = register_chaos("sharded", name="chaos-shard",
                          script={0: Fault("device_loss", device=1)})
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=8,
                         n_devices=4, clock=clk)
    handles = _run_all(ex, imgs)
    ref = _optical_reference(imgs, max_batch=8)
    for h, r in zip(_values(handles), ref):
        np.testing.assert_allclose(h, r, rtol=RTOL, atol=ATOL)
    assert ex.telemetry.fault_counts["fft"]["device_loss"] == 1
    assert ex.quarantine.is_quarantined(("device", 1), ex.now())
    assert ex.quarantine.active_device_count(ex.now()) == 1
    # the next group re-scatters across the 3 survivors only
    ex.telemetry.reset()
    more = [ex.submit("fft", im) for im in imgs[:6]]
    ex.flush()
    assert ex.telemetry.devices_observed("fft") == 3
    for h, r in zip(_values(more), ref[:6]):
        np.testing.assert_allclose(h, r, rtol=RTOL, atol=ATOL)


def test_router_replan_shrinks_fanout_around_quarantined_devices():
    from repro.runtime import PlanRouter
    imgs = _images(8)
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend="sharded", max_batch=8,
                         n_devices=4, clock=clk)
    router = PlanRouter(ex)
    for h in [ex.submit("fft", im) for im in imgs]:
        pass
    ex.flush()
    full = router.choose_sharding()["fft"][1]
    ex.quarantine.quarantine(("device", 2), ex.now(), reason="test")
    ex.quarantine.quarantine(("device", 3), ex.now(), reason="test")
    shrunk = router.choose_sharding()["fft"][1]
    assert shrunk == min(full, 2) and shrunk < full
    clk.advance(ex.retry.quarantine_s + ex.retry.probation_s + 1e-3)
    assert router.choose_sharding()["fft"][1] == full  # re-admitted


def test_drift_violation_corrected_from_shadow_and_quarantined():
    imgs = _images(4)
    name = register_chaos("optical-sim", name="chaos-drift",
                          script={0: Fault("drift", gain=64.0)})
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=4,
                         clock=clk, fidelity=FidelityChecker())
    handles = _run_all(ex, imgs)
    refs = _host_reference(imgs)
    for h, r in zip(_values(handles), refs):
        np.testing.assert_array_equal(h, r)   # corrected: host bit-equal
    assert all(h.backend == "host" for h in handles)
    assert ex.telemetry.fault_counts["fft"]["drift"] == 1
    assert ex.fidelity.violations("fft")
    assert ex.quarantine.is_quarantined(("category", "fft"), ex.now())
    assert ex.quarantine.events[-1].reason == "fidelity-drift"


def test_fault_sequence_reproducible_under_manual_clock():
    imgs = _images(24, shape=(16, 16))

    def _run():
        name = register_chaos("optical-sim", name="chaos-repro",
                              rate=0.3, seed=7, straggle_s=0.5)
        ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=4,
                             clock=ManualClock(), fidelity=FidelityChecker())
        handles = _run_all(ex, imgs)
        return (_values(handles), [h.backend for h in handles],
                {k: dict(v) for k, v in ex.telemetry.fault_counts.items()},
                [(e.key, e.reason) for e in ex.quarantine.events])

    vals_a, be_a, faults_a, ev_a = _run()
    vals_b, be_b, faults_b, ev_b = _run()
    assert be_a == be_b and faults_a == faults_b and ev_a == ev_b
    assert faults_a  # a 30% rate over 24 calls must inject something
    for a, b in zip(vals_a, vals_b):
        np.testing.assert_array_equal(a, b)


# -- the 10% equivalence harness ------------------------------------------


def test_ten_percent_fault_rate_all_frames_retire_host_close():
    imgs = _images(48, shape=(16, 16))
    name = register_chaos("optical-sim", name="chaos-ten",
                          rate=0.10, seed=2)
    clk = ManualClock()
    tr = Tracer(clock=clk)
    ex = OffloadExecutor(BATCHED_4F, default_backend=name, max_batch=2,
                         clock=clk, tracer=tr, fidelity=FidelityChecker())
    handles = _run_all(ex, imgs)
    assert all(h.ready and h.value is not None for h in handles)
    refs = _host_reference(imgs)
    enob = min(BATCHED_4F.dac.effective_bits, BATCHED_4F.adc.effective_bits)
    bound = enob_error_bound(enob, 16.0)
    for h, r in zip(_values(handles), refs):
        rel = np.linalg.norm(h - r) / max(np.linalg.norm(r), 1e-12)
        assert rel <= bound
    assert ex.telemetry.faults_total("fft") > 0
    names = {s.name for s in tr.spans()}
    assert "fault" in names  # a 10% rate over 48 calls must show up
    # fault observability reconciles: the charged-time contract reads only
    # invocation trees, so fault/retry/quarantine spans cannot skew it
    assert tr.find("invocation")
    rec = reconcile(tr.spans(), 1.0)
    assert rec["attributed_s"] >= 0.0 and "coverage" in rec


# -- the quarantine lifecycle ---------------------------------------------


def test_quarantine_window_probation_escalation_round_trip():
    q = Quarantine(window_s=1.0, probation_s=0.5, patience=3)
    key = ("device", 0)
    ev = q.quarantine(key, 10.0)
    assert ev.level == 0 and ev.until == 11.0
    assert q.is_quarantined(key, 10.5) and not q.is_quarantined(key, 11.0)
    assert q.on_probation(key, 11.2) and not q.on_probation(key, 11.5)
    # re-offend during probation: window doubles
    ev2 = q.quarantine(key, 11.2)
    assert ev2.level == 1 and ev2.until == pytest.approx(11.2 + 2.0)
    # survive the new probation cleanly: next quarantine starts over
    t_clean = ev2.probation_until + 0.1
    ev3 = q.quarantine(key, t_clean)
    assert ev3.level == 0 and ev3.until == pytest.approx(t_clean + 1.0)
    assert q.active(t_clean + 0.5) == (key,)
    assert q.active_device_count(t_clean + 0.5) == 1
    assert "quarantine" in q.summary(t_clean + 0.5)


def test_quarantine_straggle_strikes_and_forgiveness():
    q = Quarantine(window_s=1.0, patience=3)
    key = ("category", "fft")
    assert q.note_straggle(key, 0.0) is None
    assert q.note_straggle(key, 0.1) is None
    q.note_healthy(key)                      # streak forgiven
    assert q.note_straggle(key, 0.2) is None
    assert q.note_straggle(key, 0.3) is None
    ev = q.note_straggle(key, 0.4)           # third consecutive: quarantined
    assert ev is not None and ev.reason == "straggler"
    assert q.note_straggle(key, 0.5) is None  # already quarantined: no-op


def test_retry_policy_backoff_grows_with_jitter():
    p = RetryPolicy(backoff_s=1e-3, backoff_factor=2.0, jitter=0.5, seed=1)
    b1, b2, b3 = (p.backoff_for(i) for i in (1, 2, 3))
    assert 1e-3 <= b1 <= 1.5e-3
    assert 2e-3 <= b2 <= 3e-3
    assert 4e-3 <= b3 <= 6e-3
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


# -- the shared trailing-median deadline ----------------------------------


def test_trailing_median_deadline_cold_and_armed():
    det = TrailingMedianDeadline(factor=3.0, patience=2)
    assert det.deadline_s() == float("inf")       # no signal, no claim
    assert not det.observe(100.0)                 # cold: always healthy
    assert det.deadline_s() == pytest.approx(300.0)
    det2 = TrailingMedianDeadline(factor=3.0, floor_s=0.05)
    # a modeled baseline arms a cold detector
    assert det2.deadline_s(base_s=0.02) == pytest.approx(0.15)  # floor wins
    assert det2.observe(1.0, base_s=0.02)         # straggler on first obs
    assert det2.median == float("inf")            # excluded from history


def test_trailing_median_deadline_strikes_and_reset():
    det = TrailingMedianDeadline(factor=2.0, patience=2)
    for _ in range(4):
        assert not det.observe(1.0)
    assert det.observe(10.0) and not det.exhausted
    assert det.observe(10.0) and det.exhausted
    assert det.median == pytest.approx(1.0)       # stragglers never poison
    det.reset_strikes()
    assert not det.exhausted
    det.reset()
    assert det.deadline_s() == float("inf")


# -- lifecycle: nothing leaks on exception paths --------------------------


def test_exit_drains_held_and_inflight_groups_on_body_exception():
    imgs = _images(6)
    clk = ManualClock()
    ex = OffloadExecutor(BATCHED_4F, default_backend="optical-sim",
                         max_batch=8, clock=clk)
    with pytest.raises(ValueError, match="body"):
        with OffloadScheduler(ex, deadline_s=10.0, clock=clk) as sched:
            handles = [sched.submit("fft", im) for im in imgs]
            assert ex.pending == 6        # held: deadline far away
            raise ValueError("body")
    # the body's exception escaped AND every held frame still retired
    assert ex.pending == 0 and ex.in_flight == 0
    assert all(h.ready and h.value is not None for h in handles)
    ref = _optical_reference(imgs, max_batch=8)
    for h, r in zip(_values(handles), ref):
        np.testing.assert_array_equal(h, r)


def test_exit_does_not_mask_body_exception_with_backend_error():
    class _Exploding:
        name = "exploding"

        def supports(self, category, ctx):
            return True

        def run(self, category, xs, ctx, *, kernel=None, weights=None):
            raise RuntimeError("boom")   # NOT a FaultError: no retry

    register_backend("exploding", _Exploding)
    ex = OffloadExecutor(BATCHED_4F, default_backend="exploding",
                         clock=ManualClock())
    sched = OffloadScheduler(ex, deadline_s=10.0, clock=ex._clock)
    with pytest.raises(ValueError, match="body"):
        with sched:
            sched.submit("fft", _images(1)[0])
            raise ValueError("body")     # must win over the drain's boom
    # without a body exception, the drain's own error surfaces
    ex2 = OffloadExecutor(BATCHED_4F, default_backend="exploding",
                          clock=ManualClock())
    with pytest.raises(RuntimeError, match="boom"):
        with ex2:
            ex2.submit("fft", _images(1)[0])


def test_chaos_backend_delegates_supports_and_samples():
    sched = FaultSchedule()
    be = ChaosBackend("sharded", schedule=sched)
    assert be.inner_name == "sharded"
    assert be.name == "chaos-sharded"
    assert be.take_device_samples() is None
    with pytest.raises(TransientDispatchError):
        ChaosBackend("host", schedule=FaultSchedule(
            script={0: Fault("error")})).run("fft", [], None)


# -- per-engine pipeline windows under faults ------------------------------


def _retire_spy(ex):
    """Record every retirement's ``(wkey, call_ids)`` in retire order."""
    retired = []
    orig = ex._retire

    def spy(g):
        retired.append((g.wkey, [p.call_id for p in g.chunk]))
        orig(g)

    ex._retire = spy
    return retired


def test_chaos_straggler_does_not_stall_other_engine_window():
    """A latency spike on engine A's in-flight invocation must not force
    engine B to retire through it: per-engine pipeline windows gate each
    ``(category, backend)`` pair independently, so B dispatches while A's
    straggler is still in flight.  ``shared_window=True`` is the control
    — the old global two-deep gate retires A's straggler to admit B."""
    imgs = _images(8)
    k = jnp.zeros((32, 32)).at[0, 0].set(1.0)
    for shared in (False, True):
        name = register_chaos(
            "optical-sim", name=f"chaos-win-{int(shared)}",
            script={0: Fault("straggle", delay_s=5.0)})
        clk = ManualClock()
        ex = OffloadExecutor(BATCHED_4F, max_batch=2, pipeline_depth=2,
                             clock=clk, shared_window=shared)
        retired = _retire_spy(ex)
        # engine A: two fft invocations through the chaos backend — the
        # first carries the injected straggle and stays in flight
        for im in imgs[:4]:
            ex.submit("fft", im, backend=name)
        ex.flush_async()
        assert [g.wkey for g in ex._inflight] == [("fft", name)] * 2
        # engine B: two conv invocations through the plain optical engine
        for im in imgs[4:]:
            ex.submit("conv", im, kernel=k, backend="optical-sim")
        ex.flush_async()
        forced = [w for w, _ in retired]
        if shared:
            # the global gate admitted conv only by retiring through the
            # straggling fft invocation — the stall this PR removes
            assert ("fft", name) in forced
        else:
            # fft's window is full but conv's own window is empty:
            # nothing retires, all four invocations ride in flight
            assert forced == []
            assert [g.wkey for g in ex._inflight] == \
                [("fft", name)] * 2 + [("conv", "optical-sim")] * 2
        ex.drain()
        # retirement stays submit-ordered WITHIN each engine either way
        for wkey in {w for w, _ in retired}:
            ids = [i for w, grp in retired for i in grp if w == wkey]
            assert ids == sorted(ids)
        assert ex.telemetry.fault_counts["fft"]["straggle"] == 1
