"""Generate EXPERIMENTS.md from the recorded artifacts.

Run:  PYTHONPATH=src:. python experiments/gen_experiments.py
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_cells,
                                 roofline_row)

ROOT = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(ROOT, "..", "EXPERIMENTS.md")


def gib(x):
    return f"{x / 2**30:.1f}"


def spearman(a, b):
    def rank(v):
        order = sorted(range(len(v)), key=lambda i: v[i])
        r = [0.0] * len(v)
        for pos, i in enumerate(order):
            r[i] = pos
        return r
    ra, rb = rank(a), rank(b)
    n = len(a)
    d2 = sum((x - y) ** 2 for x, y in zip(ra, rb))
    return 1 - 6 * d2 / (n * (n * n - 1))


def amdahl_section() -> str:
    with open(os.path.join(ROOT, "amdahl.json")) as f:
        rows = json.load(f)
    ours = [r["fraction"] * 100 for r in rows]
    papers = [r["paper_frac"] for r in rows]
    rho = spearman(ours, papers)
    sp = sorted(r["speedup"] for r in rows)
    med, mean = sp[len(sp) // 2], sum(sp) / len(sp)
    lines = [
        "## §Amdahl — the 27-benchmark case study (paper Table 1 / Fig. 9)",
        "",
        "All 27 applications reimplemented in JAX and profiled with the same",
        "methodology (FFT/conv library calls attributed to the accelerator;",
        "ideal zero-cost offload; Amdahl bound).  Our host (JAX on one CPU",
        "core) has far less per-op interpreter overhead than the paper's",
        "SciPy/LightPipes stack, so accelerable *fractions* shift up uniformly;",
        "the reproduced quantities are the per-app ranking and the shape of",
        "the distribution:",
        "",
        f"* median speedup **{med:.2f}x** (paper 1.94x) — small, Amdahl-limited",
        f"* mean **{mean:.2f}x** (paper 9.39x) — both skewed by the two",
        "  pure-kernel apps, which is the paper's own point (§5.1)",
        f"* Spearman rank correlation of FFT/conv fractions vs paper: "
        f"**{rho:.3f}**",
        f"* apps above the 10x build-threshold: "
        f"{sum(1 for r in rows if r['speedup'] >= 10)}/27 (paper: 2/27) — all"
        " of them FFT/conv-dominated optics kernels",
        "",
        "| app | FFT/conv % (ours) | (paper) | speedup (ours) | (paper) |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']} | {100*r['fraction']:.1f} | {r['paper_frac']:.1f}"
            f" | {r['speedup']:.2f} | {r['paper_speedup']:.2f} |")
    return "\n".join(lines)


def dryrun_section(cells) -> str:
    singles = [c for c in cells if c["mesh"] == "single"]
    multis = [c for c in cells if c["mesh"] == "multi"]
    lines = [
        "## §Dry-run — every (arch x shape) on the production meshes",
        "",
        f"**All {len(cells)} cells lower + compile**: {len(singles)} on the "
        "single-pod 16x16 (256-chip) mesh and "
        f"{len(multis)} on the 2x16x16 (512-chip) multi-pod mesh — every "
        "applicable (architecture x input-shape) pair.  `long_500k` runs for "
        "the sub-quadratic families (recurrentgemma, xlstm) and is skipped "
        "for the eight full-attention archs per the brief (DESIGN.md §6).",
        "",
        "Memory-analysis caveat (applies to every `peak/dev` below): the",
        "xla:cpu backend upcasts all bf16 math to f32 and hoists whole-stack",
        "bf16->f32 converts out of scan loops, roughly **doubling** reported",
        "temps vs a native-bf16 TPU lowering.  Each artifact therefore also",
        "records an analytic per-chip residency model (params/opt/grads/",
        "activations/cache at the declared shardings); both are shown.",
        "",
        "| cell | devices | HLO flops/dev | coll bytes/dev | peak/dev GiB "
        "(CPU-HLO) | analytic GiB | fits 16G (analytic) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: c["cell"]):
        am = c.get("analytic_memory_per_device")
        am_s = gib(am["total"]) if am else "-"
        fit_s = ("yes" if am["fits_16gb"] else "no") if am else "-"
        lines.append(
            f"| {c['cell']} | {c['devices']} | {c['flops']:.2e} | "
            f"{c['collective_bytes_total']:.2e} | "
            f"{gib(c['peak_bytes_per_device'])} | {am_s} | {fit_s} |")
    return "\n".join(lines)


def roofline_section(cells) -> str:
    rows = [roofline_row(c) for c in cells if c["mesh"] == "single"]
    lines = [
        "## §Roofline — three terms per cell (single-pod, 256 chips)",
        "",
        f"Hardware constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16/chip, "
        f"{HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s/link ICI.",
        "",
        "Sources: compute = exact scan-aware jaxpr FLOPs / (chips x peak);",
        "memory = HLO bytes-accessed x scan-correction / HBM bw; collective =",
        "per-device collective bytes (parsed from partitioned HLO: all-gather/",
        "all-reduce/reduce-scatter/all-to-all/collective-permute, counted as",
        "max(result, operand) bytes) x scan-correction / link bw.  The",
        "scan-correction (jaxpr-flops / chips / hlo-flops) compensates XLA",
        "cost analysis counting loop bodies once; it is exact for in-loop",
        "work and over-scales the small out-of-loop remainder — memory and",
        "collective terms are therefore upper bounds, and `roof%` "
        "(= compute / dominant term) a conservative lower bound.",
        "",
        "| cell | compute_s | memory_s | collective_s | dominant | "
        "useful(6ND/HLO) | roof% |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: r["cell"]):
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {100*r['roofline_fraction']:.1f} |")
    lines += [
        "",
        "Reading the table: decode cells are overwhelmingly memory/collective",
        "bound (one token amortizes nothing — the serving analogue of the",
        "paper's conversion bottleneck); train cells sit at 4-12% of compute",
        "roofline before optimization, dominated by activation all-reduces",
        "(dense) or dispatch/combine traffic (MoE).  `useful` > 1 for the",
        "recurrent families because 6ND over-counts architectures whose",
        "mixing is elementwise recurrences rather than matmuls.",
    ]
    return "\n".join(lines)


def perf_section(base, opt) -> str:
    b = {c["cell"]: c for c in base}
    o = {c["cell"]: c for c in opt}

    def row(cell, tag):
        c = b[cell] if tag == "baseline" else o[cell]
        r = roofline_row(c)
        return (f"| {tag} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
                f"{r['collective_s']:.2e} | "
                f"{gib(c['peak_bytes_per_device'])} GiB |")

    parts = ["""## §Perf — hillclimb log (hypothesis -> change -> measure -> verdict)

Three cells were selected per the brief: **qwen2-72b train_4k** (most
collective-bound dense cell), **deepseek-v3-671b train_4k** (most
representative of the paper's technique: the MoE all-to-all dispatch is the
in-cluster analogue of the conversion boundary), and **nemotron-4-340b
train_4k** (worst memory picture: it did not fit HBM at baseline).
The paper-faithful baseline (parameter-driven SPMD propagation only) is
recorded separately from every optimized variant; artifacts live in
`experiments/dryrun/` and `experiments/dryrun_opt/`.

### iteration 0 — infrastructure finding (applies to every cell)

*Hypothesis*: activation sharding constraints in the model
(`with_sharding_constraint`) shape the lowering.
*Measurement*: collective bytes identical with/without constraints.
*Root cause*: under `with mesh:` (legacy context) the abstract mesh is
empty, so every constraint **silently no-ops**; `jax.set_mesh(mesh)` is
required.  A refuted hypothesis that found a real bug: the fix
(launch/dryrun.py) makes all following iterations possible.  The recorded
baseline is genuinely propagation-only.

### cell A: qwen2-72b train_4k (single pod)

Baseline collective breakdown: 82% all-reduce — XLA re-materializes
*unsharded* (64, 4096, d) fp32 activations and psums them over the mesh
(contraction-dim strategy under FSDP weights).

| variant | compute_s | memory_s | collective_s | peak/dev |
|---|---|---|---|---|
"""]
    parts.append(row("qwen2-72b__train_4k__single", "baseline"))
    parts.append(row("qwen2-72b__train_4k__single", "optimized (sp)"))
    parts.append("""
* *it 1 (`dp`: residual pinned batch-over-data)* — napkin math predicted
  ~17x less all-reduce (activation psums shrink to the batch shard).
  Measured: collective term 131s -> **49.5s (2.6x)** — confirmed in
  direction, under-delivered in magnitude (weight-gather traffic appears);
  **but** peak/dev exploded to 64 GiB (SPMD inserts full-batch
  rematerialization copies at the constraint boundary).  Refuted as a
  deployable point on v5e.
* *it 2 (`sp`: Megatron sequence parallelism — batch over data + sequence
  over model between blocks)* — hypothesis: TP all-reduces become
  reduce-scatter/all-gather pairs at 1/16 size, and the S-sharded residual
  keeps layout stable through the q-chunk scan.  Measured: memory bytes
  4.69e11 -> **1.55e11 (3.0x)**, peak 20.9 -> **12.8 GiB (now fits)**,
  collective term flat (the SP all-gathers replace the saved all-reduces
  byte-for-byte at this TP degree).  Shipped: memory was the binding
  constraint.  On the 512-chip multi-pod mesh the same settings give
  peak 11.5 GiB/chip.

### cell B: deepseek-v3-671b train_4k (single pod)

| variant | compute_s | memory_s | collective_s | peak/dev |
|---|---|---|---|---|
""")
    parts.append(row("deepseek-v3-671b__train_4k__single", "baseline"))
    parts.append(row("deepseek-v3-671b__train_4k__single",
                     "optimized (EP+cf1.0)"))
    parts.append("""
* *it 1 (`sp` residual)* — hypothesis: same win as cell A.  Measured:
  collective 176s -> 207s, peak 57 GiB.  **Refuted**: with MLA's latent
  projections and the (B, E, C, D) dispatch tensors, S-sharding fights the
  expert layout.  Recorded and reverted.
* *it 2 (live EP dispatch constraint + capacity factor 1.25 -> 1.0)* —
  hypothesis: pinning the gathered dispatch tensor to
  (data, model=experts, ., .) makes the expert exchange a true all-to-all
  instead of gather-everywhere, and cf=1.0 cuts dispatch payloads 20%.
  Measured: HLO memory bytes 1.19e12 -> **5.43e11 (2.2x)**, collective
  bytes 5.52e10 -> **2.09e10 (2.6x)**, all-to-all payload 1.9e9 -> 8.2e8,
  peak 65.1 -> 56.4 GiB.  Confirmed.  (Residual CPU-HLO peak is dominated
  by the f32-hoist artifact; analytic residency: 19.8 GiB at accum=8,
  13.2 GiB at accum=16.)
* *it 3 (remat policy `dots_with_no_batch_dims_saveable`)* — hypothesis:
  saving matmul outputs removes backward recompute (jaxpr flops -6%) and
  its weight re-gathers.  Measured: collective 5.52e10 -> 2.10e10 (2.6x),
  memory 1.19e12 -> 6.29e11 — **but** peak 80.4 GiB: residency explodes.
  Confirmed for traffic, rejected on 16 GB capacity; the right trade on
  HBM-rich parts.  Kept off for v5e.

### cell C: nemotron-4-340b train_4k (single pod)

Baseline **did not fit**: 96 layers x d=18432 per-layer residual saves
are 41 GiB/chip alone (analytic); CPU-HLO peak 97 GiB.

| variant | compute_s | memory_s | collective_s | peak/dev |
|---|---|---|---|---|
""")
    parts.append(row("nemotron-4-340b__train_4k__single", "baseline"))
    parts.append(row("nemotron-4-340b__train_4k__single",
                     "optimized (2-level remat + accum16)"))
    parts.append("""
* *it 1 (`sp` residual)* — **refuted**: collective term 256s -> 1010s
  (at d_model=18432 the block-boundary gathers dwarf the saved
  all-reduces), peak 45 GiB.  Recorded and reverted.
* *it 2 (2-level recursive checkpointing, group=8)* — hypothesis: saving
  only every 8th residual (12 group boundaries + 8 in-group saves during
  that group's backward) cuts saved-activation residency O(96) -> O(20)
  for ~+27% recompute flops.  Measured: peak 97.0 -> **33.4 GiB (2.9x)**
  at jaxpr flops 2.69e18 -> 3.41e18 (+27%).  Confirmed exactly.
* *it 3 (+ accum 8 -> 16: microbatch-of-1 per chip)* — halves carry size
  and weight re-gathers per microbatch.  Measured: peak -> **25.5 GiB**,
  collective bytes 1.80e10 -> **1.19e10 (1.5x)**.  With the documented
  ~2x CPU-f32 inflation this is ~12.7 GiB TPU-native — **the 340B train
  cell now fits 16 GB/chip** (analytic: 9.8 GiB).  Stop: the third
  consecutive candidate (logit_chunks 32) predicted <5% on the dominant
  term.

### Kernel-level (Pallas) notes

The optical-DFT kernel keeps MXU-shaped 128x128x128 blocks; its fused
DAC-quantize + stage-1/stage-2 + |.|^2 design eliminates 4 of the 6 HBO
round-trips of the unfused op sequence (2 reads + 1 write vs 6 passes),
and the converter-boundary kernel fuses 3 pointwise passes into 1 — both
are memory-bound ops where fusion is the entire roofline story.  The flash
local-attention kernel streams (128 q x 128 kv) tiles with fp32 online-
softmax scratch, O(S) memory vs O(S^2); GQA is zero-copy via index maps.

### Verdict vs the paper

The paper's technique (profile -> Amdahl bound -> offload decision with
conversion costs) is reproduced as the *baseline analysis*; the beyond-
paper work is everything above: the paper has no distributed-sharding
story, and the three hillclimbs buy 2.2-3.0x on the dominant roofline
terms and turn two non-fitting cells into fitting ones.  The paper's floor
was built first; the ceiling pushed after.
""")
    return "\n".join(parts)


def planner_section() -> str:
    from benchmarks.planner_table import run as planner
    rows = planner()
    lines = [
        "## §Planner — the decision rule on the 10 assigned architectures",
        "",
        "FLOP mix traced per arch (scan-aware jaxpr attribution), host time",
        "priced at the TPU peak (most generous to the accelerator), offload",
        "priced with honest on-frontier converter costs (DESIGN.md §6).",
        "The 4f Fourier/conv accelerator finds *nothing* to offload in any",
        "LM backbone; the Anderson-class optical MVM engine offloads the",
        "matmuls but the activation conversion boundary caps the win — the",
        "paper's conclusion, generalized to modern LMs:",
        "",
        "| arch | matmul flops % | MVM-accel speedup | 4f speedup | "
        ">=10x? | conversion-bound? |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['flops_pct'].get('matmul', 0):.1f} | "
            f"{r['mvm_speedup']:.2f}x | {r['fourier_speedup']:.2f}x | "
            f"{'yes' if r['mvm_worthwhile'] else 'no'} | "
            f"{'yes' if r['mvm_conversion_bound'] else 'no'} |")
    lines += [
        "",
        "Per DESIGN.md §6 the negative verdicts are the *reproduced result*:",
        "the technique applies as an analysis to every arch, and correctly",
        "declines to build the accelerator for all of them.",
    ]
    return "\n".join(lines)


def misc_sections() -> str:
    from benchmarks.conversion_bottleneck import run as fig8
    from benchmarks.pareto import run as fig2
    from benchmarks.complexity_fig import run as fig3
    r8, r2, r3 = fig8(), fig2(), fig3()
    return f"""## §Fig8 — prototype data-movement split

Component-latency model calibrated to the paper's measured totals, vs the
software FFT measured on this host:

* hardware total **{r8['hardware_total_s']:.3f} s** (paper 5.209 s) of which
  **{r8['hardware_movement_pct']:.3f}%** is data movement (paper 99.599%)
* breakdown: DAC {r8['breakdown']['dac_s']*1e3:.2f} ms, ADC
  {r8['breakdown']['adc_s']*1e3:.2f} ms, interface
  {r8['breakdown']['interface_s']:.3f} s, optics
  {r8['breakdown']['analog_s']*1e3:.1f} ms
* hardware vs software FFT on this host: {r8['hardware_vs_software']:.0f}x
  slower (paper: 23.8x on the Raspberry Pi 4 — the ratio is host-dependent,
  the split is not)
* functional sim intensity error vs oracle: {r8['sim_intensity_rel_err']:.2e}

## §Fig2 — converter Pareto frontier

* Kim DAC frontier gap {r2['kim_dac_gap']:.2f}x, Liu ADC
  {r2['liu_adc_gap']:.2f}x (≈1: the paper's reference designs sit on the
  survey envelope)
* the converters Anderson et al.'s >=100,000x MAC-energy claim needs:
  **{r2['anderson_dac_gap']:.0f}x / {r2['anderson_adc_gap']:.0f}x below the
  frontier** — the paper's core §2 feasibility argument, reproduced.

## §Fig3 — compute vs conversion complexity (C = 2N)

crossover sizes where compute/conversion advantage first reaches 1x / 10x:

| class | 1x | 10x |
|---|---|---|
""" + "\n".join(
        f"| {k} | {r3['crossover_1x'][k]} | {r3['crossover_10x'][k]} |"
        for k in r3["crossover_1x"]) + """

O(N) never crosses: elementwise accelerators are *always*
conversion-bound — the paper's §4 rule.
"""


def main() -> None:
    base = load_cells(os.path.join(ROOT, "dryrun"))
    opt = load_cells(os.path.join(ROOT, "dryrun_opt"))
    doc = "\n\n".join([
        "# EXPERIMENTS",
        "",
        "All numbers regenerable: `python -m repro.launch.dryrun --all` "
        "(baseline), `--opt` (optimized), "
        "`python -m benchmarks.run` (paper tables), "
        "`python experiments/gen_experiments.py` (this file).",
        dryrun_section(base),
        roofline_section(base),
        perf_section(base, opt),
        amdahl_section(),
        planner_section(),
        misc_sections(),
    ])
    with open(OUT, "w") as f:
        f.write(doc)
    print(f"wrote {OUT} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
