"""Fault-tolerant checkpointing (atomic, hashed, async, mesh-elastic)."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
