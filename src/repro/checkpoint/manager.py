"""Fault-tolerant checkpointing: atomic, hashed, async, elastic.

Layout per step:

    <dir>/step_0000420/
        manifest.json     tree structure, shapes, dtypes, per-leaf sha256
        leaf_00000.npy ... one file per pytree leaf (np.save, fp32/int as-is)
    <dir>/LATEST          text file naming the newest *complete* step dir

Guarantees:
  * atomicity  — written to ``.tmp-<step>`` then os.rename'd; a crash
    mid-write can never corrupt LATEST (rename is atomic on POSIX).
  * integrity  — restore verifies each leaf's sha256 against the manifest;
    a corrupted checkpoint raises and the caller falls back to the previous
    step (see ``restore_latest(..., allow_fallback=True)``).
  * elasticity — leaves are stored *unsharded*; ``restore`` device_puts
    them with whatever sharding the (possibly different) target mesh needs,
    so a 256-chip checkpoint restores onto 512 chips and vice versa.
  * async      — ``save_async`` snapshots to host RAM synchronously
    (jax.device_get) and writes on a daemon thread; ``wait`` joins.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _tree_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----- write path ---------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        host_tree = jax.device_get(tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> str:
        name = f"step_{step:010d}"
        tmp = os.path.join(self.dir, f".tmp-{name}")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree_util.tree_flatten(host_tree)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            with open(os.path.join(tmp, fname), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"].append({
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, ".tmp-LATEST"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.dir, ".tmp-LATEST"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----- read path --------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_"):
                try:
                    out.append(int(n[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        try:
            return int(name[5:])
        except ValueError:
            return None

    def restore(self, step: int, like: Any, *,
                shardings: Any | None = None, verify: bool = True) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs); ``shardings`` (same structure, or None) places
        leaves onto the current mesh — different from the saving mesh is fine.
        """
        base = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves_like)}")
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        out = []
        for entry, tgt, shd in zip(manifest["leaves"], leaves_like, shard_leaves):
            path = os.path.join(base, entry["file"])
            if verify:
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != entry["sha256"]:
                    raise IOError(f"checksum mismatch in {path}")
            arr = np.load(path)
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"shape mismatch {arr.shape} vs {tgt.shape} "
                                 f"for {entry['file']}")
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any, *, shardings: Any | None = None,
                       allow_fallback: bool = True):
        """Returns (step, tree) from the newest valid checkpoint, walking
        backwards past corrupted ones when ``allow_fallback``."""
        candidates = sorted(self.steps(), reverse=True)
        last_err: Exception | None = None
        for step in candidates:
            try:
                return step, self.restore(step, like, shardings=shardings)
            except Exception as e:  # corrupted/incomplete -> try older
                last_err = e
                if not allow_fallback:
                    raise
        if last_err is not None:
            raise last_err
        return None, None
