"""Training step construction: grads, microbatch accumulation, optimizer.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with explicit shardings (see launch/dryrun.py and
launch/train.py).  Gradient accumulation scans over microbatches with fp32
accumulators, bounding the activation peak at (1/accum_steps) of the global
batch — how the 340B/671B train cells fit HBM (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.optim.base import Optimizer, apply_updates

__all__ = ["make_train_step", "make_eval_step"]


def _split_microbatches(batch: dict, accum: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(model: LM, optimizer: Optimizer, *, accum_steps: int = 1,
                    remat: bool = True) -> Callable:
    def loss_fn(params, mb):
        return model.loss(params, mb, remat=remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, accum_steps)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                gs, ls = carry
                (l, _), g = grad_fn(params, mb)
                gs = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gs, g)
                return (gs, ls + l), None

            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = {}
        updates, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params, step)
        new_params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: LM, *, remat: bool = False) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, remat=remat)
        return {"loss": loss, **metrics}
    return eval_step
