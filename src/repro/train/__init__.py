"""Training loop building blocks."""

from repro.train.steps import make_eval_step, make_train_step

__all__ = ["make_train_step", "make_eval_step"]
