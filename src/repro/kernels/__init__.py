"""Pallas TPU kernels for the perf-critical compute hot spots.

  optical_dft      — fused 4f pipeline: DAC quantize + DFT-as-matmul + |.|^2
  adc_dac          — fused converter-boundary emulation (one VMEM pass)
  local_attention  — blocked causal/sliding-window flash attention (GQA)

``ops`` holds the public jit wrappers; ``ref`` the pure-jnp oracles.
"""

from repro.kernels import ops, ref
from repro.kernels.common import INTERPRET

__all__ = ["ops", "ref", "INTERPRET"]
