"""Pallas TPU kernel: fused converter-boundary emulation (DAC -> noise -> ADC).

Emulating the digital/analog boundary in-model (quantization-aware training,
hardware-in-the-loop studies) is three pointwise passes if written naively:
quantize, add noise, re-quantize — each a full HBM round trip.  This kernel
fuses them into one VMEM pass: for activation-sized tensors the op is purely
memory-bound, so fusion is a straight ~3x HBM-traffic reduction.

The ADC in the real pipeline auto-ranges on the *global* max (see
``repro.core.optical.adc_quantize``); a global reduction cannot live in a
single elementwise pass, so the wrapper computes the scale with a cheap
jnp.max first (one extra read) and feeds it as a scalar-prefetch operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block

__all__ = ["converter_boundary"]


def _kernel(scale_ref, x_ref, noise_ref, o_ref, *, dac_levels: int,
            adc_levels: int, noise_std: float):
    x = x_ref[...].astype(jnp.float32)
    # DAC: fixed full-scale [0, 1] uniform quantizer.
    x = jnp.round(jnp.clip(x, 0.0, 1.0) * dac_levels) / dac_levels
    # Analog channel noise (pre-generated unit gaussians; std is static).
    if noise_std > 0.0:
        x = x + noise_std * noise_ref[...].astype(jnp.float32)
    # ADC: auto-ranged to the global scale computed by the wrapper.
    s = scale_ref[0]
    y = jnp.clip(x / s, 0.0, 1.0)
    o_ref[...] = (jnp.round(y * adc_levels) / adc_levels * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dac_bits", "adc_bits", "noise_std",
                                             "block_rows"))
def converter_boundary(x: jax.Array, noise: jax.Array | None = None, *,
                       dac_bits: int = 8, adc_bits: int = 8,
                       noise_std: float = 0.0, block_rows: int = 256) -> jax.Array:
    """Fused DAC -> analog noise -> ADC boundary for a 2-D tensor in [0, 1]."""
    h, w = x.shape
    if noise is None:
        noise = jnp.zeros_like(x)
    br = pick_block(h, block_rows, 8)
    bc = pick_block(w, 512, 128)
    scale = jnp.maximum(jnp.max(x), 1e-20).reshape(1)
    kern = functools.partial(
        _kernel,
        dac_levels=(1 << dac_bits) - 1,
        adc_levels=(1 << adc_bits) - 1,
        noise_std=noise_std,
    )
    grid = (h // br, w // bc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # scale (scalar)
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=INTERPRET,
    )(scale, x, noise)
