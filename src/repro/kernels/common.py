"""Shared Pallas kernel utilities.

Kernels are written for TPU (explicit BlockSpec VMEM tiling, MXU-aligned
block shapes) and validated on CPU with ``interpret=True``, which executes
the kernel body in Python.  ``INTERPRET`` flips automatically.
"""

from __future__ import annotations

import jax

__all__ = ["INTERPRET", "MXU", "LANE", "SUBLANE", "round_up", "pick_block"]

INTERPRET = jax.default_backend() != "tpu"

# TPU v5e geometry: 128x128 MXU systolic array; (8, 128) float32 VREG tiles.
MXU = 128
LANE = 128
SUBLANE = 8


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_block(dim: int, preferred: int, align: int) -> int:
    """Largest block <= preferred that divides ``dim``; falls back to dim.

    Keeps MXU alignment when the dimension allows it — callers pad inputs to
    ``align`` multiples before invoking kernels, so the fallback only fires
    for deliberately tiny test shapes.
    """
    if dim >= preferred and dim % preferred == 0:
        return preferred
    b = min(dim, preferred)
    while b > align and dim % b != 0:
        b -= align
    return b if dim % b == 0 else dim
