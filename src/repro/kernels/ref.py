"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the semantic specification of its kernel; tests sweep
shapes/dtypes and assert kernel-vs-oracle agreement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "optical_dft2_intensity_ref",
    "converter_boundary_ref",
    "local_attention_ref",
    "dft_stage1_ref",
    "dft_stage2_ref",
]


def _quantize(x: jax.Array, bits: int) -> jax.Array:
    levels = (1 << bits) - 1
    return jnp.round(jnp.clip(x, 0.0, 1.0) * levels) / levels


def dft_stage1_ref(wr, wi, a, *, dac_bits: int = 0):
    a = a.astype(jnp.float32)
    if dac_bits:
        a = _quantize(a, dac_bits)
    w = wr.astype(jnp.float32) + 1j * wi.astype(jnp.float32)
    t = w @ a.astype(jnp.complex64)
    return jnp.real(t), jnp.imag(t)


def dft_stage2_ref(tr, ti, wr, wi):
    t = tr.astype(jnp.float32) + 1j * ti.astype(jnp.float32)
    w = wr.astype(jnp.float32) + 1j * wi.astype(jnp.float32)
    u = t @ w.T
    return jnp.abs(u) ** 2


def optical_dft2_intensity_ref(a: jax.Array, *, dac_bits: int = 8) -> jax.Array:
    """|unitary 2-D DFT of quantize(a)|^2 — matches repro.core.optical."""
    a = _quantize(a.astype(jnp.float32), dac_bits) if dac_bits else a
    f = jnp.fft.fft2(a.astype(jnp.complex64), norm="ortho")
    return jnp.abs(f) ** 2


def converter_boundary_ref(x, noise=None, *, dac_bits=8, adc_bits=8,
                           noise_std=0.0):
    y = _quantize(x.astype(jnp.float32), dac_bits)
    if noise is not None and noise_std > 0.0:
        y = y + noise_std * noise.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(x), 1e-20)
    z = jnp.clip(y / scale, 0.0, 1.0)
    levels = (1 << adc_bits) - 1
    return (jnp.round(z * levels) / levels * scale).astype(x.dtype)


def local_attention_ref(q, k, v, *, scale=None, window: int = 0,
                        causal: bool = True, kv_groups: int = 1):
    """Dense masked softmax attention, (BH, Lq, D) x (BHkv, Lk, D)."""
    bh, lq, d = q.shape
    if scale is None:
        scale = d ** -0.5
    if kv_groups > 1:
        k = jnp.repeat(k, kv_groups, axis=0)
        v = jnp.repeat(v, kv_groups, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(lq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((lq, k.shape[1]), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
