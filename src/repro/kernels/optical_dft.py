"""Pallas TPU kernel: fused 4f-optics DFT pipeline (DFT-as-matmul + detector).

Hardware adaptation (DESIGN.md §3): the paper's accelerator computes a 2-D
Fourier transform by free-space diffraction.  On TPU the systolic MXU makes
the O(N^2) *matmul form* of the DFT the native equivalent:

    F = W_h @ A @ W_w^T,   W_n[j, k] = exp(-2 pi i j k / n) / sqrt(n)

Complex arithmetic is carried as separate (re, im) planes because the MXU
has no complex datapath.  The pipeline is two blocked complex matmuls with
the *physics fused in*:

  stage 1 (``dft_stage1``):  T = W_h @ quantize_dac(A)        (A real)
  stage 2 (``dft_stage2``):  I = |T @ W_w^T|^2                (detector)

Fusing the DAC quantizer into stage 1 and the square-law detector into
stage 2 keeps every intermediate in VMEM: HBM traffic is exactly one read
of A and one write of I (plus the small DFT factor matrices), vs 6 separate
HBM round-trips for the unfused op sequence.

Block shapes default to 128x128x128 (MXU-shaped); accumulation over the
contraction grid axis happens in fp32 VMEM scratch.  The contraction axis
is the *last* grid axis so TPU's sequential-grid guarantee makes the
accumulator carry valid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block

__all__ = [
    "dft_matrix_factors",
    "dft_stage1",
    "dft_stage2",
    "dft_stage1_batched",
    "dft_stage2_batched",
    "optical_dft2_intensity",
    "optical_dft2_intensity_batched",
]


def dft_matrix_factors(n: int, dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """(re, im) of the unitary DFT matrix W_n (host-side, once per size)."""
    j = jnp.arange(n, dtype=jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    ang = -2.0 * jnp.pi * jnp.outer(j, j) / n
    scale = 1.0 / jnp.sqrt(jnp.asarray(n, ang.dtype))
    return (jnp.cos(ang) * scale).astype(dtype), (jnp.sin(ang) * scale).astype(dtype)


# --- stage 1: T = W @ quantize(A), A real ------------------------------------


def _stage1_kernel(wr_ref, wi_ref, a_ref, tr_ref, ti_ref, acc_r, acc_i,
                   *, levels: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    a = a_ref[...].astype(jnp.float32)
    if levels > 0:  # fused DAC quantization (SLM drive resolution)
        a = jnp.round(jnp.clip(a, 0.0, 1.0) * levels) / levels
    acc_r[...] += jnp.dot(wr_ref[...].astype(jnp.float32), a,
                          preferred_element_type=jnp.float32)
    acc_i[...] += jnp.dot(wi_ref[...].astype(jnp.float32), a,
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        tr_ref[...] = acc_r[...].astype(tr_ref.dtype)
        ti_ref[...] = acc_i[...].astype(ti_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dac_bits", "bm", "bk", "bn"))
def dft_stage1(wr: jax.Array, wi: jax.Array, a: jax.Array, *,
               dac_bits: int = 0, bm: int = 128, bk: int = 128, bn: int = 128):
    """T = W @ quantize_dac(A).  W: (m, k) complex as (wr, wi); A: (k, n) real."""
    m, kdim = wr.shape
    _, n = a.shape
    bm = pick_block(m, bm, 8)
    bk = pick_block(kdim, bk, 128)
    bn = pick_block(n, bn, 128)
    grid = (m // bm, n // bn, kdim // bk)
    levels = (1 << dac_bits) - 1 if dac_bits else 0
    kern = functools.partial(_stage1_kernel, levels=levels, nk=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # W re
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # W im
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # A
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=INTERPRET,
    )(wr, wi, a)


# --- stage 1, batched: T[b] = W @ quantize(A[b]) ------------------------------


def _stage1_batched_kernel(wr_ref, wi_ref, a_ref, tr_ref, ti_ref, acc_r, acc_i,
                           *, levels: int, nk: int, bb: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    wr = wr_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    for b in range(bb):  # bb frames share one load of the factor blocks
        a = a_ref[b].astype(jnp.float32)
        if levels > 0:  # fused DAC quantization (SLM drive resolution)
            a = jnp.round(jnp.clip(a, 0.0, 1.0) * levels) / levels
        acc_r[b] += jnp.dot(wr, a, preferred_element_type=jnp.float32)
        acc_i[b] += jnp.dot(wi, a, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        for b in range(bb):
            tr_ref[b] = acc_r[b].astype(tr_ref.dtype)
            ti_ref[b] = acc_i[b].astype(ti_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dac_bits", "bb", "bm", "bk",
                                             "bn"))
def dft_stage1_batched(wr: jax.Array, wi: jax.Array, a: jax.Array, *,
                       dac_bits: int = 0, bb: int = 1, bm: int = 128,
                       bk: int = 128, bn: int = 128):
    """T[b] = W @ quantize_dac(A[b]) for a whole batch in ONE kernel launch.

    W: (m, k) complex as (wr, wi); A: (batch, k, n) real.  The batch rides
    the *first* Pallas grid axis, so one ``pallas_call`` serves every frame
    and the per-shape factor matrices (wr, wi) are loaded once and reused
    across the batch — their BlockSpec index map ignores the batch index,
    which is exactly the aperture-packing story of the runtime's batched
    boundary crossing (K frames, one launch, shared optics).

    Block sizes are caller-driven (the runtime derives them from the VMEM
    budget — ``repro.runtime.tiling.choose_blocks``): ``bb`` frames ride
    each grid step and share one load of the W blocks, ``bm/bk/bn`` tile
    the matmul itself.
    """
    batch, kdim, n = a.shape
    m, _ = wr.shape
    bb = pick_block(batch, bb, 1)
    bm = pick_block(m, bm, 8)
    bk = pick_block(kdim, bk, 128)
    bn = pick_block(n, bn, 128)
    grid = (batch // bb, m // bm, n // bn, kdim // bk)
    levels = (1 << dac_bits) - 1 if dac_bits else 0
    kern = functools.partial(_stage1_batched_kernel, levels=levels,
                             nk=grid[3], bb=bb)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda b, i, j, k: (i, k)),      # W re
            pl.BlockSpec((bm, bk), lambda b, i, j, k: (i, k)),      # W im
            pl.BlockSpec((bb, bk, bn), lambda b, i, j, k: (b, k, j)),  # A
        ],
        out_specs=[
            pl.BlockSpec((bb, bm, bn), lambda b, i, j, k: (b, i, j)),
            pl.BlockSpec((bb, bm, bn), lambda b, i, j, k: (b, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, m, n), jnp.float32),
            jax.ShapeDtypeStruct((batch, m, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, bm, bn), jnp.float32),
            pltpu.VMEM((bb, bm, bn), jnp.float32),
        ],
        interpret=INTERPRET,
    )(wr, wi, a)


# --- stage 2: I = |T @ W^T|^2 --------------------------------------------------


def _stage2_kernel(tr_ref, ti_ref, wr_ref, wi_ref, out_ref, acc_r, acc_i, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    tr = tr_ref[...].astype(jnp.float32)
    ti = ti_ref[...].astype(jnp.float32)
    # W^T block: we load W[j_block, k_block] and contract its *rows*, i.e.
    # dot(t, w.T) — dimension_numbers keep the transpose inside the MXU pass.
    wr = wr_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    dot_t = lambda x, w: jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    acc_r[...] += dot_t(tr, wr) - dot_t(ti, wi)
    acc_i[...] += dot_t(tr, wi) + dot_t(ti, wr)

    @pl.when(k == nk - 1)
    def _detector():  # fused square-law camera
        out_ref[...] = (acc_r[...] ** 2 + acc_i[...] ** 2).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def dft_stage2(tr: jax.Array, ti: jax.Array, wr: jax.Array, wi: jax.Array, *,
               bm: int = 128, bk: int = 128, bn: int = 128):
    """I = |T @ W^T|^2.  T: (m, k) complex; W: (n, k) complex; I: (m, n)."""
    m, kdim = tr.shape
    n, _ = wr.shape
    bm = pick_block(m, bm, 8)
    bk = pick_block(kdim, bk, 128)
    bn = pick_block(n, bn, 128)
    grid = (m // bm, n // bn, kdim // bk)
    kern = functools.partial(_stage2_kernel, nk=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # T re
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # T im
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),   # W re (row-major)
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),   # W im
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=INTERPRET,
    )(tr, ti, wr, wi)


# --- stage 2, batched: I[b] = |T[b] @ W^T|^2 ----------------------------------


def _stage2_batched_kernel(tr_ref, ti_ref, wr_ref, wi_ref, out_ref,
                           acc_r, acc_i, *, nk: int, bb: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    wr = wr_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    dot_t = lambda x, w: jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    for b in range(bb):  # bb frames share one load of the factor blocks
        tr = tr_ref[b].astype(jnp.float32)
        ti = ti_ref[b].astype(jnp.float32)
        acc_r[b] += dot_t(tr, wr) - dot_t(ti, wi)
        acc_i[b] += dot_t(tr, wi) + dot_t(ti, wr)

    @pl.when(k == nk - 1)
    def _detector():  # fused square-law camera
        for b in range(bb):
            out_ref[b] = (acc_r[b] ** 2 + acc_i[b] ** 2).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bm", "bk", "bn"))
def dft_stage2_batched(tr: jax.Array, ti: jax.Array, wr: jax.Array,
                       wi: jax.Array, *, bb: int = 1, bm: int = 128,
                       bk: int = 128, bn: int = 128):
    """I[b] = |T[b] @ W^T|^2 for a whole batch in ONE kernel launch.

    T: (batch, m, k) complex as (tr, ti); W: (n, k) complex; I: (batch, m, n).
    Like :func:`dft_stage1_batched`, the batch is the first grid axis, the
    W factor blocks are shared across it, and the block sizes (``bb``
    frames per grid step, ``bm/bk/bn`` matmul tiles) are caller-driven —
    the runtime derives them from the VMEM budget.
    """
    batch, m, kdim = tr.shape
    n, _ = wr.shape
    bb = pick_block(batch, bb, 1)
    bm = pick_block(m, bm, 8)
    bk = pick_block(kdim, bk, 128)
    bn = pick_block(n, bn, 128)
    grid = (batch // bb, m // bm, n // bn, kdim // bk)
    kern = functools.partial(_stage2_batched_kernel, nk=grid[3], bb=bb)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm, bk), lambda b, i, j, k: (b, i, k)),  # T re
            pl.BlockSpec((bb, bm, bk), lambda b, i, j, k: (b, i, k)),  # T im
            pl.BlockSpec((bn, bk), lambda b, i, j, k: (j, k)),        # W re
            pl.BlockSpec((bn, bk), lambda b, i, j, k: (j, k)),        # W im
        ],
        out_specs=pl.BlockSpec((bb, bm, bn), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb, bm, bn), jnp.float32),
            pltpu.VMEM((bb, bm, bn), jnp.float32),
        ],
        interpret=INTERPRET,
    )(tr, ti, wr, wi)


def optical_dft2_intensity(a: jax.Array, *, dac_bits: int = 8,
                           block: int = 128) -> jax.Array:
    """Full fused pipeline: detector intensity of the 2-D unitary DFT of ``a``.

    Matches ``repro.core.optical`` with amplitude encoding, no noise, and no
    ADC quantization (the ADC is a separate global-auto-range pass — see
    ``repro.kernels.adc_dac``).
    """
    h, w = a.shape
    whr, whi = dft_matrix_factors(h)
    wwr, wwi = dft_matrix_factors(w)
    tr, ti = dft_stage1(whr, whi, a, dac_bits=dac_bits,
                        bm=block, bk=block, bn=block)
    return dft_stage2(tr, ti, wwr, wwi, bm=block, bk=block, bn=block)


@functools.partial(jax.jit, static_argnames=("dac_bits",))
def _dft2_intensity_batched_xla(a: jax.Array, *, dac_bits: int) -> jax.Array:
    """One fused batched XLA dispatch with the kernel pipeline's semantics:
    DAC quantize -> unitary 2-D DFT -> square-law detector, (b, h, w) in/out."""
    a = a.astype(jnp.float32)
    if dac_bits:
        levels = (1 << dac_bits) - 1
        a = jnp.round(jnp.clip(a, 0.0, 1.0) * levels) / levels
    f = jnp.fft.fft2(a.astype(jnp.complex64), norm="ortho")
    return jnp.abs(f) ** 2


def optical_dft2_intensity_batched(a: jax.Array, *, dac_bits: int = 8,
                                   block: int = 128, bb: int = 1,
                                   use_pallas: bool | None = None) -> jax.Array:
    """Batched fused pipeline: ``a`` is (batch, h, w), output (batch, h, w).

    On TPU this is two kernel launches total for the whole batch (vs
    2 * batch for a loop over :func:`optical_dft2_intensity`): the factor
    matrices are computed once per shape and every frame shares them via
    the batched grid axis.  Off-TPU, Pallas interpret mode is a
    *correctness* simulator — every grid step functionally updates the
    whole (batch, h, w) output buffer, so a batched interpret call copies
    batch-times more memory than the loop it replaces and inverts the perf
    story — so the same batched semantics execute as ONE fused XLA dispatch
    instead (``use_pallas`` overrides the automatic choice for tests).
    Either way the caller gets a single batched invocation per group.
    """
    if use_pallas is None:
        use_pallas = not INTERPRET
    if not use_pallas:
        return _dft2_intensity_batched_xla(a, dac_bits=dac_bits)
    _, h, w = a.shape
    whr, whi = dft_matrix_factors(h)
    wwr, wwi = dft_matrix_factors(w)
    tr, ti = dft_stage1_batched(whr, whi, a, dac_bits=dac_bits, bb=bb,
                                bm=block, bk=block, bn=block)
    return dft_stage2_batched(tr, ti, wwr, wwi, bb=bb, bm=block, bk=block,
                              bn=block)
