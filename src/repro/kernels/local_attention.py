"""Pallas TPU kernel: blocked causal / sliding-window (local) flash attention.

Used by the RecurrentGemma hybrid blocks (window=2048 local attention) and
by long-context prefill, where materializing the (L x L) score matrix is the
memory-roofline killer.  Online-softmax streaming keeps the working set at
O(block_q x block_k) in VMEM.

Design notes (TPU):
  * grid = (batch*q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
    last (sequential) grid dimension so fp32 VMEM scratch (acc, m, l) carries
    across kv steps — the standard MaxText/TPU flash pattern.
  * GQA is zero-copy: K/V BlockSpec index maps divide the head index by the
    group size instead of materializing repeated KV heads.
  * Fully-masked (q_block, kv_block) tiles still execute in this validation
    kernel; the production grid prunes them with a lower-triangular +
    window-band index map (see the `skip` computation — it is exact, and on
    TPU becomes a `pl.when` guard over the whole body).
  * Masking uses -1e30 (not -inf) so m stays finite and exp() never NaNs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block

__all__ = ["local_flash_attention"]

_NEG = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
                 scale: float, window: int, causal: bool,
                 bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, _NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    i = pl.program_id(1)
    q_idx = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window > 0:
        mask &= (q_idx - k_idx) < window

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, _NEG)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
    m_sc[...] = m_new
    acc[...] = acc[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        l = jnp.maximum(l_sc[...], 1e-20)[:, None]
        o_ref[0, ...] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "causal",
                                             "block_q", "block_k", "kv_groups"))
def local_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          scale: float | None = None, window: int = 0,
                          causal: bool = True, block_q: int = 128,
                          block_k: int = 128, kv_groups: int = 1) -> jax.Array:
    """Flash attention with optional sliding window.

    Args:
      q: (BH, Lq, D) — batch*query-heads flattened.
      k, v: (BHkv, Lk, D) with BHkv = BH // kv_groups (GQA via index maps).
      window: 0 = unlimited (pure causal); w > 0 = each query attends to at
        most ``w`` most recent keys (RecurrentGemma local attention).
      causal: lower-triangular masking (assumes aligned q/k positions).
    """
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh == bhkv * kv_groups, (bh, bhkv, kv_groups)
    if scale is None:
        scale = d ** -0.5
    bq = pick_block(lq, block_q, 8)
    bk = pick_block(lk, block_k, 128)
    grid = (bh, lq // bq, lk // bk)
    kern = functools.partial(_attn_kernel, scale=scale, window=window,
                             causal=causal, bq=bq, bk=bk, nk=grid[2])
    g = kv_groups
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v)
