"""Public jit'd wrappers over the Pallas kernels.

Models and benchmarks import from here; the raw kernels stay private so the
BlockSpec plumbing can evolve without touching call sites.  On non-TPU
backends every kernel runs in ``interpret=True`` mode (bit-accurate Python
execution of the kernel body).
"""

from __future__ import annotations

import jax

from repro.kernels.adc_dac import converter_boundary
from repro.kernels.local_attention import local_flash_attention
from repro.kernels.optical_dft import (
    dft_matrix_factors,
    dft_stage1,
    dft_stage1_batched,
    dft_stage2,
    dft_stage2_batched,
    optical_dft2_intensity,
    optical_dft2_intensity_batched,
)

__all__ = [
    "optical_dft2_intensity",
    "optical_dft2_intensity_batched",
    "dft_stage1",
    "dft_stage1_batched",
    "dft_stage2",
    "dft_stage2_batched",
    "dft_matrix_factors",
    "converter_boundary",
    "local_flash_attention",
    "gqa_flash_attention",
]


def gqa_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0, causal: bool = True,
                        block_q: int = 128, block_k: int = 128) -> jax.Array:
    """(B, Hq, L, D) grouped-query flash attention over 4-D operands.

    Flattens (batch, heads) onto the kernel's leading grid axis; KV heads
    are shared across groups inside the kernel via index maps (no repeat).
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    groups = hq // hkv
    out = local_flash_attention(
        q.reshape(b * hq, lq, d),
        k.reshape(b * hkv, lk, d),
        v.reshape(b * hkv, lk, d),
        window=window, causal=causal, block_q=block_q, block_k=block_k,
        kv_groups=groups,
    )
    return out.reshape(b, hq, lq, d)
