"""Multi-device sharded offload: scatter one invocation across accelerators.

Photonic systems scale by *replicating apertures*, not by growing one (a
bigger SLM needs a bigger lens, a longer path, and a denser camera; a second
4f engine needs none of that).  This module makes that scaling mode
executable: :class:`ShardedOpticalBackend` wraps any registered inner
backend (``host`` / ``optical-sim`` / ``ideal``) and splits each batched
invocation across ``ctx.n_devices`` simulated accelerators, two ways:

  group sharding   the stacked ``(K, H, W)`` flush group scatters across
                   devices — device d carries a contiguous slice of the
                   batch through its OWN converters, so every device pays
                   its own DAC/ADC boundary crossing (per-invocation fixed
                   costs do NOT amortize across devices) but the crossings
                   run concurrently: the modeled wall is max-over-devices
                   plus a per-device sync epsilon
                   (``batched_step_cost(n_devices=...)``).
  frame sharding   one large frame tiles onto multiple apertures.  ``conv``
                   uses overlap-save: each device receives its row block
                   plus a circular halo covering the kernel's support, runs
                   the 4f pipeline on the extended tile, and discards the
                   halo rows — exact up to per-device converter
                   quantization (each aperture's detector auto-exposes its
                   own tile, precisely the "every device pays its own
                   boundary" story).  ``matmul`` row-splits the activation
                   block (no halo needed — rows are independent).  ``fft``
                   never frame-shards: the 2-D DFT is global, so tiling
                   would need a cross-device transpose between the two 1-D
                   stages — it group-shards instead.

Dispatch reuses the ``distributed/`` mesh plumbing:
:func:`repro.distributed.sharding.shard_devices` picks the active context
mesh's devices (or ``jax.devices()``) and each shard is ``device_put`` onto
its own device, so JAX's async dispatch runs the shards concurrently —
``shard_map``-style scatter without requiring the inner backends to be
traceable under a mesh.  With fewer real devices than shards (the CPU test
environment: one device) the same shards dispatch sequentially with
identical numerics — the off-mesh fallback the equivalence property tests
lock down: sharded == single-device batched == looped per-frame, on every
backend.

Per-device boundary traffic is surfaced to the executor via
:meth:`ShardedOpticalBackend.take_device_samples` and aggregated by
:class:`~repro.runtime.telemetry.RuntimeTelemetry`.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import StepCost
from repro.core.optical import optical_conv2d_batched
from repro.distributed.sharding import shard_devices
from repro.runtime.faults import DeviceLostError, FaultError
from repro.runtime.backends import (
    CONV_CAPTURES,
    BackendContext,
    ExecutionBackend,
    _host_circular_conv,
    _host_matmul,
    _optical_matmul_batched,
    conv_range_map,
    get_backend,
    ideal_step_cost,
    register_backend,
)
from repro.runtime.residency import operating_point, residency_key

__all__ = ["ShardedOpticalBackend", "shard_sizes", "kernel_halo"]


@dataclasses.dataclass
class _Placement:
    """One committed sharded placement for a (category, group-shape).

    ``assign`` maps each frame's content key to the pool slot whose device
    holds it resident; the mapping replicates the executor's exact
    dispatch structure (per-tile ``shard_sizes`` split over the survivor
    pool), so a placed tile dispatches the same per-device stack shapes
    the re-scatter path compiles — warm parity by construction.  The
    placement outlives tiles AND flushes: frames stay device-resident in
    the ``ResidencyCache``'s per-device sets until their content changes
    (only changed frames re-cross the DAC) or a device quarantines (the
    placement drops and the next commit rebuilds on survivors)."""

    pool: list[int]                 # logical device slots (survivors)
    devices: list | None            # jax devices (None: sequential fallback)
    assign: dict[tuple, int]        # frame content key -> pool slot
    frames: int = 0                 # frames covered at commit time

# Inners frame sharding knows how to drive (group sharding takes any inner).
_FRAME_INNERS = ("host", "optical-sim", "ideal")


def _device_span(ctx, d: int, frames: int):
    """Span over one device's host-side scatter staging (device_put + inner
    dispatch) when the owning executor traces; no-op otherwise.  This is
    the instrumentation that makes the sharded wall regression *visible*:
    the per-device loop runs on the host sequentially, so its spans sum to
    the serial staging cost the modeled max-over-devices wall never pays."""
    tr = getattr(ctx, "tracer", None)
    if tr is None:
        return contextlib.nullcontext()
    return tr.span("scatter", lane=f"device{d}", device=d, frames=frames)


def _stage_span(ctx, d: int, frames: int):
    """Span over JUST the host->device staging work for one shard (the
    ``device_put`` + residency bookkeeping inside the broader ``scatter``
    span, compute launch excluded).  Summed per flush this is the
    re-scatter tax a committed placement eliminates: on a resident hit the
    span closes in microseconds because nothing crosses."""
    tr = getattr(ctx, "tracer", None)
    if tr is None:
        return contextlib.nullcontext()
    return tr.span("scatter_stage", lane=f"device{d}", device=d,
                   frames=frames)


def _gather_span(ctx, n_blocks: int):
    """Span over the host-side gather + reassembly of per-device blocks."""
    tr = getattr(ctx, "tracer", None)
    if tr is None:
        return contextlib.nullcontext()
    return tr.span("gather", lane="host", blocks=n_blocks)


def shard_sizes(total: int, n: int) -> list[int]:
    """Balanced contiguous shard sizes over ``n`` devices.

    The first ``total % n`` shards carry one extra item, so ``max(sizes) ==
    ceil(total / n)`` — exactly the largest-shard crossing the cost model's
    max-over-devices pricing charges.  Never returns more shards than
    items (``n`` is clamped), so a 3-deep group on 4 devices uses 3.
    """
    n = max(1, min(n, total))
    base, rem = divmod(total, n)
    return [base + (1 if i < rem else 0) for i in range(n)]


def kernel_halo(kernel: jax.Array) -> tuple[int, int]:
    """(halo_top, halo_bottom) rows a conv tile needs for overlap-save.

    Circular conv: ``out[i] = sum_r k[r] * a[(i - r) mod H]``.  A kernel row
    ``r`` is read as the circular offset ``r`` (if ``r <= H/2``) or ``r - H``
    (wrap-around support, e.g. the bottom rows of a centered kernel):
    positive offsets pull input rows *above* the tile, negative ones below.
    """
    k = np.asarray(kernel)
    rows = np.nonzero(np.any(k != 0, axis=-1))[0]
    if rows.size == 0:
        return 0, 0
    h = k.shape[-2]
    off = np.where(rows <= h // 2, rows, rows - h)
    return int(max(off.max(), 0)), int(max(-off.min(), 0))


def _gather_blocks(blocks: list[jax.Array], devices) -> list[jax.Array]:
    """Bring per-device output tiles back onto one device before they are
    concatenated: a jitted concatenate over operands committed to distinct
    devices is an error, and the reassembled frame is host-facing anyway."""
    if devices is None:
        return blocks
    home = jax.devices()[0]
    return [jax.device_put(b, home) for b in blocks]


def _fold_kernel(kernel: jax.Array, ext: int) -> jax.Array:
    """Re-express ``kernel``'s circular row support on an ``ext``-row tile.

    Each support offset lands at ``offset % ext``; offsets are distinct mod
    ``ext`` because the tile always spans ``halo_top + halo_bottom + rows``
    with ``rows >= 1``."""
    k = np.asarray(kernel)
    h = k.shape[-2]
    out = np.zeros((ext,) + k.shape[-1:], k.dtype)
    for r in np.nonzero(np.any(k != 0, axis=-1))[0]:
        off = int(r) if r <= h // 2 else int(r) - h
        out[off % ext] = k[r]
    return jnp.asarray(out)


class ShardedOpticalBackend(ExecutionBackend):
    """Scatter each batched invocation across ``ctx.n_devices`` accelerators.

    Wraps a registered inner backend; with ``ctx.n_devices == 1`` it is a
    transparent pass-through.  ``ctx.shard_mode`` selects the split:

      ``"auto"``   group-shard whenever whole frames can feed the fleet —
                   including shallow groups, which simply occupy fewer
                   devices (tight numerics, zero halo traffic); frame-shard
                   only when a frame is genuinely too big for one aperture
                   (``usable_pixels``) or MVM core.  ``fft`` always
                   group-shards.
      ``"group"``  always scatter the batch.
      ``"frame"``  always tile frames (conv: overlap-save halos; matmul:
                   row split; fft falls back to group).
    """

    def __init__(self, inner: str = "optical-sim") -> None:
        self.inner_name = inner
        self.name = "sharded" if inner == "optical-sim" else f"sharded-{inner}"
        self._inner: ExecutionBackend | None = None
        self._last_device_samples: list[tuple[int, int]] | None = None
        self._fold_cache: dict[tuple, jax.Array] = {}
        # (category, frame shape, dtype) -> committed device placement
        self._placements: dict[tuple, _Placement] = {}

    def _folded(self, kernel: jax.Array, ext: int,
                ctx: BackendContext) -> jax.Array:
        """Cached :func:`_fold_kernel`: one refold per (kernel content,
        tile height) instead of one per device per flush."""
        key = ctx.content_key(kernel) + (ext,)
        if key not in self._fold_cache:
            if len(self._fold_cache) >= 64:
                self._fold_cache.clear()
            self._fold_cache[key] = _fold_kernel(kernel, ext)
        return self._fold_cache[key]

    @property
    def inner(self) -> ExecutionBackend:
        if self._inner is None:
            self._inner = get_backend(self.inner_name)
        return self._inner

    def supports(self, category: str, ctx: BackendContext) -> bool:
        return self.inner.supports(category, ctx)

    def take_device_samples(self) -> list[tuple[int, int]] | None:
        """Per-device (samples_in, samples_out) of the last ``run`` — popped
        by the executor right after dispatch and recorded into telemetry at
        retire time."""
        samples, self._last_device_samples = self._last_device_samples, None
        return samples

    # -- device-resident placements --------------------------------------------
    def _survivor_pool(self, ctx) -> list[int]:
        """Logical device slots currently healthy: the fleet minus
        quarantined devices (device 0 serves alone when all are out)."""
        q = getattr(ctx, "quarantine", None)
        clock = getattr(ctx, "clock", None)
        now = clock() if clock is not None else 0.0
        n = max(1, int(ctx.n_devices))
        pool = [d for d in range(n)
                if q is None or not q.is_quarantined(("device", d), now)]
        return pool or [0]

    def commit_placement(self, category, xs, ctx, *, kernel=None,
                         weights=None, tile_sizes=None):
        """Commit ONE sharded placement for a released group (the executor
        calls this before its tile loop whenever a residency cache is
        attached).

        The placement records which pool slot each frame belongs to,
        replicating the dispatch structure exactly: the group streams as
        ``tile_sizes`` sub-invocations and each tile shard-splits over the
        survivor pool, so slot assignment runs per tile.  Frames are NOT
        staged here — the first placed dispatch ``device_put``s each frame
        once (a residency miss) and every later tile/flush serves it from
        the device (a hit, no DAC re-crossing).  Re-committing an
        unchanged group is free; a changed group re-maps and only the
        changed frames re-ship.  Returns the placement, or ``None`` when
        placements do not apply (no cache, single device, frame-sharded
        mode, or the sequential off-mesh fallback)."""
        res = getattr(ctx, "residency", None)
        if res is None or not xs:
            return None
        if self._resolve_mode(category, xs, ctx) != "group":
            return None
        pool = self._survivor_pool(ctx)
        sizes = shard_sizes(len(xs), len(pool))
        pool = pool[:len(sizes)]
        # the physical device list is indexed by LOGICAL pool id, not by
        # slot position: a quarantine-shrunk pool like [0, 2, 3] must keep
        # staging logical device 2's frames on the SAME physical device
        # its ("device", 2) resident entries already live on, or a shard
        # would stack label-resident frames with fresh device_puts homed
        # elsewhere (mixed-device stack -> jit refuses)
        devices = shard_devices(max(pool) + 1)
        if devices is None:
            # fewer real devices than the pool spans: dispatch is the
            # sequential fallback and nothing is committed device-side
            return None
        pkey = (category, tuple(xs[0].shape), str(xs[0].dtype))
        assign: dict[tuple, int] = {}
        start = 0
        for t in (tile_sizes if tile_sizes is not None else [len(xs)]):
            tile = xs[start:start + t]
            start += t
            s0 = 0
            for slot, size in enumerate(shard_sizes(len(tile), len(pool))):
                for x in tile[s0:s0 + size]:
                    assign[ctx.content_key(x)] = slot
                s0 += size
        cur = self._placements.get(pkey)
        if cur is not None and cur.pool == pool and cur.assign == assign:
            return cur
        if cur is not None:
            # donate the stale device buffers of frames that changed since
            # the last commit: their re-stage is about to device_put a
            # fresh copy, and keeping the old one resident would hold two
            # copies of the frame against the staging budget until LRU
            # pressure happened to evict the dead one
            op = operating_point(ctx.spec)
            for ck, slot in cur.assign.items():
                if ck not in assign and slot < len(cur.pool):
                    res.discard(("device", cur.pool[slot]),
                                ("frame-shard", op, (ck,)), ctx=ctx)
        pl = _Placement(pool=pool, devices=devices, assign=assign,
                        frames=len(xs))
        self._placements[pkey] = pl
        tr = getattr(ctx, "tracer", None)
        if tr is not None:
            tr.instant("placement", lane="sched", event="commit",
                       category=category, frames=len(xs),
                       devices=len(pool),
                       rebuilt=cur is not None)
            tr.metrics.counter("placements", event="commit",
                               category=category).inc()
        return pl

    def _placement_for(self, category, xs, ctx) -> _Placement | None:
        """The committed placement covering every frame of ``xs``, if one
        exists and references only healthy devices; ``None`` routes the
        dispatch down the legacy re-scatter path."""
        res = getattr(ctx, "residency", None)
        if res is None or not xs:
            return None
        pl = self._placements.get(
            (category, tuple(xs[0].shape), str(xs[0].dtype)))
        if pl is None:
            return None
        if any(ctx.content_key(x) not in pl.assign for x in xs):
            return None
        q = getattr(ctx, "quarantine", None)
        if q is not None:
            clock = getattr(ctx, "clock", None)
            now = clock() if clock is not None else 0.0
            if any(q.is_quarantined(("device", d), now) for d in pl.pool):
                return None
        return pl

    def _drop_placements_for_device(self, ctx, d: int) -> None:
        """Quarantine/device-loss cleanup: every placement referencing the
        dead device drops, so the next commit rebuilds on survivors."""
        stale = [k for k, pl in self._placements.items() if d in pl.pool]
        tr = getattr(ctx, "tracer", None)
        for k in stale:
            del self._placements[k]
            if tr is not None:
                tr.instant("placement", lane="sched", event="invalidate",
                           category=k[0], device=d)
                tr.metrics.counter("placements", event="invalidate",
                                   category=k[0]).inc()

    def _inner_run_on(self, category, shard, ctx, kernel, weights, device):
        """Run the inner backend with the context's ``stage_stream`` pinned
        to logical ``device`` for the duration of the call, so delta
        classification's per-slot code signatures never alias across
        devices — two devices' same-shaped sub-groups stage into different
        physical write streams even under the sequential off-mesh
        fallback."""
        prev = getattr(ctx, "stage_stream", "host")
        ctx.stage_stream = ("device", device)
        try:
            return self.inner.run(category, shard, ctx, kernel=kernel,
                                  weights=weights)
        finally:
            ctx.stage_stream = prev

    # -- dispatch --------------------------------------------------------------
    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        mode = self._resolve_mode(category, xs, ctx)
        if mode == "none":
            outs, cost = self.inner.run(category, xs, ctx, kernel=kernel,
                                        weights=weights)
            self._last_device_samples = [
                (sum(int(x.size) for x in xs), sum(int(o.size) for o in outs))]
            return outs, cost
        if mode == "group":
            return self._run_group(category, xs, ctx, kernel, weights)
        if self.inner_name not in _FRAME_INNERS:
            raise ValueError(
                f"frame sharding supports inners {_FRAME_INNERS}, "
                f"not {self.inner_name!r}")
        if category == "conv":
            return self._frame_conv(xs, ctx, kernel)
        if category == "matmul":
            return self._frame_matmul(xs, ctx, weights)
        raise ValueError(f"frame sharding does not support {category!r}")

    def _resolve_mode(self, category, xs, ctx) -> str:
        n = max(1, int(ctx.n_devices))
        if n == 1:
            return "none"
        if category == "fft":
            # the 2-D DFT is global: tiling one frame would need a
            # cross-device transpose between the row and column stages
            return "group"
        if ctx.shard_mode == "auto":
            # Group sharding whenever whole frames can feed every device
            # (tight numerics, zero halo traffic).  Tiling is reserved for
            # frames genuinely too big for one aperture/core — a shallow
            # group of small frames group-shards over fewer devices rather
            # than trading exactness for fan-out mid-flush.
            if len(xs) >= n or not self._frame_worthwhile(category, xs, ctx):
                return "group"
            return "frame"
        return ctx.shard_mode

    @staticmethod
    def _frame_worthwhile(category, xs, ctx) -> bool:
        """True when one frame overflows a single device's aperture (4f) or
        optical core (MVM), so tiling it is the only way to stop a lone
        device paying multiple serial settles/handshakes."""
        spec = ctx.spec
        if category == "conv":
            cap = getattr(spec, "usable_pixels", 0)
        else:
            cap = spec.rows * spec.cols if hasattr(spec, "rows") else 0
        return cap > 0 and int(xs[0].size) > cap

    # -- (a) group sharding: scatter the stacked flush group -------------------
    def _run_group(self, category, xs, ctx, kernel, weights):
        pl = self._placement_for(category, xs, ctx)
        if pl is not None:
            return self._run_group_placed(category, xs, ctx, kernel,
                                          weights, pl)
        clock = getattr(ctx, "clock", None)
        # scatter only across survivors: quarantined devices sit out until
        # their probation window clears (with the whole fleet quarantined,
        # device 0 serves alone rather than the dispatch failing)
        pool = self._survivor_pool(ctx)
        # chaos-injected device loss is a property of THIS dispatch only;
        # the injector clears ctx.lost_devices after the run
        lost = frozenset(getattr(ctx, "lost_devices", frozenset()) or ())
        sizes = shard_sizes(len(xs), len(pool))
        devices = shard_devices(len(sizes))
        outs: list[jax.Array] = []
        costs: list[StepCost | None] = []
        samples: list[tuple[int, int]] = []
        start = 0
        for i, size in enumerate(sizes):
            shard = xs[start:start + size]
            start += size
            d = pool[i]
            t0 = clock() if clock is not None else 0.0
            try:
                if d in lost:
                    raise DeviceLostError(d)
                with _device_span(ctx, d, size):
                    o, c = self._shard_dispatch(category, shard, ctx, kernel,
                                                weights, devices, i, device=d)
            except FaultError as e:
                # the shard's device failed mid-scatter: quarantine it and
                # re-run the SAME shard on a surviving device — every frame
                # still retires, from survivors, in order
                self._note_device_fault(ctx, category, d, e)
                self._quarantine_device(ctx, d, reason=e.kind)
                sv = next((s for s in pool if s != d and s not in lost), d)
                with _device_span(ctx, sv, size):
                    o, c = self._shard_dispatch(category, shard, ctx, kernel,
                                                weights, devices, i, device=sv)
                d = sv
            else:
                dt = (clock() - t0) if clock is not None else 0.0
                self._observe_shard(ctx, category, d, dt, c)
            outs.extend(o)
            costs.append(c)
            samples.append((sum(int(x.size) for x in shard),
                            sum(int(v.size) for v in o)))
        self._last_device_samples = samples
        return outs, self._combine(costs, len(sizes), ctx)

    def _shard_dispatch(self, category, shard, ctx, kernel, weights,
                        devices, slot, *, device=0):
        """One shard through the inner backend on placement ``slot``.

        With a residency cache attached, the committed shard list is kept
        under the LOGICAL device label ``("device", d)``: a re-scatter of
        the same frames to the same device skips the ``device_put`` entirely
        (the per-shard grain is what makes partial residency real — only
        the shards whose content changed re-ship).  Quarantining a device
        drops its resident set, so a recovered device always re-stages.
        """
        if devices is not None:
            res = getattr(ctx, "residency", None)
            key = None
            if res is not None:
                key = residency_key(ctx, shard, "shard")
                cached = res.lookup(("device", device), key,
                                    category=category, ctx=ctx)
                if cached is not None:
                    return self._inner_run_on(category, cached, ctx,
                                              kernel, weights, device)
            # only the frames are committed per device: the kernel /
            # weights (and the masks derived from them) stay
            # uncommitted, so jit moves them to whichever device
            # each shard's stack pins the computation to — one
            # cached mask and one content hash serve the whole fleet
            with _stage_span(ctx, device, len(shard)):
                shard = [jax.device_put(x, devices[slot % len(devices)])
                         for x in shard]
                if res is not None:
                    nbytes = sum(int(getattr(x, "nbytes", x.size * 4))
                                 for x in shard)
                    res.store(("device", device), key, list(shard), nbytes,
                              category=category, kind="shard", ctx=ctx)
        return self._inner_run_on(category, shard, ctx, kernel, weights,
                                  device)

    def _run_group_placed(self, category, xs, ctx, kernel, weights, pl):
        """Group sharding through a committed device placement.

        Frames regroup by their committed slot (for a tile sub-stack this
        reproduces the tile's own ``shard_sizes`` split, so the compiled
        stack shapes match the re-scatter path) and each shard serves its
        frames from per-device residency: only frames whose content
        changed since commit re-cross the host->device boundary, and the
        per-device output blocks gather only at readout.  A device fault
        mid-dispatch quarantines the device, drops the placement, and
        re-runs the shard on a survivor — the next commit rebuilds."""
        clock = getattr(ctx, "clock", None)
        lost = frozenset(getattr(ctx, "lost_devices", frozenset()) or ())
        slots: dict[int, list[int]] = {}
        for i, x in enumerate(xs):
            slots.setdefault(pl.assign[ctx.content_key(x)], []).append(i)
        outs: list = [None] * len(xs)
        costs: list[StepCost | None] = []
        samples: list[tuple[int, int]] = []
        for slot in sorted(slots):
            idxs = slots[slot]
            shard = [xs[i] for i in idxs]
            d = pl.pool[slot]
            t0 = clock() if clock is not None else 0.0
            try:
                if d in lost:
                    raise DeviceLostError(d)
                with _device_span(ctx, d, len(shard)):
                    o, c = self._placed_dispatch(category, shard, ctx,
                                                 kernel, weights, pl, slot)
            except FaultError as e:
                self._note_device_fault(ctx, category, d, e)
                # drops this placement too (see _quarantine_device), so
                # the next commit rebuilds on the survivors
                self._quarantine_device(ctx, d, reason=e.kind)
                sv = next((s for s in pl.pool if s != d and s not in lost),
                          d)
                with _device_span(ctx, sv, len(shard)):
                    # pl.devices is logical-id indexed, so the survivor's
                    # own id is the right physical slot for the re-put
                    o, c = self._shard_dispatch(
                        category, shard, ctx, kernel, weights, pl.devices,
                        sv % len(pl.devices), device=sv)
                d = sv
            else:
                dt = (clock() - t0) if clock is not None else 0.0
                self._observe_shard(ctx, category, d, dt, c)
            for i, v in zip(idxs, o):
                outs[i] = v
            costs.append(c)
            samples.append((sum(int(x.size) for x in shard),
                            sum(int(v.size) for v in o)))
        self._last_device_samples = samples
        return outs, self._combine(costs, len(slots), ctx)

    def _placed_dispatch(self, category, shard, ctx, kernel, weights, pl,
                         slot):
        """One placed shard through the inner backend: every frame is
        served from (or committed into) its device's resident set at
        per-frame grain, so a tile sub-range and a repeat flush both hit
        without re-shipping unchanged neighbors.  The residency store
        replaces a changed frame's buffer in place — the donation that
        keeps only *changed* shards re-crossing the DAC."""
        res = ctx.residency
        d = pl.pool[slot]
        # index the physical device by LOGICAL pool id, not slot position:
        # after a quarantine shrinks the pool, logical device d's resident
        # frames already live on devices[d], and mixing them with fresh
        # device_puts on a different physical device breaks jnp.stack
        dev = pl.devices[d % len(pl.devices)]
        served = []
        with _stage_span(ctx, d, len(shard)):
            for x in shard:
                key = residency_key(ctx, [x], "frame-shard")
                cached = res.lookup(("device", d), key, category=category,
                                    ctx=ctx)
                if cached is not None:
                    served.append(cached[0])
                    continue
                y = jax.device_put(x, dev)
                res.store(("device", d), key, [y],
                          int(getattr(y, "nbytes", y.size * 4)),
                          category=category, kind="frame-shard", ctx=ctx)
                served.append(y)
        return self._inner_run_on(category, served, ctx, kernel, weights, d)

    def _observe_shard(self, ctx, category, d, dt_s, cost):
        """Feed one healthy shard wall to the per-device straggler
        watchdog; ``patience`` consecutive stragglers quarantine the
        device (re-scattering subsequent groups across the survivors)."""
        wd = getattr(ctx, "watchdog", None)
        q = getattr(ctx, "quarantine", None)
        if wd is None:
            return
        base = cost.total_s if cost is not None else None
        if not wd.observe(("device", self.name, d), dt_s, base):
            if q is not None:
                q.note_healthy(("device", d))
            return
        tel = getattr(ctx, "telemetry", None)
        if tel is not None:
            tel.note_fault(category, "straggle")
        tr = getattr(ctx, "tracer", None)
        if tr is not None:
            tr.instant("fault", lane=f"device{d}", category=category,
                       device=d, kind="straggle", elapsed_s=dt_s)
            tr.metrics.counter("faults", category=category,
                               kind="straggle").inc()
        if q is not None:
            now = getattr(ctx, "clock", None)
            ev = q.note_straggle(("device", d),
                                 now() if now is not None else 0.0)
            if ev is not None and tr is not None:
                q0 = tr.now()
                tr.record("quarantine", q0, q0 + (ev.until - ev.t),
                          lane=f"device{d}", kind="async", key=str(ev.key),
                          reason=ev.reason, level=ev.level)
                tr.metrics.counter("quarantines", reason=ev.reason).inc()

    def _note_device_fault(self, ctx, category, d, exc):
        tel = getattr(ctx, "telemetry", None)
        if tel is not None:
            tel.note_fault(category, exc.kind)
        tr = getattr(ctx, "tracer", None)
        if tr is not None:
            tr.instant("fault", lane=f"device{d}", category=category,
                       device=d, kind=exc.kind)
            tr.metrics.counter("faults", category=category,
                               kind=exc.kind).inc()

    def _quarantine_device(self, ctx, d, *, reason):
        # a quarantined device's memory is no longer trustworthy (and the
        # scheduler will route around it anyway): drop its resident set so
        # nothing serves stale bytes when it rejoins the pool, and every
        # placement that mapped frames onto it
        res = getattr(ctx, "residency", None)
        if res is not None:
            res.invalidate_device(("device", d), ctx=ctx)
        self._drop_placements_for_device(ctx, d)
        q = getattr(ctx, "quarantine", None)
        if q is None:
            return None
        clock = getattr(ctx, "clock", None)
        ev = q.quarantine(("device", d),
                          clock() if clock is not None else 0.0,
                          reason=reason)
        tr = getattr(ctx, "tracer", None)
        if tr is not None:
            q0 = tr.now()
            tr.record("quarantine", q0, q0 + (ev.until - ev.t),
                      lane=f"device{d}", kind="async", key=str(ev.key),
                      reason=ev.reason, level=ev.level)
            tr.metrics.counter("quarantines", reason=ev.reason).inc()
        return ev

    # -- (b) frame sharding: tile frames onto multiple apertures ---------------
    def _frame_conv(self, xs, ctx, kernel):
        h, w = int(xs[0].shape[-2]), int(xs[0].shape[-1])
        sizes = shard_sizes(h, ctx.n_devices)
        if len(sizes) == 1:
            return self.run("conv", xs, dataclasses.replace(ctx, n_devices=1),
                            kernel=kernel)
        halo_t, halo_b = kernel_halo(kernel)
        stack = jnp.stack(list(xs))
        optical = self.inner_name == "optical-sim"
        if optical:
            # one affine range map for the WHOLE frame (the host knows the
            # full frame before scattering tiles), so the DAC quantization
            # grid is identical to the unsharded invocation; only the
            # per-tile detector auto-exposure differs across devices
            lo, scale = conv_range_map(stack)
            v = (stack - lo) / scale
        else:
            v = stack
        devices = shard_devices(len(sizes))
        res = getattr(ctx, "residency", None) if devices is not None \
            else None
        blocks, costs, samples = [], [], []
        r0 = 0
        for d, rows in enumerate(sizes):
            with _device_span(ctx, d, len(xs)):
                ext = rows + halo_t + halo_b
                k_sub = self._folded(kernel, ext, ctx)
                # per-device tile residency: the halo slice is a pure
                # function of the frames' content and the slice geometry
                # (the range map is frame-derived too), so an unchanged
                # tile of an unchanged stack serves device-resident on
                # repeat flushes instead of re-slicing + re-shipping —
                # the sharded.py:446 fix: tiled re-dispatch no longer
                # device_puts unchanged sub-stacks
                tkey = None
                sub = None
                if res is not None:
                    tkey = residency_key(
                        ctx, list(xs),
                        f"ctile-{d}-{r0}-{rows}-{halo_t}-{halo_b}")
                    cached = res.lookup(("device", d), tkey,
                                        category="conv", ctx=ctx)
                    if cached is not None:
                        sub = cached[0]
                if sub is None:
                    idx = jnp.arange(r0 - halo_t, r0 + rows + halo_b) % h
                    sub = jnp.take(v, idx, axis=1)
                    if devices is not None:
                        # the tile is committed; k_sub / its mask stay
                        # uncommitted and follow it (see _run_group)
                        sub = jax.device_put(sub, devices[d])
                    if tkey is not None:
                        res.store(("device", d), tkey, [sub],
                                  int(getattr(sub, "nbytes",
                                              sub.size * 4)),
                                  category="conv", kind="frame-tile",
                                  ctx=ctx)
                if optical:
                    out_sub = optical_conv2d_batched(sub, ctx.mask(k_sub),
                                                     ctx.sim_params, None)
                else:
                    out_sub = _host_circular_conv(sub, k_sub)
            blocks.append(out_sub[:, halo_t:halo_t + rows, :])
            samples.append((int(sub.size), len(xs) * rows * w))
            costs.append(self._frame_conv_cost(ctx, ext * w, rows * w,
                                               len(xs)))
            r0 += rows
        with _gather_span(ctx, len(blocks)):
            out = jnp.concatenate(_gather_blocks(blocks, devices), axis=1)
        if optical:
            out = out * scale + lo * jnp.sum(kernel)
        self._last_device_samples = samples
        return list(out), self._combine(costs, len(sizes), ctx)

    def _frame_matmul(self, xs, ctx, weights):
        m = int(xs[0].shape[0])
        kdim = int(xs[0].shape[1])
        nout = int(weights.shape[-1])
        sizes = shard_sizes(m, ctx.n_devices)
        if len(sizes) == 1:
            return self.run("matmul", xs,
                            dataclasses.replace(ctx, n_devices=1),
                            weights=weights)
        stack = jnp.stack(list(xs))
        devices = shard_devices(len(sizes))
        res = getattr(ctx, "residency", None) if devices is not None \
            else None
        blocks, costs, samples = [], [], []
        r0 = 0
        for d, rows in enumerate(sizes):
            with _device_span(ctx, d, len(xs)):
                # per-device tile residency, as in _frame_conv: an
                # unchanged row block of an unchanged activation stack
                # stays device-resident across flushes
                tkey = None
                sub = None
                if res is not None:
                    tkey = residency_key(ctx, list(xs),
                                         f"mtile-{d}-{r0}-{rows}")
                    cached = res.lookup(("device", d), tkey,
                                        category="matmul", ctx=ctx)
                    if cached is not None:
                        sub = cached[0]
                if sub is None:
                    sub = stack[:, r0:r0 + rows, :]
                    if devices is not None:
                        # activations committed per device; uncommitted
                        # weights follow them under jit (see _run_group)
                        sub = jax.device_put(sub, devices[d])
                    if tkey is not None:
                        res.store(("device", d), tkey, [sub],
                                  int(getattr(sub, "nbytes",
                                              sub.size * 4)),
                                  category="matmul", kind="frame-tile",
                                  ctx=ctx)
                if self.inner_name == "optical-sim":
                    out_sub = _optical_matmul_batched(
                        sub, weights, dac_bits=ctx.spec.dac.bits,
                        adc_bits=ctx.spec.adc.bits)
                else:
                    out_sub = _host_matmul(sub, weights)
            blocks.append(out_sub)
            samples.append((int(sub.size), int(out_sub.size)))
            costs.append(self._frame_matmul_cost(ctx, len(xs), rows, kdim,
                                                 nout))
            r0 += rows
        with _gather_span(ctx, len(blocks)):
            out = jnp.concatenate(_gather_blocks(blocks, devices), axis=1)
        self._last_device_samples = samples
        return list(out), self._combine(costs, len(sizes), ctx)

    # -- pricing ---------------------------------------------------------------
    def _combine(self, costs, n_eff: int, ctx) -> StepCost | None:
        """Max-over-devices: the invocation retires when the slowest
        (largest) shard's boundary crossing does; the sync barrier scales
        with the participant count.  Host-like inners price by measured
        wall (None propagates); the ideal bound stays sync-free — a
        zero-boundary accelerator has nothing to synchronize through."""
        if any(c is None for c in costs):
            return None
        worst = max(costs, key=lambda c: c.total_s)
        sync = getattr(ctx.spec, "device_sync_s", 0.0)
        if self.inner_name == "ideal" or sync <= 0.0:
            return worst
        return dataclasses.replace(
            worst, interface_s=worst.interface_s + n_eff * sync)

    def _frame_conv_cost(self, ctx, n_in: int, n_out: int,
                         batch: int) -> StepCost | None:
        if self.inner_name == "host":
            return None
        spec = ctx.spec
        if self.inner_name == "ideal":
            return ideal_step_cost(spec, "conv", batch)
        spec4 = dataclasses.replace(spec, phase_shift_captures=CONV_CAPTURES)
        return spec4.batched_step_cost(n_in, n_out, batch=batch,
                                       pipeline_depth=ctx.pipeline_depth)

    def _frame_matmul_cost(self, ctx, batch: int, rows: int, kdim: int,
                           nout: int) -> StepCost | None:
        if self.inner_name == "host":
            return None
        spec = ctx.spec
        if self.inner_name == "ideal":
            return ideal_step_cost(spec, "matmul", batch)
        return dataclasses.replace(
            spec.matmul_cost(batch * rows, kdim, nout),
            interface_s=spec.interface_latency_s)


register_backend("sharded", ShardedOpticalBackend)
register_backend("sharded-host", lambda: ShardedOpticalBackend(inner="host"))
register_backend("sharded-ideal", lambda: ShardedOpticalBackend(inner="ideal"))
