"""Execution telemetry: measured per-category traffic -> ``CategoryProfile``s.

The planner (``repro.core.planner``) prices offload from a workload profile.
The seed repo fed it *hand-written* profiles (or ``OpProfiler`` brackets the
caller had to place manually).  The runtime records the same quantities as a
side effect of executing requests — call counts, boundary sample counts,
wall time — keyed by ``(category, backend)``, so after any traffic has
flowed through the :class:`~repro.runtime.executor.OffloadExecutor` the
observed workload can be handed straight back to ``plan_offload``:

    telemetry.start()
    ... route traffic through the executor ...
    telemetry.stop()
    plan = plan_offload(telemetry.profiles(), spec)

closing the paper's profile -> plan -> execute -> re-profile loop.

``host_s`` in an emitted profile prefers wall time measured on the digital
backends (``host`` / ``ideal``) because that is the quantity the planner
compares accelerator pricing against; a category observed only through the
optical-sim backend falls back to its simulated wall time (flagged via
:meth:`RuntimeTelemetry.host_timed`).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

from repro.core.accelerator import StepCost
from repro.core.planner import CategoryProfile
from repro.runtime.metrics import Histogram

__all__ = ["BackendStats", "DeltaStats", "DeviceStats", "RuntimeTelemetry",
           "WindowStats"]

# Backends whose measured wall time is honest *host* time for planning
# (sharded-over-host still executes digitally, scattered or not).
_HOST_LIKE = ("host", "ideal", "sharded-host", "sharded-ideal")


@dataclasses.dataclass
class BackendStats:
    """Accumulated traffic for one (category, backend) pair."""

    calls: int = 0            # logical offload requests
    invocations: int = 0      # accelerator dispatches (batches) serving them
    samples_in: int = 0       # scalars that crossed (or would cross) the DAC
    samples_out: int = 0      # scalars back through the ADC
    wall_s: float = 0.0       # measured execution wall time
    bytes_in: int = 0         # measured operand bytes staged per dispatch
    bytes_out: int = 0        # measured result bytes read back
    modeled: StepCost = StepCost(0.0, 0.0, 0.0, 0.0)
    # per-tile samples: invocation depth (calls coalesced into ONE
    # dispatched stack — the tile size under memory-budgeted tiling) ->
    # how many invocations dispatched at that depth
    tiles: dict = dataclasses.field(default_factory=dict)

    def add(self, *, calls: int, samples_in: int, samples_out: int,
            wall_s: float, modeled: StepCost | None,
            bytes_in: int = 0, bytes_out: int = 0) -> None:
        self.calls += calls
        self.invocations += 1
        self.samples_in += samples_in
        self.samples_out += samples_out
        self.wall_s += wall_s
        self.bytes_in += bytes_in
        self.bytes_out += bytes_out
        self.tiles[calls] = self.tiles.get(calls, 0) + 1
        if modeled is not None:
            self.modeled = self.modeled + modeled


@dataclasses.dataclass
class DeviceStats:
    """Boundary traffic one simulated device absorbed under sharded offload."""

    invocations: int = 0      # sharded invocations this device took part in
    samples_in: int = 0       # scalars through THIS device's DAC
    samples_out: int = 0      # scalars back through THIS device's ADC


@dataclasses.dataclass
class DeltaStats:
    """Delta-staging ledger for one category: how many written operands
    took the partial (delta-encoded) write versus the full re-stage, and
    the summed flip fraction of the delta writes — the mean flip fraction
    is what the router feeds back into write-side deadline pricing."""

    frames: int = 0           # operands staged as delta writes
    full: int = 0             # written operands that re-staged in full
    flip_sum: float = 0.0     # sum of delta writes' flip fractions

    @property
    def mean_flip_fraction(self) -> float:
        return self.flip_sum / self.frames if self.frames else 0.0


@dataclasses.dataclass
class WindowStats:
    """Per-engine pipeline-window occupancy for one (category, backend).

    Recorded at every dispatch: how many of this engine's invocations were
    in flight the moment the new one entered its window (including
    itself), against the window depth it gated on.  The mean occupancy is
    the overlap the engine *actually achieved* — the measured counterpart
    of the cost model's ``engines=`` composition claim."""

    dispatches: int = 0       # invocations gated through this window
    in_flight_sum: int = 0    # sum of occupancy-at-dispatch (incl. self)
    peak: int = 0             # deepest occupancy observed
    depth: int = 0            # window depth at the last dispatch

    def add(self, *, in_flight: int, depth: int) -> None:
        self.dispatches += 1
        self.in_flight_sum += in_flight
        self.peak = max(self.peak, in_flight)
        self.depth = depth

    @property
    def mean_occupancy(self) -> float:
        return (self.in_flight_sum / self.dispatches
                if self.dispatches else 0.0)


# How many recent submit timestamps back the arrival-rate estimate (enough
# to smooth Poisson burstiness, few enough to track a changing rate).
_ARRIVAL_WINDOW = 64


class RuntimeTelemetry:
    """Records executor traffic and emits measured ``CategoryProfile``s."""

    def __init__(self) -> None:
        self.stats: dict[tuple[str, str], BackendStats] = \
            collections.defaultdict(BackendStats)
        # (category, backend) -> device index -> per-device boundary traffic
        self.device_stats: dict[tuple[str, str], dict[int, DeviceStats]] = \
            collections.defaultdict(dict)
        # category -> recent submit timestamps (the arrival process itself,
        # recorded at submit rather than dispatch so held traffic still has
        # an honest rate estimate)
        self._submits: dict[str, collections.deque[float]] = \
            collections.defaultdict(
                lambda: collections.deque(maxlen=_ARRIVAL_WINDOW))
        # (category, backend) -> per-invocation wall-time histogram: the
        # percentile view (p50/p95/p99) the multi-tenant SLO roadmap item
        # needs — totals say how much, percentiles say how consistently
        self._latency: dict[tuple[str, str], Histogram] = {}
        # category -> fault-kind counter ("error" / "straggle" / "drift" /
        # "device_loss" / "fallback" / "reroute"): the goodput-under-faults
        # ledger the chaos bench and operators read
        self.fault_counts: dict[str, collections.Counter] = \
            collections.defaultdict(collections.Counter)
        # category -> recovery-latency histogram: first fault of a dispatch
        # to its successful (possibly degraded) completion
        self._recovery: dict[str, Histogram] = {}
        # category -> residency-event counter ("hit" / "miss" / "eviction"
        # / "invalidation"): the operand-residency ledger — per-category
        # hit rate is what the router weighs batch depth against
        self.residency_counts: dict[str, collections.Counter] = \
            collections.defaultdict(collections.Counter)
        # category -> delta-staging ledger: delta-written vs fully
        # re-staged operand counts and summed flip fractions — the
        # write-side signal `replan` weighs alongside the hit rate
        self.delta_stats: dict[str, DeltaStats] = \
            collections.defaultdict(DeltaStats)
        # (category, backend) -> pipeline-window occupancy: the per-engine
        # in-flight depth each dispatch actually found — the measured
        # overlap the `engines=` composed price is judged against
        self.engine_windows: dict[tuple[str, str], WindowStats] = \
            collections.defaultdict(WindowStats)
        self._t0: float | None = None
        self._window_s: float = 0.0
        self._in_window_s: float = 0.0  # recorded wall inside the window

    # -- whole-run window (for the non-offloadable 'other' bucket) -----------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        """Close the measurement window; idempotent.  ``stop`` without a
        matching ``start`` (teardown paths can hit this — an example's
        ``finally`` block, a reset mid-window) is a no-op returning the
        accumulated window, not an error."""
        if self._t0 is not None:
            self._window_s += time.perf_counter() - self._t0
            self._t0 = None
        return self._window_s

    @property
    def window_s(self) -> float:
        return self._window_s

    # -- arrival process (the scheduler's admission signal) -------------------
    def note_submit(self, category: str, t: float | None = None) -> None:
        """Record one offload submission at time ``t`` (the executor stamps
        its own clock so submit ages and arrival rates share a timebase)."""
        self._submits[category].append(
            time.perf_counter() if t is None else t)

    def arrival_rate(self, category: str) -> float:
        """Estimated submit arrival rate for ``category`` in calls/second,
        from the recent submit timestamps (0.0 until two arrivals have been
        seen — no estimate is *no* claim, not a claim of zero traffic; the
        scheduler treats it as "hold until the deadline says otherwise").

        A burst of simultaneous submits (span ~0) estimates ``inf``:
        the next arrival is expected immediately, so waiting is free."""
        ts = self._submits.get(category)
        if ts is None or len(ts) < 2:
            return 0.0
        span = ts[-1] - ts[0]
        if span <= 0.0:
            return float("inf")
        return (len(ts) - 1) / span

    # -- recording (called by the executor) ----------------------------------
    def record(self, category: str, backend: str, *, calls: int,
               samples_in: int, samples_out: int, wall_s: float,
               modeled: StepCost | None = None,
               per_device: Sequence[tuple[int, int]] | None = None,
               bytes_in: int = 0, bytes_out: int = 0) -> None:
        self.stats[(category, backend)].add(
            calls=calls, samples_in=samples_in, samples_out=samples_out,
            wall_s=wall_s, modeled=modeled, bytes_in=bytes_in,
            bytes_out=bytes_out)
        self._latency.setdefault((category, backend),
                                 Histogram()).record(wall_s)
        if per_device:
            devs = self.device_stats[(category, backend)]
            for i, (s_in, s_out) in enumerate(per_device):
                st = devs.setdefault(i, DeviceStats())
                st.invocations += 1
                st.samples_in += int(s_in)
                st.samples_out += int(s_out)
        if self._t0 is not None:  # only in-window traffic offsets 'other'
            self._in_window_s += wall_s

    def note_fault(self, category: str, kind: str) -> None:
        """Count one fault event against ``category`` (the executor's
        retry path, the sharded backend's per-device recovery, and the
        drift-correction path all report through here)."""
        self.fault_counts[category][kind] += 1

    def note_recovery(self, category: str, dt_s: float) -> None:
        """Record one recovery latency: the span from a dispatch's first
        fault to the caller having a correct result again."""
        self._recovery.setdefault(category, Histogram()).record(max(dt_s,
                                                                    0.0))

    def note_window(self, category: str, backend: str, *,
                    in_flight: int, depth: int) -> None:
        """Record one dispatch's pipeline-window occupancy for the
        ``(category, backend)`` engine (the executor reports at every
        invocation, after gating on the engine's window)."""
        self.engine_windows[(category, backend)].add(in_flight=in_flight,
                                                     depth=depth)

    def window_occupancy(self, category: str | None = None,
                         backend: str | None = None) -> float:
        """Mean in-flight-at-dispatch occupancy across the matching engine
        windows (dispatch-weighted); 0.0 when nothing dispatched."""
        disp = occ = 0
        for (cat, be), st in self.engine_windows.items():
            if category is not None and cat != category:
                continue
            if backend is not None and be != backend:
                continue
            disp += st.dispatches
            occ += st.in_flight_sum
        return occ / disp if disp else 0.0

    def note_residency(self, category: str, event: str) -> None:
        """Count one residency-cache event ("hit" / "miss" / "eviction" /
        "invalidation") against ``category`` (mirrored here by the
        ``ResidencyCache`` whenever a context with telemetry is attached)."""
        self.residency_counts[category][event] += 1

    def residency_hit_rate(self, category: str | None = None,
                           ) -> float | None:
        """hits / (hits + misses) for ``category`` (overall when None);
        ``None`` before any residency lookup — no traffic is no claim,
        and the router treats it as rate 0."""
        hits = misses = 0
        for cat, c in self.residency_counts.items():
            if category is not None and cat != category:
                continue
            hits += c.get("hit", 0)
            misses += c.get("miss", 0)
        total = hits + misses
        return None if total == 0 else hits / total

    def note_delta(self, category: str, *,
                   flip_fraction: float | None = None) -> None:
        """Count one *written* (non-hit) operand staging against
        ``category``: with a ``flip_fraction`` it was a delta-encoded
        partial write at that measured LSB flip fraction; with ``None``
        it re-staged in full (first sighting, or a flip fraction past
        the delta threshold)."""
        st = self.delta_stats[category]
        if flip_fraction is None:
            st.full += 1
        else:
            st.frames += 1
            st.flip_sum += max(0.0, min(1.0, float(flip_fraction)))

    def delta_rate(self, category: str | None = None) -> float | None:
        """delta writes / all writes for ``category`` (overall when None);
        ``None`` before any write-side staging was classified — no traffic
        is no claim, and the router treats it as rate 0."""
        frames = full = 0
        for cat, st in self.delta_stats.items():
            if category is not None and cat != category:
                continue
            frames += st.frames
            full += st.full
        total = frames + full
        return None if total == 0 else frames / total

    def mean_flip_fraction(self, category: str | None = None) -> float:
        """Mean LSB flip fraction across the observed delta writes for
        ``category`` (overall when None); 0.0 when none occurred."""
        frames = 0
        flips = 0.0
        for cat, st in self.delta_stats.items():
            if category is not None and cat != category:
                continue
            frames += st.frames
            flips += st.flip_sum
        return flips / frames if frames else 0.0

    def faults_total(self, category: str | None = None) -> int:
        """Total fault events observed (for ``category``, or overall)."""
        if category is not None:
            return sum(self.fault_counts.get(category, {}).values())
        return sum(sum(c.values()) for c in self.fault_counts.values())

    def recovery_stats(self, category: str | None = None) -> dict | None:
        """``{n, mean_s, p50_s, p95_s}`` of recovery latency for
        ``category`` (merged across categories when None); ``None`` when
        nothing ever needed recovering."""
        merged: Histogram | None = None
        for cat, h in self._recovery.items():
            if category is not None and cat != category:
                continue
            if merged is None:
                merged = h.copy()
            else:
                merged.merge(h)
        if merged is None or merged.n == 0:
            return None
        return {"n": merged.n, "mean_s": merged.total / merged.n,
                "p50_s": merged.percentile(50),
                "p95_s": merged.percentile(95)}

    def discount_window(self, wall_s: float) -> None:
        """Exclude ``wall_s`` of measurement overhead (e.g. the fidelity
        checker's shadow reference run) from the window's 'other' bucket —
        it elapsed inside the window but is not workload."""
        if self._t0 is not None:
            self._in_window_s += wall_s

    # -- views ----------------------------------------------------------------
    def categories(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        for cat, _ in self.stats:
            seen.setdefault(cat)
        return tuple(seen)

    def host_timed(self, category: str) -> bool:
        """True when ``category`` has wall time from a host-like backend."""
        return any(self.stats[(category, b)].wall_s > 0.0
                   for b in _HOST_LIKE if (category, b) in self.stats)

    def _category_rollup(self, category: str) -> tuple[int, int, int, float]:
        calls = s_in = s_out = host_calls = 0
        host_s = other_s = 0.0
        for (cat, backend), st in self.stats.items():
            if cat != category:
                continue
            calls += st.calls
            s_in += st.samples_in
            s_out += st.samples_out
            if backend in _HOST_LIKE:
                host_s += st.wall_s
                host_calls += st.calls
            else:
                other_s += st.wall_s
        if host_s > 0.0 and host_calls > 0:
            # price ALL observed calls at the measured host rate, so a
            # category that later ran offloaded is not under-weighted on
            # the host side of the next replan
            est = host_s * (calls / host_calls)
        else:
            est = other_s
        return calls, s_in, s_out, est

    def recorded_s(self) -> float:
        return sum(st.wall_s for st in self.stats.values())

    def samples_per_call(self, category: str) -> tuple[int, int]:
        """Observed mean boundary traffic per call: (n_in, n_out) scalars.

        This is what adaptive batching prices invocations from — the
        per-call DAC/ADC sample counts the category's traffic actually
        exhibited, not a hand-written workload guess."""
        calls = s_in = s_out = 0
        for (cat, _backend), st in self.stats.items():
            if cat != category:
                continue
            calls += st.calls
            s_in += st.samples_in
            s_out += st.samples_out
        if calls <= 0:
            return (0, 0)
        return (s_in // calls, s_out // calls)

    def device_samples(self, category: str) -> dict[int, tuple[int, int]]:
        """Per-device aggregated boundary traffic for ``category``:
        ``{device_index: (samples_in, samples_out)}`` summed across
        backends.  Empty when the category never ran sharded."""
        out: dict[int, list[int]] = {}
        for (cat, _backend), devs in self.device_stats.items():
            if cat != category:
                continue
            for i, st in devs.items():
                acc = out.setdefault(i, [0, 0])
                acc[0] += st.samples_in
                acc[1] += st.samples_out
        return {i: (s[0], s[1]) for i, s in sorted(out.items())}

    def devices_observed(self, category: str | None = None) -> int:
        """Widest device fan-out any recorded invocation used (1 when no
        sharded traffic was recorded)."""
        widest = 1
        for (cat, _backend), devs in self.device_stats.items():
            if category is not None and cat != category:
                continue
            widest = max(widest, len(devs))
        return widest

    def tile_sizes_observed(self, category: str) -> dict[int, int]:
        """Per-tile samples: ``{invocation depth: dispatch count}`` merged
        across backends — the tile granularity the executor *actually*
        dispatched at.  A monolithic K-deep flush shows ``{K: 1}``; the
        same group streamed through a ``tile_k=4`` budget shows
        ``{4: K//4, ...}`` (plus a ragged tail entry).  Benchmarks assert
        the budget-chosen ``tile_k`` against this — the tile the planner
        picked must be the tile the boundary saw."""
        out: dict[int, int] = {}
        for (cat, _backend), st in self.stats.items():
            if cat != category:
                continue
            for size, count in st.tiles.items():
                out[size] = out.get(size, 0) + count
        return dict(sorted(out.items()))

    def latency_histogram(self, category: str,
                          backend: str | None = None) -> Histogram | None:
        """Per-invocation wall-time histogram for ``(category, backend)``
        — or, with ``backend=None``, a merged copy across every backend
        that served the category.  ``None`` when no traffic recorded."""
        if backend is not None:
            h = self._latency.get((category, backend))
            return None if h is None else h.copy()
        merged: Histogram | None = None
        for (cat, _b), h in self._latency.items():
            if cat != category:
                continue
            if merged is None:
                merged = h.copy()
            else:
                merged.merge(h)
        return merged

    def percentiles(self, category: str, backend: str | None = None,
                    ps: Sequence[float] = (50.0, 95.0, 99.0),
                    ) -> dict[float, float]:
        """p50/p95/p99 (by default) of per-invocation wall time for
        ``(category, backend)`` — NaN-valued when no traffic recorded, so
        SLO dashboards can render the absence without special-casing."""
        h = self.latency_histogram(category, backend)
        if h is None:
            return {p: float("nan") for p in ps}
        return h.percentiles(ps)

    def bytes_per_frame(self, category: str) -> int:
        """Measured mean staged bytes per call (operand in + result out) —
        the ground truth the tiling model's working-set estimate is judged
        against.  0 until traffic with byte accounting has flowed."""
        calls = total = 0
        for (cat, _backend), st in self.stats.items():
            if cat != category:
                continue
            calls += st.calls
            total += st.bytes_in + st.bytes_out
        if calls <= 0:
            return 0
        return total // calls

    def observed_occupancy(self, category: str | None = None) -> int:
        """Average calls coalesced per invocation in the observed traffic,
        per category (or globally when ``category`` is None).

        This is the amortization the workload *actually achieved* — pricing
        a plan at a deeper batch than a category's traffic exhibits would
        credit the accelerator with handshake amortization it never gets,
        and one category's deep batches must not subsidize another's
        serial calls."""
        calls = invocations = 0
        for (cat, _backend), st in self.stats.items():
            if category is not None and cat != category:
                continue
            calls += st.calls
            invocations += st.invocations
        if invocations <= 0:
            return 1
        return max(1, round(calls / invocations))

    # -- the loop-closing output ----------------------------------------------
    def profiles(self, include_other: bool = True) -> list[CategoryProfile]:
        """Observed traffic as planner input.

        One profile per executed category, plus (when a start/stop window was
        used) an ``other`` profile holding the non-offloadable remainder of
        the window — exactly the shape ``plan_offload`` expects.
        """
        out: list[CategoryProfile] = []
        for cat in self.categories():
            calls, s_in, s_out, host_s = self._category_rollup(cat)
            out.append(CategoryProfile(cat, host_s=host_s, calls=max(calls, 1),
                                       samples_in=s_in, samples_out=s_out))
        if include_other and self._window_s > 0.0:
            other = max(self._window_s - self._in_window_s, 0.0)
            out.append(CategoryProfile("other", host_s=other))
        return out

    def merge(self, other: "RuntimeTelemetry") -> None:
        for key, st in other.stats.items():
            mine = self.stats[key]
            mine.calls += st.calls
            mine.invocations += st.invocations
            mine.samples_in += st.samples_in
            mine.samples_out += st.samples_out
            mine.wall_s += st.wall_s
            mine.bytes_in += st.bytes_in
            mine.bytes_out += st.bytes_out
            mine.modeled = mine.modeled + st.modeled
            for size, count in st.tiles.items():
                mine.tiles[size] = mine.tiles.get(size, 0) + count
        for key, devs in other.device_stats.items():
            mine_devs = self.device_stats[key]
            for i, st in devs.items():
                acc = mine_devs.setdefault(i, DeviceStats())
                acc.invocations += st.invocations
                acc.samples_in += st.samples_in
                acc.samples_out += st.samples_out
        for cat, ts in other._submits.items():
            mine_ts = self._submits[cat]
            merged = sorted(list(mine_ts) + list(ts))
            mine_ts.clear()
            mine_ts.extend(merged[-_ARRIVAL_WINDOW:])
        for key, h in other._latency.items():
            if key in self._latency:
                self._latency[key].merge(h)
            else:
                self._latency[key] = h.copy()
        for cat, counts in other.fault_counts.items():
            self.fault_counts[cat].update(counts)
        for cat, h in other._recovery.items():
            if cat in self._recovery:
                self._recovery[cat].merge(h)
            else:
                self._recovery[cat] = h.copy()
        for cat, counts in other.residency_counts.items():
            self.residency_counts[cat].update(counts)
        for cat, st in other.delta_stats.items():
            mine_d = self.delta_stats[cat]
            mine_d.frames += st.frames
            mine_d.full += st.full
            mine_d.flip_sum += st.flip_sum
        for key, st in other.engine_windows.items():
            mine_w = self.engine_windows[key]
            mine_w.dispatches += st.dispatches
            mine_w.in_flight_sum += st.in_flight_sum
            mine_w.peak = max(mine_w.peak, st.peak)
            mine_w.depth = st.depth or mine_w.depth
        self._window_s += other._window_s
        self._in_window_s += other._in_window_s

    def reset(self) -> None:
        self.stats.clear()
        self.device_stats.clear()
        self._submits.clear()
        self._latency.clear()
        self.fault_counts.clear()
        self._recovery.clear()
        self.residency_counts.clear()
        self.delta_stats.clear()
        self.engine_windows.clear()
        self._t0 = None
        self._window_s = 0.0
        self._in_window_s = 0.0

    def summary(self) -> str:
        rows = ["telemetry:"]
        for (cat, backend), st in sorted(self.stats.items()):
            rows.append(
                f"  {cat:>8}/{backend:<11} calls={st.calls} "
                f"batches={st.invocations} in={st.samples_in} "
                f"out={st.samples_out} wall={st.wall_s:.4g}s "
                f"modeled={st.modeled.total_s:.4g}s "
                f"(conv {st.modeled.conversion_s:.4g}s)")
            devs = self.device_stats.get((cat, backend))
            if devs:
                parts = [f"d{i}: in={d.samples_in} out={d.samples_out} "
                         f"x{d.invocations}" for i, d in sorted(devs.items())]
                rows.append(f"           devices[{len(devs)}] "
                            + "; ".join(parts))
            if len(st.tiles) > 1:  # tiled / mixed-depth dispatch is news
                parts = [f"depth{s} x{c}"
                         for s, c in sorted(st.tiles.items())]
                rows.append("           tiles: " + "; ".join(parts))
            h = self._latency.get((cat, backend))
            if h is not None and h.n > 1:  # percentiles of one are noise
                rows.append(
                    f"           wall p50={h.percentile(50):.3g}s "
                    f"p95={h.percentile(95):.3g}s "
                    f"p99={h.percentile(99):.3g}s (n={h.n})")
            w = self.engine_windows.get((cat, backend))
            if w is not None and w.dispatches:
                rows.append(
                    f"           window depth={w.depth} "
                    f"occupancy={w.mean_occupancy:.2f} peak={w.peak} "
                    f"(n={w.dispatches})")
        for cat, counts in sorted(self.fault_counts.items()):
            parts = [f"{k} x{c}" for k, c in sorted(counts.items())]
            row = f"  faults[{cat}]: " + "; ".join(parts)
            rec = self.recovery_stats(cat)
            if rec is not None:
                row += (f" | recovery p50={rec['p50_s']:.3g}s "
                        f"p95={rec['p95_s']:.3g}s (n={rec['n']})")
            rows.append(row)
        for cat, counts in sorted(self.residency_counts.items()):
            parts = [f"{k} x{c}" for k, c in sorted(counts.items())]
            row = f"  residency[{cat}]: " + "; ".join(parts)
            rate = self.residency_hit_rate(cat)
            if rate is not None:
                row += f" | hit rate {rate:.0%}"
            rows.append(row)
        for cat, st in sorted(self.delta_stats.items()):
            if st.frames or st.full:
                rows.append(
                    f"  delta[{cat}]: delta x{st.frames} full x{st.full}"
                    f" | mean flip {st.mean_flip_fraction:.1%}")
        if self._window_s:
            rows.append(f"  window={self._window_s:.4g}s "
                        f"recorded={self.recorded_s():.4g}s")
        return "\n".join(rows)
