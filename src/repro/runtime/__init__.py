"""Conversion-aware offload runtime: execute hybrid host/optical plans.

The seed repo *priced* the paper's conversion bottleneck (``repro.core``
returns an ``OffloadPlan`` nothing consumed); this package is the layer
that runs it.  Module map:

  backends   — registry of three interchangeable executors per op category:
               ``host`` (pure JAX fft/conv/matmul), ``optical-sim`` (fused
               Pallas DFT pipeline + 4f physics sim with the DAC/ADC
               boundary applied, every batch priced with a ``StepCost``),
               ``ideal`` (exact values at the zero-conversion analog bound).
  executor   — ``OffloadExecutor``: request queue that coalesces same-shape
               calls into ONE batched invocation (stacked operands, batched
               Pallas kernels / vmapped physics — amortizing per-call
               handshake latency, SLM settle/exposure, converter-lane ceil
               residue, AND the dispatch/launch overhead itself: the
               paper's §6 batching lever, executed rather than modeled),
               pipelined two deep (``flush_async``: invocation k+1 stages
               while invocation k computes; per-result ``wait``/``done``)
               behind per-``(category, backend)`` pipeline *windows*
               (``set_pipeline_window``): one engine's in-flight depth
               never gates another's, retirement stays submit-ordered
               within each engine, and the global ``pipeline_depth``
               remains the back-compat default for unpinned categories
               (``shared_window=True`` restores the old single gate),
               with per-category coalescing ceilings (``set_max_batch``),
               per-shape DFT-factor / Fourier-mask / jit caches, a public
               group-release primitive (``release``) the scheduler drives,
               and context-manager cleanup (``with`` drains queued, held,
               and in-flight work).
  scheduler  — ``OffloadScheduler``: admission-controlled continuous
               batching over the executor — partially filled groups are
               *held open across flushes* under a per-category deadline and
               released when full (``max_batch``), due (oldest age reaches
               the deadline), or futile to hold (the telemetry-estimated
               arrival rate says the next arrival lands past the deadline);
               hold time is priced into the invocation
               (``StepCost.hold_s``).  ``ManualClock`` makes admission
               deterministic in tests/benchmarks.
  telemetry  — ``RuntimeTelemetry``: measured per-category call counts,
               sample counts, wall time, and the submit arrival process
               (``arrival_rate``), emitted as ``CategoryProfile``s so
               ``plan_offload`` re-plans from observed traffic.
  fidelity   — ``FidelityChecker``: shadows optical-sim batches with the
               host reference (vectorized: one norm reduction + one sync
               per batch; ``sample_every`` bounds hot-path cost) and scores
               quantization error against the converters' ENOB budget,
               pairing speedups with accuracy — and *gating* planning:
               ``replan`` threads the worst observed error into each
               profile so an over-budget category is vetoed off the
               accelerator regardless of speedup.
  sharded    — ``ShardedOpticalBackend``: scatters one batched invocation
               across ``n_devices`` replicated simulated accelerators —
               group sharding (the stacked flush group splits across
               devices, each paying its own DAC/ADC crossing; modeled wall
               = max-over-devices + sync) or frame sharding (one large
               frame tiles onto multiple apertures with overlap-save halos
               for conv) — with mesh-aware device placement and an
               off-mesh sequential fallback (CPU tests).  With residency
               on, the backend commits one device-resident *placement*
               per ``(category, group shape)``: shards are
               ``device_put`` once and stay resident across tiles and
               flushes, only changed frames re-cross the DAC, gather
               happens only at ADC readout, and quarantine/device loss
               drops the placement and rebuilds it on the survivors.
  tiling     — ``MemoryBudget`` / ``choose_tile`` / ``choose_blocks``:
               memory-budgeted tiled dispatch.  A released flush group
               whose monolithic ``(K, H, W)`` stack would overflow the
               per-device staging budget (VMEM-derived on TPU,
               LLC-derived off it) streams as ``ceil(K / tile_k)``
               sub-invocations through the same two-deep pipeline
               (write/analog/read overlap between tiles), and the batched
               Pallas DFT grid's block sizes are derived from the same
               budget.  ``tile_k=1`` degenerates to looped, ``>= K`` to
               monolithic — the runtime-equivalence invariant covers all
               three.
  residency  — ``ResidencyCache``: per-device operand residency under the
               tiling ``MemoryBudget`` — content-keyed (operand digest +
               converter operating point) entries for flush-group frame
               stacks, conv kernels, matmul weight panels, and sharded
               per-device shard placements, LRU-evicted against the same
               staging budget tiles spend from.  A resident operand skips
               the write-side DAC crossing and host staging entirely
               (priced read-side-only by ``batched_step_cost``); hit /
               miss / eviction / invalidation counters land in
               ``RuntimeTelemetry`` and ``cache`` instants in the tracer.
               Opt-in: ``OffloadExecutor(residency=True)``.
  router     — ``PlanRouter``: applies an ``OffloadPlan``'s decisions as a
               category->backend routing table and closes the
               profile -> plan -> execute -> re-profile loop via ``replan``
               — adaptively: each category's ``max_batch``, sharded
               ``n_devices`` AND memory-budgeted ``tile_k`` are picked
               from observed telemetry (occupancy, per-call boundary
               traffic) under an optional latency ``deadline_s``, and
               each category's pipeline window collapses to its observed
               in-flight occupancy (``choose_windows``).
  faults     — the fault story for the conversion boundary:
               ``ChaosBackend`` wraps any registered backend with a
               deterministic seeded ``FaultSchedule`` (transient dispatch
               errors, latency-spike stragglers, ENOB drift, hard device
               loss); ``RetryPolicy`` gives every executor dispatch
               deadline/retry/backoff semantics with graceful degradation
               to the host backend; ``DispatchWatchdog`` applies the
               training runner's trailing-median straggler deadline to
               dispatch walls; ``Quarantine`` time-windows failing devices
               and categories out of the scatter/routing set with
               probation-based re-admission.  The equivalence invariant
               survives every fault: all frames retire, in order, with
               host-equal results.
  tracing    — ``Tracer`` / ``Span``: opt-in boundary-attributed span
               trees (``OffloadExecutor(tracer=...)``) — one tree per
               batched invocation covering submit -> held(reason) ->
               release(full|due|futile) -> tile -> stage -> compute ->
               fidelity-shadow, with per-device scatter children under
               sharded dispatch.  Zero overhead when off; injectable
               clock (``ManualClock``) for exact test assertions.
  metrics    — ``Counter`` / ``Histogram`` / ``MetricsRegistry``
               (mergeable log-binned percentile histograms) and
               ``drift_report``: the modeled-vs-measured per-stage join
               against ``batched_step_cost`` that names the
               worst-drifting stage.
  trace_export — Chrome/Perfetto ``trace_event`` JSON export
               (``write_trace``), per-stage charged sums
               (``stage_sums`` / ``reconcile``), one-screen digests
               (``summarize``).
  specs      — shared demo design points (``BATCHED_4F``: upgraded
               peripherals + frame latency that only batching amortizes).

Quick start::

    from repro.runtime import OffloadExecutor, PlanRouter
    ex = OffloadExecutor(PROTOTYPE_4F, max_batch=16)
    router = PlanRouter(ex)                   # all-host profiling mode
    ex.telemetry.start()
    outs = [router.run("fft", img) for img in imgs]
    ex.telemetry.stop()
    plan = router.replan()                    # measured plan; routes updated
"""

from repro.runtime.backends import (
    CATEGORIES,
    CONV_CAPTURES,
    BackendContext,
    ExecutionBackend,
    HostBackend,
    IdealBackend,
    OpticalSimBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.runtime.executor import OffloadExecutor, OffloadResult
from repro.runtime.faults import (
    ChaosBackend,
    DeviceLostError,
    DispatchWatchdog,
    Fault,
    FaultError,
    FaultSchedule,
    Quarantine,
    QuarantineEvent,
    RetryPolicy,
    TransientDispatchError,
    advance_or_sleep,
    register_chaos,
)
from repro.runtime.fidelity import FidelityChecker, FidelityReport, enob_error_bound
from repro.runtime.metrics import (
    Counter,
    DriftReport,
    Histogram,
    MetricsRegistry,
    StageDrift,
    drift_report,
)
from repro.runtime.residency import (
    DELTA_THRESHOLD,
    ResidencyCache,
    ResidencyEntry,
    operating_point,
    residency_key,
)
from repro.runtime.router import PlanRouter
from repro.runtime.scheduler import ManualClock, OffloadScheduler
from repro.runtime.sharded import ShardedOpticalBackend, kernel_halo, shard_sizes
from repro.runtime.specs import BATCHED_4F, CAMERA_ADC, SLM_DAC
from repro.runtime.telemetry import (
    BackendStats,
    DeltaStats,
    DeviceStats,
    RuntimeTelemetry,
    WindowStats,
)
from repro.runtime.tiling import (
    BlockPlan,
    MemoryBudget,
    TilePlan,
    choose_blocks,
    choose_tile,
    tile_sizes,
)
from repro.runtime.trace_export import (
    reconcile,
    stage_sums,
    summarize,
    to_trace_events,
    write_trace,
)
from repro.runtime.tracing import Span, Tracer

__all__ = [
    "CATEGORIES",
    "CONV_CAPTURES",
    "BackendContext",
    "ExecutionBackend",
    "HostBackend",
    "IdealBackend",
    "OpticalSimBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "OffloadExecutor",
    "OffloadResult",
    "ChaosBackend",
    "DeviceLostError",
    "DispatchWatchdog",
    "Fault",
    "FaultError",
    "FaultSchedule",
    "Quarantine",
    "QuarantineEvent",
    "RetryPolicy",
    "TransientDispatchError",
    "advance_or_sleep",
    "register_chaos",
    "FidelityChecker",
    "FidelityReport",
    "enob_error_bound",
    "ResidencyCache",
    "ResidencyEntry",
    "operating_point",
    "residency_key",
    "PlanRouter",
    "ManualClock",
    "OffloadScheduler",
    "ShardedOpticalBackend",
    "kernel_halo",
    "shard_sizes",
    "BackendStats",
    "DELTA_THRESHOLD",
    "DeltaStats",
    "DeviceStats",
    "RuntimeTelemetry",
    "WindowStats",
    "BlockPlan",
    "MemoryBudget",
    "TilePlan",
    "choose_blocks",
    "choose_tile",
    "tile_sizes",
    "BATCHED_4F",
    "CAMERA_ADC",
    "SLM_DAC",
    "Counter",
    "DriftReport",
    "Histogram",
    "MetricsRegistry",
    "StageDrift",
    "drift_report",
    "Span",
    "Tracer",
    "reconcile",
    "stage_sums",
    "summarize",
    "to_trace_events",
    "write_trace",
]
