"""Backend registry: three interchangeable executors per op category.

Every backend implements the same three op categories the planner knows
about (``fft``, ``conv``, ``matmul``) with identical call signatures, so
the executor can swap them per the routing table without touching callers:

  ``host``        pure digital JAX (fft2 / circular conv / matmul) — the
                  baseline the planner's ``host_s`` measures.
  ``optical-sim`` the simulated analog engine with the conversion boundary
                  applied: the fused Pallas DFT pipeline (DAC quantization
                  folded into stage 1, square-law detector into stage 2)
                  plus the auto-ranged ADC read path for ``fft``; the 4f
                  physics simulator for ``conv``; DAC->MVM->ADC for
                  ``matmul``.  Returns a modeled :class:`StepCost` built
                  from the executor's accelerator spec so every result is
                  priced, not just produced.
  ``ideal``       the zero-conversion-cost analog bound (paper Table 1):
                  exact digital values, cost = analog physics only.

Op semantics (fixed across backends so results are comparable):

  fft(a)        -> detector intensity |F a|^2 of the unitary 2-D DFT,
                   a real, values in [0, 1] (the camera cannot see phase;
                   a single capture yields intensity — paper App. A.1).
  conv(a, k)    -> circular 2-D convolution (4-step interferometric capture
                   + host-side inverse transform, paper Eq. 1).
  matmul(a, w)  -> a @ w with activations streamed through the converters
                   (weights held in the optical domain, amortized).

Batching is *real* on every backend: ``run`` stacks the group's same-shape
items into one ``(K, H, W)`` array and makes ONE batched invocation — a
single jitted ``fft2``/conv/matmul on the host, the batched Pallas DFT
pipeline (batch as the leading grid axis, factor matrices shared across
frames) or a vmapped 4f/MVM simulation on the analog backends — so a
K-deep flush pays one dispatch round-trip and one kernel launch instead
of K.  Per-item semantics are preserved inside the batch (per-frame ADC
auto-ranging, per-item affine range mapping, per-item matmul scaling), so
batched results match a Python loop of single-item calls to float
tolerance (the only difference is reduction/blocking order inside XLA).
"""

from __future__ import annotations

import abc
import dataclasses
import functools
import hashlib
import math
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import (
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
    StepCost,
)
from repro.core.optical import (
    OpticalSimParams,
    adc_quantize,
    adc_quantize_batched,
    dac_quantize,
    fourier_mask_for_kernel,
    optical_conv2d_batched,
)
from repro.kernels.common import INTERPRET
from repro.kernels.optical_dft import (
    _dft2_intensity_batched_xla,
    dft_matrix_factors,
    dft_stage1_batched,
    dft_stage2_batched,
)
from repro.runtime.residency import residency_key
from repro.runtime.tiling import BlockPlan, MemoryBudget, choose_blocks

__all__ = [
    "CATEGORIES",
    "CONV_CAPTURES",
    "BackendContext",
    "ExecutionBackend",
    "HostBackend",
    "OpticalSimBackend",
    "IdealBackend",
    "conv_range_map",
    "ideal_step_cost",
    "register_backend",
    "get_backend",
    "available_backends",
    "stage_group",
]

CATEGORIES = ("fft", "conv", "matmul")

# Interferometric complex recovery (needed by conv) costs 4 captures.
CONV_CAPTURES = 4


@dataclasses.dataclass
class BackendContext:
    """Per-executor state shared with backends: the accelerator spec plus
    the shape-keyed caches (DFT factor matrices, Fourier-plane masks,
    resolved Pallas block plans).  Compiled kernels are cached by jit
    itself, keyed on the same shapes *and* block sizes (the block sizes
    are jit-static), so a replanned layout always compiles fresh.

    ``pipeline_depth`` is how deep the owning executor overlaps boundary
    crossings for *this* invocation; analog backends thread it into
    ``batched_step_cost`` so the modeled price matches how the invocation
    is actually overlapped (2 = the executor's async double-buffered
    flush; 1 = strictly serial crossings).  The executor writes it
    per-dispatch (and ``warm()`` mirrors the same write) from the
    dispatched category's per-engine pipeline window
    (``set_pipeline_window``), falling back to the global
    ``pipeline_depth`` for unpinned categories — so a backend never needs
    to know which window it ran under, only the depth it was given.

    ``n_devices`` is how many replicated simulated accelerators the sharded
    backend scatters one invocation across (the executor writes the
    per-category effective count here before every dispatch — and before
    ``warm`` — so sharded dispatch shapes are primed consistently);
    ``shard_mode`` picks between group sharding, frame sharding, and the
    automatic policy (see ``repro.runtime.sharded``).

    ``mem_budget`` is the per-device staging byte budget
    (``repro.runtime.tiling.MemoryBudget``): the executor tiles flush
    groups against it, and the optical backend derives the batched Pallas
    grid's block sizes from it (``blocks_for``)."""

    spec: OpticalFourierAcceleratorSpec | OpticalMVMAcceleratorSpec
    factor_cache: dict[tuple, tuple[jax.Array, jax.Array]] = \
        dataclasses.field(default_factory=dict)
    mask_cache: dict[tuple, jax.Array] = dataclasses.field(default_factory=dict)
    pipeline_depth: int = 2
    n_devices: int = 1
    shard_mode: str = "auto"
    mem_budget: "MemoryBudget | None" = None
    block_cache: dict[tuple, "BlockPlan"] = \
        dataclasses.field(default_factory=dict)
    _digest_memo: dict[int, tuple[jax.Array, tuple]] = \
        dataclasses.field(default_factory=dict)
    # The owning executor's tracer (None = tracing off).  Backends with
    # internally interesting structure (the sharded backend's per-device
    # scatter/gather loop) emit child spans through it; spans opened inside
    # an instrumented dispatch nest under the executor's stage span via
    # the tracer's lexical stack.  Typed loosely to keep backends importable
    # without the tracing module.
    tracer: "object | None" = None
    # The owning executor's timebase (``ManualClock`` in deterministic
    # tests/benches, ``time.perf_counter`` live).  Fault-aware backends
    # sleep injected straggles and stamp quarantine windows through it so
    # the whole fault story replays bit-identically under a manual clock.
    clock: "Callable[[], float]" = time.perf_counter
    # Devices declared lost for the *current* dispatch only (chaos
    # injection): the sharded backend's shard on a lost device raises
    # DeviceLostError and recovers on a survivor.  Cleared by the injector.
    lost_devices: frozenset = frozenset()
    # Fault-handling collaborators (duck-typed like ``tracer`` to keep
    # backends importable without the faults/telemetry modules): the
    # executor's Quarantine (sharded dispatch skips quarantined devices and
    # records new exclusions here), its DispatchWatchdog (per-device
    # straggler deadlines), and its RuntimeTelemetry (fault counters).
    quarantine: "object | None" = None
    watchdog: "object | None" = None
    telemetry: "object | None" = None
    # The owning executor's operand residency cache
    # (``repro.runtime.residency.ResidencyCache``), or None for the
    # historical stage-every-flush behavior.  With a cache attached, the
    # shared ``stage_group`` helper serves staged stacks from it (and the
    # sharded backend keeps per-device placement sets), so repeat flushes
    # of unchanged operands skip staging and are priced read-side-only.
    residency: "object | None" = None
    # Which physical write stream ``stage_group`` is staging into: "host"
    # for the staged-stack path, ("device", d) when the sharded backend
    # runs the inner backend against one device's sub-group.  Delta
    # classification keys its per-slot code signatures by this, so two
    # devices' same-shaped sub-groups never diff against each other's
    # staged codes.
    stage_stream: "object" = "host"

    def blocks_for(self, batch: int, h: int, w: int) -> "BlockPlan":
        """Resolved Pallas block sizes for a ``(batch, h, w)`` stacked DFT
        invocation, derived from the VMEM budget (``choose_blocks``).

        Keyed by the stack shape AND the budget's identity: replanning
        ``tile_k`` changes the dispatched stack depth, and an operator
        swapping the budget changes the blocks — either way the resolution
        must be fresh, never a stale plan shaped for the old layout."""
        budget = self.mem_budget
        key = (batch, h, w,
               None if budget is None else (budget.bytes_limit,
                                            budget.reserve))
        if key not in self.block_cache:
            self.block_cache[key] = choose_blocks(batch, h, w, w, budget)
        return self.block_cache[key]

    def factors(self, n: int,
                blocks: tuple = ()) -> tuple[jax.Array, jax.Array]:
        # Computed from host constants, so the cached matrices stay
        # *uncommitted*: jit moves them to whatever device a (possibly
        # sharded, committed) operand pins the computation to.  The key
        # carries the resolved block signature the matrices will be tiled
        # under: a replan that changes tile_k (hence the stack depth,
        # hence the budget-derived blocks) must never pair a freshly
        # compiled kernel with factors cached under the old layout — the
        # kernel jit-specializes on the block sizes, and keying the
        # factors identically keeps one cache entry per compiled layout.
        # The matrix *values* depend only on n, so layout entries alias
        # one shared pair (built once under the bare (n,) key) instead of
        # recomputing and holding duplicate O(n^2) arrays per layout.
        key = (n,) + tuple(blocks)
        if key not in self.factor_cache:
            base = self.factor_cache.setdefault((n,), dft_matrix_factors(n))
            self.factor_cache[key] = base
        return self.factor_cache[key]

    def content_key(self, kernel: jax.Array) -> tuple:
        """Content key of an operand: shape, dtype, SHA1 of the bytes.

        Content-keyed (not id-keyed): object identity can be recycled by
        the allocator after a temporary kernel dies, which would serve a
        stale cache entry.  Repeat hashing of a long-lived kernel is
        avoided by an id-keyed memo that HOLDS a reference to the array —
        a live entry pins the object, so a *recycled* id cannot alias
        while the memo is valid.  Pinning cannot protect against in-place
        mutation though: a writeable numpy buffer reused across submits
        is the same object with different bytes, so only immutable
        operands (jax arrays, read-only ndarrays) are memoized — mutable
        ones re-hash every time."""
        memo = self._digest_memo.get(id(kernel))
        if memo is not None and memo[0] is kernel:
            return memo[1]
        arr = np.asarray(kernel)
        key = (arr.shape, str(arr.dtype),
               hashlib.sha1(arr.tobytes()).hexdigest())
        if isinstance(kernel, np.ndarray) and kernel.flags.writeable:
            return key
        if len(self._digest_memo) >= 64:  # bounded: kernels are few
            self._digest_memo.clear()
        self._digest_memo[id(kernel)] = (kernel, key)
        return key

    def mask(self, kernel: jax.Array) -> jax.Array:
        # The key also carries the kernel's device placement: a kernel
        # committed to one device pins its mask there, and serving that
        # mask to a stack committed elsewhere would crash the jitted conv
        # with mixed-device operands.  (Uncommitted kernels — the usual
        # case, including sharded dispatch — yield an uncommitted mask
        # that follows whatever device the stack is committed to.)
        devs = getattr(kernel, "devices", None)
        dev_key = tuple(sorted(d.id for d in devs())) if callable(devs) \
            else ()
        key = self.content_key(kernel) + (dev_key,)
        if key not in self.mask_cache:
            self.mask_cache[key] = fourier_mask_for_kernel(kernel)
        return self.mask_cache[key]

    @property
    def sim_params(self) -> OpticalSimParams:
        return OpticalSimParams(dac_bits=self.spec.dac.bits,
                                adc_bits=self.spec.adc.bits)


class ExecutionBackend(abc.ABC):
    """One way of executing the planner's op categories."""

    name: str = "?"

    def supports(self, category: str, ctx: BackendContext) -> bool:
        if category not in CATEGORIES:
            return False
        if category == "matmul":
            return isinstance(ctx.spec, OpticalMVMAcceleratorSpec) \
                or self.name == "host"
        return isinstance(ctx.spec, OpticalFourierAcceleratorSpec) \
            or self.name == "host"

    @abc.abstractmethod
    def run(self, category: str, xs: Sequence[jax.Array], ctx: BackendContext,
            *, kernel: jax.Array | None = None,
            weights: jax.Array | None = None,
            ) -> tuple[list[jax.Array], StepCost | None]:
        """Execute a batch of same-shape requests.

        Returns per-item results and the modeled cost of the whole batch
        (None for backends whose cost is just their measured wall time)."""


def _samples(x: jax.Array) -> int:
    return int(x.size)


def stage_group(category: str, xs: Sequence[jax.Array], ctx: BackendContext,
                *, single_expand: bool = False,
                ) -> tuple[jax.Array, int, tuple]:
    """Stack a same-shape group into the dispatch operand, serving the
    staged stack from the context's residency cache on a content hit.

    Returns ``(stack, resident, delta_fractions)``: ``resident`` is how
    many of the group's items were already staged (``len(xs)`` on a
    group-grain hit), and ``delta_fractions`` the per-frame write scales
    of the items that changed *little enough* to take the delta-encoded
    partial write.  On a group miss each frame is classified against the
    operand last staged into its dispatch slot (the context's
    ``stage_stream`` + category + shape + position, via
    ``ResidencyCache.classify_operand``): an unchanged frame counts
    resident, a low-flip frame contributes its write scale, everything
    else re-stages in full.  The analog backends thread both into
    ``batched_step_cost(resident_frames=..., delta_fractions=...)`` so
    the modeled price matches what dispatch just skipped.  With no cache
    attached this is exactly the historical ``jnp.stack`` (or the host's
    single-item expand), bit for bit.

    Rerunning the same jitted computation on the same cached stack yields
    bit-identical results, which is how the runtime-equivalence invariant
    extends to cached == delta-staged == re-staged.
    """
    res = getattr(ctx, "residency", None)
    if res is None:
        if single_expand and len(xs) == 1:
            return xs[0][None], 0, ()
        return jnp.stack(list(xs)), 0, ()
    key = residency_key(ctx, xs, "frame")
    stack = res.lookup("host", key, category=category, ctx=ctx)
    if stack is not None:
        return stack, len(xs), ()
    if single_expand and len(xs) == 1:
        stack = xs[0][None]
    else:
        stack = jnp.stack(list(xs))
    res.store("host", key, stack,
              int(getattr(stack, "nbytes", stack.size * 4)),
              category=category, kind="frame", ctx=ctx)
    classify = getattr(res, "classify_operand", None)
    if classify is None:
        return stack, 0, ()
    # group-grain miss: classify each frame against its dispatch slot —
    # unchanged frames are still resident per-frame, drifted ones delta
    stream = getattr(ctx, "stage_stream", "host")
    shape_sig = (tuple(xs[0].shape), str(xs[0].dtype))
    op = key[1]
    resident = 0
    deltas: list[float] = []
    for i, ck in enumerate(key[2]):
        slot = (stream, category, "frame", op, shape_sig, i)
        label, scale = classify(slot, ck, xs[i], ctx.spec,
                                category=category, ctx=ctx)
        if label == "hit":
            resident += 1
        elif label == "delta":
            deltas.append(scale)
    return stack, resident, tuple(deltas)


def _operand_resident(category: str, arr: jax.Array, ctx: BackendContext,
                      kind: str) -> bool:
    """Whether a kernel/weight operand is resident (registering it when
    not): True means this invocation writes no weight samples."""
    res = getattr(ctx, "residency", None)
    if res is None or arr is None:
        return False
    key = residency_key(ctx, [arr], kind)
    if res.lookup("host", key, category=category, ctx=ctx) is not None:
        return True
    res.store("host", key, arr, int(getattr(arr, "nbytes", arr.size * 4)),
              category=category, kind=kind, ctx=ctx)
    return False


# --- host: the digital baseline ----------------------------------------------

# Each op accepts a leading batch axis natively: fft2/ifft2 act on the last
# two axes (the (H, W) kernel broadcasts under the (K, H, W) stack) and
# (K, m, k) @ (k, n) is a batched matmul.  One jitted call serves the group.


@jax.jit
def _host_fft_intensity(a: jax.Array) -> jax.Array:
    return jnp.abs(jnp.fft.fft2(a, norm="ortho")) ** 2


@jax.jit
def _host_circular_conv(a: jax.Array, k: jax.Array) -> jax.Array:
    return jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(k)))


@jax.jit
def _host_matmul(a: jax.Array, w: jax.Array) -> jax.Array:
    return a @ w


class HostBackend(ExecutionBackend):
    """Pure JAX execution; cost is whatever wall time the executor measures."""

    name = "host"

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        stack, _, _ = stage_group(category, xs, ctx, single_expand=True)
        if category == "fft":
            out = _host_fft_intensity(stack)
        elif category == "conv":
            out = _host_circular_conv(stack, kernel)
        elif category == "matmul":
            out = _host_matmul(stack, weights)
        else:
            raise ValueError(f"unknown category {category!r}")
        return list(out), None


# --- optical-sim: the conversion boundary, executed and priced ----------------


def conv_range_map(stack: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-frame affine map of arbitrary-range frames onto the SLM's [0, 1]
    aperture: the DAC's full-scale range is fixed and the SLM cannot encode
    negative amplitudes.  Conv is linear, so the map undoes exactly:
    conv(s*v + lo) = s*conv(v) + lo*sum(kernel) (circular conv of a
    constant plane is the kernel sum).  Shared by the batched conv path and
    the frame-sharded tiler — the two must use the SAME map (one grid of
    DAC quantization points) or sharded results drift from unsharded ones.
    """
    lo = jnp.min(stack, axis=(-2, -1), keepdims=True)
    scale = jnp.maximum(jnp.max(stack, axis=(-2, -1), keepdims=True) - lo,
                        1e-9)
    return lo, scale


@functools.partial(jax.jit, static_argnames=("params",))
def _optical_conv_batched(stack: jax.Array, mask: jax.Array, ksum: jax.Array,
                          params: OpticalSimParams) -> jax.Array:
    # lo/scale are per frame, and ``optical_conv2d_batched`` keeps the
    # interferometric ADC full-scale per frame too.
    lo, scale = conv_range_map(stack)
    v = (stack - lo) / scale
    out = optical_conv2d_batched(v, mask, params, None)
    return out * scale + lo * ksum


@functools.partial(jax.jit, static_argnames=("dac_bits", "adc_bits"))
def _optical_matmul_batched(stack: jax.Array, w: jax.Array, *,
                            dac_bits: int, adc_bits: int) -> jax.Array:
    # One streamed invocation: the batch stacks activation rows, but each
    # item keeps its own DAC range mapping and differential ADC ranges.
    def one(a):
        scale = jnp.maximum(jnp.max(jnp.abs(a)), 1e-9)
        q = dac_quantize(0.5 * (a / scale + 1.0), dac_bits) * 2.0 - 1.0
        y = (q * scale) @ w
        pos = jnp.maximum(y, 0.0)
        neg = jnp.maximum(-y, 0.0)  # differential readout: two ADC ranges
        return adc_quantize(pos, adc_bits) - adc_quantize(neg, adc_bits)

    return jax.vmap(one)(stack)


class OpticalSimBackend(ExecutionBackend):
    """Simulated analog engine with DAC/ADC quantization applied.

    Every category executes the whole group in ONE batched invocation:
    ``fft`` runs the batched Pallas pipeline (``dft_stage1_batched``/
    ``dft_stage2_batched`` — batch on the leading grid axis, cached factor
    matrices shared across frames) then a per-frame auto-ranged ADC pass;
    ``conv`` runs the 4f physics simulator vmapped over the stacked batch;
    ``matmul`` streams the stacked activations through the converter
    models around one batched matmul standing in for the MVM core.  Every
    batch returns a :class:`StepCost` from the spec's
    ``batched_step_cost`` at the context's pipeline depth, so callers
    always see the (overlap-aware) boundary price.
    """

    name = "optical-sim"

    def _fft_batched(self, stack: jax.Array, ctx: BackendContext) -> jax.Array:
        if INTERPRET:
            # Off-TPU the Pallas interpreter copies the whole batched
            # output per grid step (a correctness simulator, not a perf
            # one): run the same fused semantics as one XLA dispatch.
            intensity = _dft2_intensity_batched_xla(
                stack, dac_bits=ctx.spec.dac.bits)
        else:
            batch, h, w = stack.shape
            # block sizes come from the VMEM budget, not fixed defaults;
            # factors are cached per resolved layout (see ctx.factors)
            plan = ctx.blocks_for(batch, h, w)
            whr, whi = ctx.factors(h, plan.key)
            wwr, wwi = ctx.factors(w, plan.key)
            tr, ti = dft_stage1_batched(whr, whi, stack,
                                        dac_bits=ctx.spec.dac.bits,
                                        bb=plan.bb, bm=plan.bm,
                                        bk=plan.bk, bn=plan.bn)
            intensity = dft_stage2_batched(tr, ti, wwr, wwi, bb=plan.bb,
                                           bm=plan.bm, bk=plan.bk,
                                           bn=plan.bn)
        return adc_quantize_batched(intensity, ctx.spec.adc.bits)

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        batch = len(xs)
        n_in = _samples(xs[0])
        stack, resident, deltas = stage_group(category, xs, ctx)
        depth = ctx.pipeline_depth
        priced_residency = getattr(ctx, "residency", None) is not None
        if category == "fft":
            out = self._fft_batched(stack, ctx)
            cost = ctx.spec.batched_step_cost(n_in, _samples(out[0]),
                                              batch=batch,
                                              pipeline_depth=depth,
                                              resident_frames=resident,
                                              delta_fractions=deltas)
        elif category == "conv":
            mask = ctx.mask(kernel)
            # registered before the mask build so a repeat kernel prices as
            # resident even though ctx.mask memoizes the mask either way
            k_resident = _operand_resident(category, kernel, ctx, "kernel")
            out = _optical_conv_batched(stack, mask, jnp.sum(kernel),
                                        ctx.sim_params)
            spec4 = dataclasses.replace(ctx.spec,
                                        phase_shift_captures=CONV_CAPTURES)
            k_n = _samples(kernel) if priced_residency else 0
            cost = spec4.batched_step_cost(
                n_in, _samples(out[0]), batch=batch, pipeline_depth=depth,
                resident_frames=resident, weight_samples=k_n,
                resident_weights=k_n if k_resident else 0,
                delta_fractions=deltas)
        elif category == "matmul":
            w_resident = _operand_resident(category, weights, ctx, "weights")
            out = _optical_matmul_batched(stack, weights,
                                          dac_bits=ctx.spec.dac.bits,
                                          adc_bits=ctx.spec.adc.bits)
            m, k = xs[0].shape
            n = weights.shape[-1]
            # Batching stacks activations along m: one streamed invocation.
            # With residency priced, a non-resident weight panel charges
            # its one-time DAC load (weight_write) and fully resident
            # activations drop the streaming DAC term: hits read-side-only.
            w_write = priced_residency and not w_resident
            cost = ctx.spec.matmul_cost(batch * m, k, n,
                                        weight_write=w_write)
            if resident >= batch:
                act_free = ctx.spec.dac.time_for(k * n, ctx.spec.dac_lanes) \
                    if w_write else 0.0
                cost = dataclasses.replace(cost, dac_s=act_free)
            elif deltas:
                # delta-staged activations: resident frames free, delta
                # frames at their write scale, the rest whole — same
                # resident → delta → full accounting as _group_sides
                written = batch - resident
                ws = (math.fsum(deltas) + (written - len(deltas))) / written
                col_tiles = math.ceil(n / ctx.spec.cols)
                w_dac = ctx.spec.dac.time_for(k * n, ctx.spec.dac_lanes) \
                    if w_write else 0.0
                act_dac = ctx.spec.dac.time_for(
                    written * m * k * col_tiles, ctx.spec.dac_lanes) * ws
                cost = dataclasses.replace(cost, dac_s=w_dac + act_dac)
            cost = dataclasses.replace(
                cost, interface_s=ctx.spec.interface_latency_s)
        else:
            raise ValueError(f"unknown category {category!r}")
        return list(out), cost


# --- ideal: the zero-conversion-cost analog bound -----------------------------


def ideal_step_cost(spec, category: str, calls: int) -> StepCost:
    """The zero-conversion analog bound for one invocation: physics only.

    Shared by :class:`IdealBackend` and the sharded tiler's per-device
    pricing so the Table-1 bound has exactly one definition."""
    if isinstance(spec, OpticalMVMAcceleratorSpec):
        analog = calls * spec.optical_pass_s
    else:
        caps = CONV_CAPTURES if category == "conv" \
            else spec.phase_shift_captures
        analog = ((spec.slm_settle_s + spec.exposure_s) * caps
                  + spec.time_of_flight_s())
    return StepCost(0.0, 0.0, 0.0, analog_s=analog)


class IdealBackend(ExecutionBackend):
    """Exact digital values, priced as if conversion and interface were free.

    This is the paper's Table-1 'ideal accelerator' column made executable:
    the only cost charged is the analog physics itself, so comparing a plan
    under ``ideal`` against ``optical-sim`` isolates exactly what the
    boundary costs.
    """

    name = "ideal"

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        outs, _ = _HOST.run(category, xs, ctx, kernel=kernel, weights=weights)
        return outs, ideal_step_cost(ctx.spec, category, len(xs))


_HOST = HostBackend()

_REGISTRY: dict[str, Callable[[], ExecutionBackend]] = {}


def register_backend(name: str, factory: Callable[[], ExecutionBackend]) -> None:
    """Register (or override) a backend under ``name``."""
    _REGISTRY[name] = factory


def get_backend(name: str) -> ExecutionBackend:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"available: {available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend("host", HostBackend)
register_backend("optical-sim", OpticalSimBackend)
register_backend("ideal", IdealBackend)
