"""Backend registry: three interchangeable executors per op category.

Every backend implements the same three op categories the planner knows
about (``fft``, ``conv``, ``matmul``) with identical call signatures, so
the executor can swap them per the routing table without touching callers:

  ``host``        pure digital JAX (fft2 / circular conv / matmul) — the
                  baseline the planner's ``host_s`` measures.
  ``optical-sim`` the simulated analog engine with the conversion boundary
                  applied: the fused Pallas DFT pipeline (DAC quantization
                  folded into stage 1, square-law detector into stage 2)
                  plus the auto-ranged ADC read path for ``fft``; the 4f
                  physics simulator for ``conv``; DAC->MVM->ADC for
                  ``matmul``.  Returns a modeled :class:`StepCost` built
                  from the executor's accelerator spec so every result is
                  priced, not just produced.
  ``ideal``       the zero-conversion-cost analog bound (paper Table 1):
                  exact digital values, cost = analog physics only.

Op semantics (fixed across backends so results are comparable):

  fft(a)        -> detector intensity |F a|^2 of the unitary 2-D DFT,
                   a real, values in [0, 1] (the camera cannot see phase;
                   a single capture yields intensity — paper App. A.1).
  conv(a, k)    -> circular 2-D convolution (4-step interferometric capture
                   + host-side inverse transform, paper Eq. 1).
  matmul(a, w)  -> a @ w with activations streamed through the converters
                   (weights held in the optical domain, amortized).

Backends execute batch items one by one through per-shape jit caches:
batching in this runtime amortizes *boundary* costs (one invocation, one
frame, one handshake — see ``batched_step_cost``), and per-item execution
keeps results bit-identical whether or not calls were coalesced.
"""

from __future__ import annotations

import abc
import dataclasses
import hashlib
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import (
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
    StepCost,
)
from repro.core.optical import (
    OpticalSimParams,
    adc_quantize,
    dac_quantize,
    fourier_mask_for_kernel,
    optical_conv2d,
)
from repro.kernels.optical_dft import dft_matrix_factors, dft_stage1, dft_stage2

__all__ = [
    "CATEGORIES",
    "BackendContext",
    "ExecutionBackend",
    "HostBackend",
    "OpticalSimBackend",
    "IdealBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]

CATEGORIES = ("fft", "conv", "matmul")

# Interferometric complex recovery (needed by conv) costs 4 captures.
_CONV_CAPTURES = 4


@dataclasses.dataclass
class BackendContext:
    """Per-executor state shared with backends: the accelerator spec plus
    the shape-keyed caches (DFT factor matrices, Fourier-plane masks).
    Compiled kernels are cached by jit itself, keyed on the same shapes."""

    spec: OpticalFourierAcceleratorSpec | OpticalMVMAcceleratorSpec
    factor_cache: dict[int, tuple[jax.Array, jax.Array]] = \
        dataclasses.field(default_factory=dict)
    mask_cache: dict[tuple, jax.Array] = dataclasses.field(default_factory=dict)

    def factors(self, n: int) -> tuple[jax.Array, jax.Array]:
        if n not in self.factor_cache:
            self.factor_cache[n] = dft_matrix_factors(n)
        return self.factor_cache[n]

    def mask(self, kernel: jax.Array) -> jax.Array:
        # Content-keyed (not id-keyed): object identity can be recycled by
        # the allocator after a temporary kernel dies, which would serve a
        # stale mask.  Kernels are small; one host hash per flush group.
        arr = np.asarray(kernel)
        key = (arr.shape, str(arr.dtype),
               hashlib.sha1(arr.tobytes()).hexdigest())
        if key not in self.mask_cache:
            self.mask_cache[key] = fourier_mask_for_kernel(kernel)
        return self.mask_cache[key]

    @property
    def sim_params(self) -> OpticalSimParams:
        return OpticalSimParams(dac_bits=self.spec.dac.bits,
                                adc_bits=self.spec.adc.bits)


class ExecutionBackend(abc.ABC):
    """One way of executing the planner's op categories."""

    name: str = "?"

    def supports(self, category: str, ctx: BackendContext) -> bool:
        if category not in CATEGORIES:
            return False
        if category == "matmul":
            return isinstance(ctx.spec, OpticalMVMAcceleratorSpec) \
                or self.name == "host"
        return isinstance(ctx.spec, OpticalFourierAcceleratorSpec) \
            or self.name == "host"

    @abc.abstractmethod
    def run(self, category: str, xs: Sequence[jax.Array], ctx: BackendContext,
            *, kernel: jax.Array | None = None,
            weights: jax.Array | None = None,
            ) -> tuple[list[jax.Array], StepCost | None]:
        """Execute a batch of same-shape requests.

        Returns per-item results and the modeled cost of the whole batch
        (None for backends whose cost is just their measured wall time)."""


def _samples(x: jax.Array) -> int:
    return int(x.size)


# --- host: the digital baseline ----------------------------------------------


@jax.jit
def _host_fft_intensity(a: jax.Array) -> jax.Array:
    return jnp.abs(jnp.fft.fft2(a, norm="ortho")) ** 2


@jax.jit
def _host_circular_conv(a: jax.Array, k: jax.Array) -> jax.Array:
    return jnp.real(jnp.fft.ifft2(jnp.fft.fft2(a) * jnp.fft.fft2(k)))


@jax.jit
def _host_matmul(a: jax.Array, w: jax.Array) -> jax.Array:
    return a @ w


class HostBackend(ExecutionBackend):
    """Pure JAX execution; cost is whatever wall time the executor measures."""

    name = "host"

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        if category == "fft":
            outs = [_host_fft_intensity(x) for x in xs]
        elif category == "conv":
            outs = [_host_circular_conv(x, kernel) for x in xs]
        elif category == "matmul":
            outs = [_host_matmul(x, weights) for x in xs]
        else:
            raise ValueError(f"unknown category {category!r}")
        return outs, None


# --- optical-sim: the conversion boundary, executed and priced ----------------


class OpticalSimBackend(ExecutionBackend):
    """Simulated analog engine with DAC/ADC quantization applied.

    ``fft`` runs the fused Pallas pipeline (``dft_stage1``/``dft_stage2``
    with cached factor matrices) then the auto-ranged ADC pass; ``conv``
    runs the 4f physics simulator; ``matmul`` streams activations through
    the converter models around a digital matmul standing in for the MVM
    core.  Every batch returns a :class:`StepCost` from the spec's
    ``batched_step_cost`` so callers always see the boundary price.
    """

    name = "optical-sim"

    def _fft_one(self, a: jax.Array, ctx: BackendContext) -> jax.Array:
        h, w = a.shape
        whr, whi = ctx.factors(h)
        wwr, wwi = ctx.factors(w)
        tr, ti = dft_stage1(whr, whi, a, dac_bits=ctx.spec.dac.bits)
        intensity = dft_stage2(tr, ti, wwr, wwi)
        return adc_quantize(intensity, ctx.spec.adc.bits)

    def _conv_one(self, a: jax.Array, kernel: jax.Array,
                  ctx: BackendContext) -> jax.Array:
        mask = ctx.mask(kernel)
        # The DAC's full-scale range is fixed [0, 1] and the SLM cannot
        # encode negative amplitudes, so the host affine-maps the input
        # onto the aperture and undoes the map after: conv is linear, and
        # conv(s*v + lo) = s*conv(v) + lo*sum(kernel) (circular conv of a
        # constant plane is the kernel sum).
        lo = jnp.min(a)
        scale = jnp.maximum(jnp.max(a) - lo, 1e-9)
        v = (a - lo) / scale
        out = optical_conv2d(v, mask, ctx.sim_params, None)
        return out * scale + lo * jnp.sum(kernel)

    def _matmul_one(self, a: jax.Array, w: jax.Array,
                    ctx: BackendContext) -> jax.Array:
        scale = jnp.maximum(jnp.max(jnp.abs(a)), 1e-9)
        q = dac_quantize(0.5 * (a / scale + 1.0), ctx.spec.dac.bits) * 2.0 - 1.0
        y = (q * scale) @ w
        pos = jnp.maximum(y, 0.0)
        neg = jnp.maximum(-y, 0.0)  # differential readout: two ADC ranges
        return (adc_quantize(pos, ctx.spec.adc.bits)
                - adc_quantize(neg, ctx.spec.adc.bits))

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        batch = len(xs)
        n_in = _samples(xs[0])
        if category == "fft":
            outs = [self._fft_one(x, ctx) for x in xs]
            cost = ctx.spec.batched_step_cost(n_in, _samples(outs[0]),
                                              batch=batch)
        elif category == "conv":
            outs = [self._conv_one(x, kernel, ctx) for x in xs]
            spec4 = dataclasses.replace(ctx.spec,
                                        phase_shift_captures=_CONV_CAPTURES)
            cost = spec4.batched_step_cost(n_in, _samples(outs[0]),
                                           batch=batch)
        elif category == "matmul":
            outs = [self._matmul_one(x, weights, ctx) for x in xs]
            m, k = xs[0].shape
            n = weights.shape[-1]
            # Batching stacks activations along m: one streamed invocation.
            cost = dataclasses.replace(
                ctx.spec.matmul_cost(batch * m, k, n),
                interface_s=ctx.spec.interface_latency_s)
        else:
            raise ValueError(f"unknown category {category!r}")
        return outs, cost


# --- ideal: the zero-conversion-cost analog bound -----------------------------


class IdealBackend(ExecutionBackend):
    """Exact digital values, priced as if conversion and interface were free.

    This is the paper's Table-1 'ideal accelerator' column made executable:
    the only cost charged is the analog physics itself, so comparing a plan
    under ``ideal`` against ``optical-sim`` isolates exactly what the
    boundary costs.
    """

    name = "ideal"

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        outs, _ = _HOST.run(category, xs, ctx, kernel=kernel, weights=weights)
        spec = ctx.spec
        if isinstance(spec, OpticalMVMAcceleratorSpec):
            analog = len(xs) * spec.optical_pass_s
        else:
            caps = _CONV_CAPTURES if category == "conv" \
                else spec.phase_shift_captures
            analog = ((spec.slm_settle_s + spec.exposure_s) * caps
                      + spec.time_of_flight_s())
        return outs, StepCost(0.0, 0.0, 0.0, analog_s=analog)


_HOST = HostBackend()

_REGISTRY: dict[str, Callable[[], ExecutionBackend]] = {}


def register_backend(name: str, factory: Callable[[], ExecutionBackend]) -> None:
    """Register (or override) a backend under ``name``."""
    _REGISTRY[name] = factory


def get_backend(name: str) -> ExecutionBackend:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"available: {available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend("host", HostBackend)
register_backend("optical-sim", OpticalSimBackend)
register_backend("ideal", IdealBackend)
