"""Chrome/Perfetto ``trace_event`` export: open a flush in a trace viewer.

Converts :class:`~repro.runtime.tracing.Span` records into the JSON object
format Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load —
the ``{"traceEvents": [...]}`` envelope with microsecond timestamps:

  * ``sync`` spans    -> ``ph: "X"`` complete slices (nested slices stack);
  * ``async`` spans   -> ``ph: "b"`` / ``ph: "e"`` async begin/end pairs
                         (containers like release/invocation/held overlap
                         on one lane without implying a call stack);
  * ``instant`` spans -> ``ph: "i"`` thread-scoped instants.

Each tracer *lane* ("sched", "host", "device0"...) becomes one tid, named
via ``M``-phase ``thread_name`` metadata, so a traced sharded flush renders
as a swimlane per device under the host staging lane.  Timestamps are
rebased to the earliest span so traces start at t=0 regardless of the
clock's epoch.

Also here: :func:`stage_sums` / :func:`reconcile` (do the per-stage charged
sums add back up to the measured wall? — the 10% acceptance gate) and
:func:`summarize` (the one-screen trace digest the example prints).
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Sequence

__all__ = ["to_trace_events", "write_trace", "stage_sums", "reconcile",
           "summarize"]

_PID = 1

# Span attrs measuring one invocation's charged stage decomposition — the
# executor writes these at retirement (see executor._retire).
_CHARGED = ("hold_s", "stage_s", "compute_s", "shadow_s")


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def _lane_tids(spans: Sequence) -> dict[str, int]:
    lanes: dict[str, None] = {}
    for s in spans:
        lanes.setdefault(s.lane)
    order = sorted(lanes, key=lambda la: (la != "sched", la != "host", la))
    return {lane: i + 1 for i, lane in enumerate(order)}


def to_trace_events(spans: Iterable) -> list[dict]:
    """Spans -> Chrome ``trace_event`` dicts (ts/dur in microseconds)."""
    spans = [s for s in spans if s.t1 is not None]
    if not spans:
        return []
    tids = _lane_tids(spans)
    base = min(s.t0 for s in spans)
    events: list[dict] = [
        {"ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
         "args": {"name": lane}}
        for lane, tid in tids.items()]
    for s in sorted(spans, key=lambda s: (s.t0, s.span_id)):
        common = {
            "name": s.name, "pid": _PID, "tid": tids[s.lane],
            "ts": (s.t0 - base) * 1e6,
            "args": _jsonable(dict(s.attrs, span_id=s.span_id,
                                   parent_id=s.parent_id)),
        }
        if s.kind == "instant":
            events.append(dict(common, ph="i", s="t"))
        elif s.kind == "async":
            # async pairs share an id scope; cat is mandatory for b/e
            events.append(dict(common, ph="b", cat=s.name,
                               id=s.span_id))
            events.append({"ph": "e", "cat": s.name, "id": s.span_id,
                           "name": s.name, "pid": _PID,
                           "tid": tids[s.lane],
                           "ts": (s.t1 - base) * 1e6})
        else:
            events.append(dict(common, ph="X",
                               dur=max(s.t1 - s.t0, 0.0) * 1e6))
    return events


def write_trace(path: str, spans: Iterable) -> dict:
    """Write the Perfetto-loadable envelope; returns the payload written."""
    payload = {"traceEvents": to_trace_events(spans),
               "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def stage_sums(spans: Iterable) -> dict[str, float]:
    """Charged seconds per stage, summed over completed invocation spans.

    Uses the *charged* attrs the executor writes at retirement (hold /
    stage / compute / shadow), not raw leaf-span geometry: charged time
    never double-bills pipeline overlap, so these sums are the ones that
    reconcile with a flush's measured wall."""
    out = {k[:-2]: 0.0 for k in _CHARGED}
    out["wall"] = 0.0
    for s in spans:
        if s.name != "invocation" or s.t1 is None:
            continue
        for k in _CHARGED:
            out[k[:-2]] += float(s.attrs.get(k, 0.0))
        out["wall"] += float(s.attrs.get("wall_s", 0.0))
    return out


def reconcile(spans: Iterable, measured_wall_s: float) -> dict:
    """Do the per-stage charged sums add back up to the measured wall?

    Returns the stage sums plus ``coverage`` = (stage + compute + hold +
    shadow) / measured_wall_s.  Coverage ~= 1 means the span decomposition
    accounts for the flush end to end (the acceptance gate asserts within
    10%); a shortfall is un-attributed host time between dispatches."""
    sums = stage_sums(spans)
    attributed = (sums["stage"] + sums["compute"] + sums["hold"]
                  + sums["shadow"])
    return dict(sums, attributed_s=attributed,
                measured_wall_s=measured_wall_s,
                coverage=(attributed / measured_wall_s
                          if measured_wall_s > 0.0 else float("nan")))


def summarize(spans: Iterable) -> str:
    """One-screen digest: span counts and total duration per (lane, name)."""
    spans = [s for s in spans if s.t1 is not None]
    rows = ["trace summary:"]
    if not spans:
        return rows[0] + " (no spans)"
    agg: dict[tuple[str, str], list[float]] = {}
    for s in spans:
        acc = agg.setdefault((s.lane, s.name), [0, 0.0])
        acc[0] += 1
        acc[1] += s.duration_s
    rows.append(f"  {'lane':>8}  {'span':<16} {'count':>5}  {'total':>10}")
    for (lane, name), (count, total) in sorted(agg.items()):
        rows.append(f"  {lane:>8}  {name:<16} {count:5d}  {total:10.3e}s")
    sums = stage_sums(spans)
    if sums["wall"] > 0.0:
        rows.append(
            f"  charged: stage={sums['stage']:.3e}s "
            f"compute={sums['compute']:.3e}s hold={sums['hold']:.3e}s "
            f"shadow={sums['shadow']:.3e}s (wall {sums['wall']:.3e}s)")
    return "\n".join(rows)
