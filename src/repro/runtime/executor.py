"""The offload executor: turns ``OffloadPlan`` decisions into execution.

Callers ``submit`` accelerable ops (fft / conv / matmul) and the executor
coalesces queued calls of the same shape into one accelerator invocation at
``flush`` time.  That is the paper's §6 batching lever made operational —
and made *real*: each group executes as ONE batched backend invocation
(stacked ``(K, H, W)`` operands, batched Pallas kernels / vmapped physics),
so a K-deep flush pays one dispatch round-trip and one kernel launch
instead of K, while per-invocation boundary costs (link handshake latency,
SLM settle/exposure, converter-lane ceil residue) amortize across the batch
in the modeled price.

Since the scheduler refactor, *flushing is a mechanism, not a policy*:
``flush``/``flush_async`` still drain the whole queue (the eager path), but
the group-releasing primitive they are built on — :meth:`OffloadExecutor.release`
— is public, and an attached :class:`~repro.runtime.scheduler.OffloadScheduler`
drives it selectively: partially filled groups stay queued ("held") across
scheduler passes until admission control says waiting can no longer raise
occupancy.  Every submission is timestamped, so held groups know their age,
telemetry knows the arrival process, and a group's queueing delay is priced
into its invocation (``StepCost.hold_s``) when a scheduler is in charge.
The executor is also a context manager: leaving the ``with`` block flushes
queued + held work and drains the pipeline, so examples and tests cannot
leak pending groups.

``flush`` is additionally *pipelined* two deep: dispatch is asynchronous
(JAX async dispatch — no premature ``block_until_ready``), so while group
k's analog+ADC compute is in flight, group k+1's host-side staging and
DAC-prep proceed, and only when a third group wants to dispatch does the
oldest get retired (blocked + recorded).  ``flush_async`` exposes the
non-blocking form: results fill immediately with async values, readiness is
queryable per result (:meth:`OffloadResult.done`), and telemetry for still
in-flight groups lands at retire time (``drain`` / next flush / ``wait``).

Execution is recorded into :class:`RuntimeTelemetry` — call counts, sample
counts, wall time, modeled cost — so ``telemetry.profiles()`` can re-enter
``plan_offload`` and the plan can be re-derived from observed traffic.
Optionally every optical-sim batch is shadowed by the host backend and
scored by a :class:`FidelityChecker`, pairing each speedup with its
quantization-error cost (shadow scoring needs concrete values, so fidelity
batches retire synchronously — validation mode trades the pipeline away).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Mapping

import jax

from repro.core.accelerator import (
    PROTOTYPE_4F,
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
    StepCost,
)
from repro.runtime.backends import (
    BackendContext,
    ExecutionBackend,
    get_backend,
)
from repro.runtime.faults import (
    DispatchWatchdog,
    FaultError,
    Quarantine,
    RetryPolicy,
    advance_or_sleep,
)
from repro.runtime.fidelity import FidelityChecker, FidelityReport
from repro.runtime.residency import ResidencyCache
from repro.runtime.telemetry import RuntimeTelemetry
from repro.runtime.tiling import MemoryBudget, choose_tile, tile_sizes
from repro.runtime.tracing import Span, Tracer

__all__ = ["OffloadResult", "OffloadExecutor"]

# Backends whose batches carry quantization error worth shadow-scoring (the
# sharded backend's default inner is the optical simulator).
_SHADOWED = ("optical-sim", "sharded")


def _shadow_worthy(be: ExecutionBackend) -> bool:
    """Whether ``be``'s batches deserve fidelity shadowing.  Wrappers (the
    chaos backend) expose the wrapped backend via ``inner_name`` so a
    fault-injected optical backend is shadowed like the optical backend —
    the drift faults it injects are exactly what the shadow must catch."""
    return (be.name in _SHADOWED
            or getattr(be, "inner_name", None) in _SHADOWED)


def _block(x: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _is_ready(x: Any) -> bool:
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "is_ready") and not leaf.is_ready():
            return False
    return True


class OffloadResult:
    """Handle for a submitted call; materializes at ``flush``/``flush_async``.

    Attributes (valid once ``ready``):
      value: the op result (an *asynchronously dispatched* jax array after
        ``flush_async`` — usable immediately, concrete after ``wait``).
      cost: modeled per-call share of the invocation's :class:`StepCost`.
      backend: backend name that served the call.
      batch: how many calls shared the invocation.
      fidelity: the batch's :class:`FidelityReport` (when checking is on).
    """

    def __init__(self, executor: "OffloadExecutor") -> None:
        self._executor = executor
        self.ready = False
        self.value: jax.Array | None = None
        self.cost: StepCost | None = None
        self.backend: str | None = None
        self.batch: int = 0
        self.fidelity: FidelityReport | None = None

    def get(self) -> jax.Array:
        if not self.ready:
            self._executor.flush()
        else:
            self.wait()
        return self.value

    def done(self) -> bool:
        """True when the underlying device computation has completed.

        ``ready`` means the handle is filled (dispatch happened); ``done``
        additionally means the value would materialize without blocking.
        """
        return self.ready and _is_ready(self.value)

    def wait(self) -> "OffloadResult":
        """Block until this result's computation (and its telemetry) lands."""
        if not self.ready:
            self._executor.flush()
        self._executor._retire_containing(self)
        _block(self.value)
        return self

    def _fill(self, value: jax.Array, cost: StepCost, backend: str,
              batch: int, fidelity: FidelityReport | None) -> None:
        self.value = value
        self.cost = cost
        self.backend = backend
        self.batch = batch
        self.fidelity = fidelity
        self.ready = True


@dataclasses.dataclass
class _Pending:
    category: str
    x: jax.Array
    kernel: jax.Array | None
    weights: jax.Array | None
    backend: str
    result: OffloadResult
    t_submit: float = 0.0   # executor-clock submission timestamp
    call_id: int = 0        # monotone per-executor submission index

    def group_key(self) -> tuple:
        return (self.category, self.backend, tuple(self.x.shape),
                str(self.x.dtype), id(self.kernel), id(self.weights))


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-unretired batched invocation."""

    chunk: list[_Pending]
    be: ExecutionBackend
    outs: list[jax.Array]
    modeled: StepCost | None
    t0: float
    dispatch_s: float  # host time spent staging + dispatching (be.run)
    device_samples: list[tuple[int, int]] | None = None  # sharded dispatch
    shadow: bool = False  # fidelity shadow-scoring owed at retire
    hold_s: float = 0.0   # scheduler hold time priced into this invocation
    span: Span | None = None      # open invocation span (tracing on)
    t_stage_end: float = 0.0      # tracer-clock time staging finished
    wkey: tuple = ()              # (category, requested backend) window key


class OffloadExecutor:
    """Queue + batcher + two-deep pipeline in front of the backend registry.

    Args:
      spec: accelerator priced/simulated by the analog backends.
      default_backend: where submits go when the caller (or router) does
        not name one.
      telemetry: shared :class:`RuntimeTelemetry` (created if omitted).
      fidelity: optional :class:`FidelityChecker`; when set, optical-sim
        batches are shadowed by the host backend and scored (validation
        mode — the shadow run is excluded from telemetry, and fidelity
        batches retire synchronously, bypassing the async pipeline).
      max_batch: largest number of calls coalesced into one invocation.
        A global ceiling; per-category ceilings (``set_max_batch``) let the
        router adapt coalescing depth per category without touching it.
      pipeline_depth: how many batched invocations may be in flight at
        once *per engine* — each ``(category, backend)`` pair owns its own
        in-flight window of this depth, so an fft group on the optical
        engine, a conv group on another, and a host-fallback group all
        overlap instead of serializing behind one shared deque (the
        pipeline is a small DAG; retirement stays submit-order *within*
        each engine).  2 (default) double-buffers each engine's boundary:
        group k+1 stages while group k computes.  1 restores strictly
        serial dispatch-then-block crossings per engine.  Per-category
        depths (``set_pipeline_window``) let the router adapt window depth
        per engine; the global value is the default/back-compat alias
        every unpinned category inherits.
      shared_window: ``True`` restores the pre-per-engine discipline — ONE
        global ``pipeline_depth``-deep window shared by every engine, so
        dispatching any invocation retires the globally oldest one
        regardless of engine.  The measured baseline per-engine windows
        are benched against.
      n_devices: how many replicated simulated accelerators the ``sharded``
        backend scatters each invocation across.  A global ceiling;
        per-category counts (``set_n_devices``) let the router adapt the
        device fan-out per category, the same way ``set_max_batch`` adapts
        coalescing depth.
      shard_mode: the sharded backend's split policy (``auto`` / ``group``
        / ``frame`` — see ``repro.runtime.sharded``).
      mem_budget: per-device staging byte budget
        (:class:`~repro.runtime.tiling.MemoryBudget`).  ``None`` (default)
        auto-detects: VMEM-derived on TPU, LLC-derived off it.  A released
        group whose monolithic ``(K, H, W)`` stack would overflow the
        budget streams as ``ceil(K / tile_k)`` budget-sized sub-invocations
        through the two-deep pipeline instead (``choose_tile``); pass
        ``MemoryBudget.unlimited()`` to restore monolithic dispatch.
      tile_k: explicit frames-per-tile override (global; per-category
        overrides via ``set_tile_k``).  ``None`` derives it from
        ``mem_budget`` per released group — small frames never tile, a
        512x512 K=16 group streams in budget-sized chunks.
      clock: timebase for submission timestamps, hold accounting, and the
        telemetry arrival-rate estimate (``time.perf_counter`` by default;
        tests and benchmarks inject a manual clock for deterministic
        admission decisions).
      retry: the per-dispatch fault policy
        (:class:`~repro.runtime.faults.RetryPolicy`; a default one if
        omitted).  Every batched invocation runs under it: a dispatch
        raising :class:`~repro.runtime.faults.FaultError` is retried with
        exponential, jittered backoff (slept through ``clock``); when every
        attempt faults the dispatch degrades to ``retry.fallback`` (host)
        and the category is quarantined so subsequent dispatches reroute
        immediately.  The policy also configures the dispatch watchdog
        (straggler deadlines from modeled wall x trailing median) and the
        quarantine windows.
      residency: the device-side operand residency cache
        (:class:`~repro.runtime.residency.ResidencyCache`).  ``None``
        (default) keeps the historical stage-every-flush behavior — every
        modeled price and every result is bit-identical to before.  Pass
        ``True`` to build a cache sized against ``mem_budget`` (residency
        and tile staging share the budget's spendable bytes), or a
        pre-built :class:`ResidencyCache` to share one across executors.
        With a cache attached, repeat flushes of unchanged operands skip
        host staging and are priced read-side-only
        (``batched_step_cost(resident_frames=...)``), sharded dispatch
        keeps per-device resident shard sets, and hit/miss/eviction
        counters land in telemetry (``residency_counts``) and the trace
        (``cache`` instants).
      tracer: optional :class:`~repro.runtime.tracing.Tracer`.  When set,
        every dispatch emits a boundary-attributed span tree (submit ->
        held -> release -> invocation -> stage -> compute ->
        fidelity-shadow, with per-device scatter children under sharded
        dispatch) plus counters/histograms in ``tracer.metrics``.  The
        default ``None`` is a measured no-op: instrumentation sites guard
        on the attribute and add no dispatch work.  For exact span
        durations in tests, give the tracer the same ``ManualClock`` as
        ``clock``.

    Use as a context manager to guarantee nothing leaks: ``__exit__``
    flushes queued *and* scheduler-held work, then drains the pipeline.
    """

    def __init__(self,
                 spec: OpticalFourierAcceleratorSpec |
                       OpticalMVMAcceleratorSpec = PROTOTYPE_4F,
                 *,
                 default_backend: str = "optical-sim",
                 telemetry: RuntimeTelemetry | None = None,
                 fidelity: FidelityChecker | None = None,
                 max_batch: int = 32,
                 pipeline_depth: int = 2,
                 n_devices: int = 1,
                 shard_mode: str = "auto",
                 mem_budget: MemoryBudget | None = None,
                 tile_k: int | None = None,
                 shared_window: bool = False,
                 clock: Callable[[], float] = time.perf_counter,
                 retry: RetryPolicy | None = None,
                 residency: "ResidencyCache | bool | None" = None,
                 tracer: Tracer | None = None) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if shard_mode not in ("auto", "group", "frame"):
            raise ValueError("shard_mode must be 'auto', 'group' or 'frame'")
        if tile_k is not None and tile_k < 1:
            raise ValueError("tile_k must be >= 1")
        if mem_budget is None:
            mem_budget = MemoryBudget.detect()
        self.ctx = BackendContext(spec=spec, pipeline_depth=pipeline_depth,
                                  n_devices=n_devices, shard_mode=shard_mode,
                                  mem_budget=mem_budget, tracer=tracer,
                                  clock=clock)
        self.tracer = tracer
        self.default_backend = default_backend
        self.telemetry = telemetry or RuntimeTelemetry()
        self.fidelity = fidelity
        self.retry = retry or RetryPolicy()
        self.quarantine = Quarantine(window_s=self.retry.quarantine_s,
                                     probation_s=self.retry.probation_s,
                                     patience=self.retry.straggler_patience)
        self._watchdog = DispatchWatchdog(
            factor=self.retry.straggler_factor,
            window=self.retry.straggler_window,
            floor_s=self.retry.straggler_floor_s,
            patience=self.retry.straggler_patience)
        # fault-handling collaborators travel with the dispatch context so
        # the sharded backend quarantines devices through the same policy
        self.ctx.quarantine = self.quarantine
        self.ctx.watchdog = self._watchdog
        self.ctx.telemetry = self.telemetry
        if residency is True:
            residency = ResidencyCache(mem_budget)
        elif residency is False:
            residency = None
        self.residency: ResidencyCache | None = residency
        self.ctx.residency = residency
        self.max_batch = max_batch
        self.pipeline_depth = pipeline_depth
        self.n_devices = n_devices
        self.mem_budget = mem_budget
        self.tile_k = tile_k
        self.shared_window = shared_window
        self._category_max_batch: dict[str, int] = {}
        self._category_n_devices: dict[str, int] = {}
        self._category_tile_k: dict[str, int] = {}
        self._category_window: dict[str, int] = {}
        self._clock = clock
        self._queue: list[_Pending] = []
        self._inflight: collections.deque[_Inflight] = collections.deque()
        self._last_retire_end = 0.0
        self._n_submitted = 0
        # tracer-clock end of the last charged compute span: leaf compute
        # spans start no earlier, so they never overlap within the device
        # lane (the same never-double-bill rule _retire's wall uses)
        self._trace_compute_end = 0.0
        self._backends: dict[str, ExecutionBackend] = {}
        # the admission-control policy driving release decisions, when one
        # is attached (repro.runtime.scheduler.OffloadScheduler); None means
        # the classic eager regime: every flush drains the queue
        self._scheduler = None

    @property
    def spec(self):
        return self.ctx.spec

    def now(self) -> float:
        """Current executor-clock time.  Quarantine windows, probation
        checks, and the router's quarantine-aware fan-out shrink all read
        this timebase, so the whole fault lifecycle replays exactly under
        an injected :class:`~repro.runtime.scheduler.ManualClock`."""
        return self._clock()

    # -- per-category batching ceilings ---------------------------------------
    def max_batch_for(self, category: str) -> int:
        """Effective coalescing ceiling for ``category`` (global cap applies)."""
        return min(self._category_max_batch.get(category, self.max_batch),
                   self.max_batch)

    def set_max_batch(self, category: str, k: int) -> None:
        """Set a per-category coalescing ceiling (the adaptive-batching hook
        ``PlanRouter.replan`` drives from observed occupancy + deadline)."""
        if k < 1:
            raise ValueError("max_batch must be >= 1")
        self._category_max_batch[category] = k

    def category_max_batches(self) -> Mapping[str, int]:
        return dict(self._category_max_batch)

    # -- per-category device fan-out -------------------------------------------
    def n_devices_for(self, category: str) -> int:
        """Effective sharded device count for ``category`` (global cap
        applies — the fleet has only ``n_devices`` accelerators)."""
        return min(self._category_n_devices.get(category, self.n_devices),
                   self.n_devices)

    def set_n_devices(self, category: str, n: int) -> None:
        """Set a per-category sharded device count (the adaptive hook
        ``PlanRouter.replan`` drives alongside ``set_max_batch``)."""
        if n < 1:
            raise ValueError("n_devices must be >= 1")
        self._category_n_devices[category] = n

    def category_n_devices(self) -> Mapping[str, int]:
        return dict(self._category_n_devices)

    # -- per-engine pipeline windows -------------------------------------------
    def pipeline_window_for(self, category: str) -> int:
        """Effective in-flight window depth for ``category``'s engine.  The
        global ``pipeline_depth`` is the default every unpinned category
        inherits — the back-compat alias: with no pins and
        ``shared_window=False`` a single-category workload behaves exactly
        like the historical global window."""
        return max(1, self._category_window.get(category,
                                                self.pipeline_depth))

    def set_pipeline_window(self, category: str, depth: int) -> None:
        """Set a per-category in-flight window depth (the adaptive hook
        ``PlanRouter.replan`` drives alongside ``set_max_batch`` /
        ``set_n_devices`` / ``set_tile_k``)."""
        if depth < 1:
            raise ValueError("pipeline window depth must be >= 1")
        self._category_window[category] = depth

    def category_windows(self) -> Mapping[str, int]:
        return dict(self._category_window)

    # -- per-category tile depth (memory-budgeted dispatch) --------------------
    def set_tile_k(self, category: str, t: int) -> None:
        """Pin ``category``'s frames-per-tile (the adaptive hook
        ``PlanRouter.replan`` drives alongside ``set_max_batch`` /
        ``set_n_devices``).  Overrides the budget-derived choice."""
        if t < 1:
            raise ValueError("tile_k must be >= 1")
        self._category_tile_k[category] = t

    def category_tile_ks(self) -> Mapping[str, int]:
        return dict(self._category_tile_k)

    def resolve_tile_k(self, category: str, x: jax.Array, depth: int, *,
                       weights: jax.Array | None = None) -> int:
        """Frames per sub-invocation for a ``depth``-deep released run of
        ``x``-shaped calls: the per-category pin, the global ``tile_k``
        override, or — when neither is set — :func:`choose_tile` against
        the memory budget.  This is the ONE resolution path; ``warm``,
        dispatch, and (via the same ``choose_tile``) the router's
        ``choose_sharding`` all go through it, so the stack shapes primed
        are exactly the stack shapes flushed and the planned tile is the
        dispatched tile.  The per-call output size enters the working-set
        model too — a matmul's result footprint is set by the weights'
        trailing dim, not the operand's."""
        t = self._category_tile_k.get(category, self.tile_k)
        if t is None:
            n_out = (int(x.shape[0]) * int(weights.shape[-1])
                     if category == "matmul" and weights is not None
                     else int(x.size))
            t = choose_tile(int(x.size), depth, self.effective_mem_budget(),
                            n_out=n_out,
                            dtype_bytes=max(1, x.dtype.itemsize),
                            pipeline_depth=self.pipeline_window_for(
                                category)).tile_k
        return max(1, min(int(t), depth))

    def effective_mem_budget(self) -> MemoryBudget:
        """The staging budget tiles are chosen against *right now*: the
        configured budget minus whatever the residency cache currently
        pins (resident stacks are live allocations in the same pool — see
        ``MemoryBudget.minus``).  With no cache this is exactly
        ``mem_budget``."""
        if self.residency is None:
            return self.mem_budget
        return self.residency.effective_budget(self.mem_budget)

    def _backend(self, name: str) -> ExecutionBackend:
        if name not in self._backends:
            self._backends[name] = get_backend(name)
        return self._backends[name]

    def _validate(self, category: str, backend: str | None,
                  kernel: jax.Array | None,
                  weights: jax.Array | None) -> str:
        name = backend or self.default_backend
        be = self._backend(name)
        if not be.supports(category, self.ctx):
            raise ValueError(
                f"backend {name!r} does not support category {category!r} "
                f"on spec {self.ctx.spec.name!r}")
        if category == "conv" and kernel is None:
            raise ValueError("conv requires kernel=")
        if category == "matmul" and weights is None:
            raise ValueError("matmul requires weights=")
        return name

    # -- lifetime --------------------------------------------------------------
    def attach_scheduler(self, scheduler) -> None:
        """Install the admission-control policy that decides when queued
        groups release (``OffloadScheduler`` calls this; ``None`` detaches
        and restores the eager drain-on-flush regime)."""
        self._scheduler = scheduler

    @property
    def scheduler(self):
        return self._scheduler

    def __enter__(self) -> "OffloadExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # Drain even when unwinding an exception: handles given out must
        # not be left forever-pending, and telemetry must balance.  When
        # the body raised, drain errors are swallowed so the body's
        # exception is never masked by cleanup.
        self.close(unwinding=exc_type is not None)
        return False

    def close(self, *, unwinding: bool = False) -> None:
        """Release every scheduler-held group and retire every in-flight
        invocation, letting no submitted frame drop silently — even when a
        release raises partway (the remaining groups still drain; the first
        error re-raises afterwards).  ``unwinding=True`` (the exception
        path of ``__exit__``) swallows drain errors instead so the caller's
        exception survives the cleanup."""
        first: BaseException | None = None
        for key in list(self.pending_groups()):
            try:
                self.release(key, reason="close")
            except BaseException as e:
                if first is None:
                    first = e
        while self._inflight:
            try:
                self._retire(self._inflight.popleft())
            except BaseException as e:
                if first is None:
                    first = e
        if first is not None and not unwinding:
            raise first

    # -- client API ------------------------------------------------------------
    def submit(self, category: str, x: jax.Array, *,
               kernel: jax.Array | None = None,
               weights: jax.Array | None = None,
               backend: str | None = None,
               reuse: str | None = None) -> OffloadResult:
        """Queue one call; returns a handle materialized at ``flush``.

        ``reuse`` names an explicit residency token: the caller promises
        that every submission under this token carries the same operand
        content, so after the first sighting the content digest is served
        from the token instead of re-hashing the array
        (:meth:`ResidencyCache.note_token`).  Purely an optimization over
        the automatic digest path — with no residency cache attached it is
        accepted and ignored.
        """
        name = self._validate(category, backend, kernel, weights)
        if reuse is not None and self.residency is not None:
            self.residency.note_token(reuse, x, self.ctx)
        result = OffloadResult(self)
        t = self._clock()
        self.telemetry.note_submit(category, t)
        self._n_submitted += 1
        if self.tracer is not None:
            self.tracer.instant("submit", lane="sched", category=category,
                                backend=name, call_id=self._n_submitted)
        self._queue.append(_Pending(category, x, kernel, weights, name,
                                    result, t_submit=t,
                                    call_id=self._n_submitted))
        return result

    def run(self, category: str, x: jax.Array, **kwargs) -> jax.Array:
        """Convenience: submit one call and flush immediately."""
        return self.submit(category, x, **kwargs).get()

    def warm(self, category: str, x: jax.Array, *,
             kernel: jax.Array | None = None,
             weights: jax.Array | None = None,
             backend: str | None = None,
             batch: int | None = None) -> None:
        """Execute once without recording: primes the per-shape jit/factor
        caches so first-call compilation time does not pollute measured
        profiles (call before ``telemetry.start()``).

        Batched execution compiles per *stacked* shape, so priming only the
        single-item shape would leave the first real flush paying the
        batched compile.  This warms the single-item ``(1, ...)`` stack
        plus every stack shape a ``batch``-deep release would actually
        dispatch (``batch`` defaults to the category's effective
        ``max_batch`` ceiling).  Under memory-budgeted tiling that is NOT
        one ``(batch, ...)`` stack: the release streams as
        ``tile_k``-sized sub-invocations (plus a ragged tail tile), and
        ``warm`` resolves ``tile_k`` through the same
        :meth:`resolve_tile_k` path dispatch uses — same budget, same
        per-category pins — so the first tiled flush pays no compile.  A
        ragged group tail (K % max_batch calls) still compiles on first
        encounter — call ``warm`` again with ``batch=tail`` when the tail
        size is known and the measurement window cannot tolerate it.

        Sharded dispatch shapes are primed too: the per-category device
        count is written into the context exactly as ``flush`` does it, so
        a sharded backend warms the same per-device shard stacks (and conv
        halo tiles) the first real sharded flush will dispatch, instead of
        whatever stale device count the context last held — without this,
        the first sharded flush is billed shard-shape compile time in
        telemetry.
        """
        name = self._validate(category, backend, kernel, weights)
        be = self._backend(name)
        if batch is None:
            batch = self.max_batch_for(category)
        if batch < 1:
            raise ValueError("batch must be >= 1")
        # the category fan-out and per-engine window depth are written for
        # shard-shape priming but must not leak into the shared context
        # after the warm call — a context consumer between warm and the
        # next dispatch would see one category's stale device count or
        # window depth (dispatch rewrites both, warm must restore them,
        # same as the tracer/watchdog below).  Writing the window here is
        # the per-engine edition of the warm-parity rule: the context's
        # pipeline depth feeds both the tile choice and the backends'
        # modeled price, so warm must prime the exact depth dispatch will
        # run this category at.
        saved_nd, self.ctx.n_devices = \
            self.ctx.n_devices, self.n_devices_for(category)
        saved_pd, self.ctx.pipeline_depth = \
            self.ctx.pipeline_depth, self.pipeline_window_for(category)
        tile = self.resolve_tile_k(category, x, batch, weights=weights)
        # warm-up runs are not workload: suppress backend-side tracing so
        # priming does not litter the trace with orphan device spans, the
        # straggler watchdog so first-call compile time can never strike
        # (let alone quarantine) a healthy device, and the residency cache
        # so priming stacks neither pollute the resident set nor skew the
        # hit-rate ledger the router replans from
        saved, self.ctx.tracer = self.ctx.tracer, None
        saved_wd, self.ctx.watchdog = self.ctx.watchdog, None
        saved_res, self.ctx.residency = self.ctx.residency, None
        try:
            for b in sorted({1} | set(tile_sizes(batch, tile))):
                outs, _ = be.run(category, [x] * b, self.ctx,
                                 kernel=kernel, weights=weights)
                _block(outs)
        finally:
            self.ctx.tracer = saved
            self.ctx.watchdog = saved_wd
            self.ctx.residency = saved_res
            self.ctx.n_devices = saved_nd
            self.ctx.pipeline_depth = saved_pd

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Dispatched batched invocations not yet retired (blocked+recorded)."""
        return len(self._inflight)

    # -- the pipelined batcher -------------------------------------------------
    def flush(self) -> list[OffloadResult]:
        """Execute everything queued and block until all results landed.

        The blocking wrapper around :meth:`flush_async` + :meth:`drain`:
        groups still overlap in flight while the flush proceeds, but by
        return time every result is concrete and recorded.
        """
        done = self.flush_async()
        self.drain()
        return done

    def pending_groups(self) -> dict[tuple, list[_Pending]]:
        """Queued submissions grouped exactly as dispatch would group them
        (category, backend, shape, dtype, operand identity), submission
        order preserved within each group.  This is the scheduler's view of
        the held queue — entries expose ``category`` and ``t_submit`` for
        admission decisions.  The mapping is a snapshot; mutate the queue
        only through :meth:`release` / :meth:`flush_async`."""
        groups: dict[tuple, list[_Pending]] = {}
        for p in self._queue:
            groups.setdefault(p.group_key(), []).append(p)
        return groups

    def release(self, key: tuple, count: int | None = None, *,
                reason: str = "flush") -> list[OffloadResult]:
        """Dispatch the first ``count`` queued members of group ``key``
        (all of them by default), leaving the rest *held* in the queue.

        This is the primitive the :class:`OffloadScheduler` drives:
        ``flush_async`` is simply "release every group whole".  Each
        released run of members dispatches as ceil(n / max_batch) batched
        chunks through the async pipeline — and each chunk, when its
        monolithic stack would overflow the memory budget, streams as
        ceil(chunk / tile_k) tiled sub-invocations (see
        :meth:`resolve_tile_k`) that double-buffer against each other.
        Hold time (dispatch minus oldest member's submit) is priced into
        each invocation when a scheduler is attached.

        ``reason`` records *why* the release happened in the trace (the
        scheduler passes its admission verdict: ``full`` / ``due`` /
        ``futile``; eager paths pass ``flush``).
        """
        members = [p for p in self._queue if p.group_key() == key]
        if count is not None:
            members = members[:count]
        if not members:
            return []
        chosen = set(map(id, members))
        self._queue = [p for p in self._queue if id(p) not in chosen]
        tr = self.tracer
        rel = None
        if tr is not None:
            rel = tr.begin("release", lane="sched", reason=reason,
                           category=members[0].category, count=len(members))
            tr.metrics.counter("release", reason=reason).inc()
        done: list[OffloadResult] = []
        cap = self.max_batch_for(members[0].category)
        for i in range(0, len(members), cap):
            chunk = members[i:i + cap]
            self._dispatch_async(chunk, reason=reason, parent=rel)
            done.extend(p.result for p in chunk)
        if rel is not None:
            tr.end(rel)
        return done

    def flush_async(self) -> list[OffloadResult]:
        """Execute everything queued without a final barrier.

        Requests group on (category, backend, shape, dtype, operand
        identity); each group dispatches as ceil(K / max_batch) batched
        invocations, preserving submission order within a group.  Each
        invocation is dispatched asynchronously and its results are filled
        immediately with async values (``ready`` is True, ``done()`` may
        not be); at most ``pipeline_depth`` invocations stay in flight, so
        dispatching invocation k+depth first retires invocation k (blocks
        it and records telemetry).  Invocations still in flight on return
        retire at the next flush, ``drain``, or ``result.wait()``.

        With a scheduler attached this is the *force-release* path (used by
        ``flush``, ``drain``, ``OffloadResult.get`` and the context-manager
        exit): held groups dispatch immediately, with their accumulated
        hold time priced in.  Scheduler-paced release goes through
        :meth:`release` via ``OffloadScheduler.poll`` instead.
        """
        done: list[OffloadResult] = []
        for key in list(self.pending_groups()):
            done.extend(self.release(key))
        return done

    def drain(self) -> None:
        """Retire every in-flight invocation (block + record telemetry).

        With a scheduler attached, scheduler-held groups release first —
        ``drain`` is the "nothing may remain pending" barrier, and a held
        group is pending work the barrier must cover.
        """
        if self._scheduler is not None and self._queue:
            self.flush_async()
        while self._inflight:
            self._retire(self._inflight.popleft())

    def _retire_containing(self, result: OffloadResult) -> None:
        """Retire in-flight invocations up to the one holding ``result``.

        Retirement is in dispatch order *within the result's engine window*
        (category, backend) — the per-engine DAG discipline: waiting on an
        fft result must not block-and-bill an unrelated conv engine's
        still-computing window.  ``shared_window=True`` restores the
        historical global dispatch-order drain."""
        target = next((g for g in self._inflight
                       if any(p.result is result for p in g.chunk)), None)
        if target is None:
            return
        while self._inflight:
            if self.shared_window:
                g = self._inflight.popleft()
            else:
                g = next((g for g in self._inflight
                          if g.wkey == target.wkey), None)
                if g is None:
                    return
                self._inflight.remove(g)
            self._retire(g)
            if g is target:
                return

    def _retire_matching(self, wkey: tuple) -> None:
        """Retire the oldest in-flight invocation of engine ``wkey`` — the
        per-engine window gate's eviction: a full fft window retires fft's
        oldest group, never a conv group that happens to be globally
        older.  Dispatch order is preserved per engine (the deque is
        scanned front to back)."""
        for i, g in enumerate(self._inflight):
            if g.wkey == wkey:
                del self._inflight[i]
                self._retire(g)
                return

    def _dispatch_async(self, chunk: list[_Pending], *,
                        reason: str = "flush",
                        parent: Span | None = None) -> None:
        """Dispatch one released chunk, tiled against the memory budget.

        A chunk whose monolithic ``(K, H, W)`` stack fits the staging
        budget dispatches whole (one batched invocation, the classic
        path).  A chunk that would overflow it streams as
        ``ceil(K / tile_k)`` sub-invocations instead — each a full batched
        invocation of its own (stacked operands, one backend dispatch,
        optionally sharded across devices) fed through the SAME two-deep
        async pipeline, so tile t+1's host-side staging and DAC-prep
        overlap tile t's in-flight analog+read compute.  ``tile_k = 1``
        degenerates to the looped regime, ``tile_k >= K`` to the
        monolithic one.
        """
        head = chunk[0]
        tile = self.resolve_tile_k(head.category, head.x, len(chunk),
                                   weights=head.weights)
        start = 0
        sizes = tile_sizes(len(chunk), tile)
        # Device-resident sharded dispatch: commit ONE sharded placement
        # for the whole released chunk before tiling, so every tile's
        # sub-stack routes through the same resident shards instead of
        # re-scattering per tile (and repeat flushes of unchanged frames
        # skip the host->device hop entirely).  Duck-typed: only backends
        # that shard (and only with a residency cache attached) have the
        # hook; without it dispatch is bit-identical to before.
        commit = getattr(self._backend(head.backend),
                         "commit_placement", None)
        if commit is not None and self.ctx.residency is not None:
            self.ctx.n_devices = self.n_devices_for(head.category)
            commit(head.category, [p.x for p in chunk], self.ctx,
                   kernel=head.kernel, weights=head.weights,
                   tile_sizes=sizes)
        for t, size in enumerate(sizes):
            self._dispatch_invocation(chunk[start:start + size],
                                      reason=reason, parent=parent,
                                      tile=t, tiles=len(sizes))
            start += size

    def _reroute_quarantined(self, category: str,
                             be: ExecutionBackend) -> ExecutionBackend:
        """The quarantine fast-path: while ``(category,)``'s backend is
        quarantined (retry exhaustion / fidelity drift), dispatches go
        straight to the fallback instead of re-paying the retry ladder.
        After the window expires, dispatch returns to the original backend
        on probation — re-offending there doubles the next window."""
        policy = self.retry
        if be.name == policy.fallback:
            return be
        if not self.quarantine.is_quarantined(("category", category),
                                              self._clock()):
            return be
        fb = self._backend(policy.fallback)
        if not fb.supports(category, self.ctx):
            return be
        self.telemetry.note_fault(category, "reroute")
        if self.tracer is not None:
            self.tracer.instant("fallback", lane="sched", category=category,
                                backend=be.name, to=fb.name,
                                reason="quarantined")
            self.tracer.metrics.counter("reroutes", category=category).inc()
        return fb

    def _run_guarded(self, be: ExecutionBackend, head: _Pending,
                     xs: list, *, parent: Span | None = None):
        """One batched invocation under the retry policy.

        Returns ``(outs, modeled, served_backend)``.  A dispatch raising
        :class:`FaultError` retries on the same backend with exponential
        jittered backoff (slept through the injected clock); exhausting
        ``max_attempts`` degrades to the fallback backend — which always
        returns correct results, preserving the runtime-equivalence
        invariant — and quarantines the category.  Successful dispatch
        walls feed the straggler watchdog: a wall past ``factor x
        max(trailing median, modeled wall, floor)`` is counted and traced
        as a straggle fault (detection only at this level — device-level
        quarantine lives in the sharded backend, category quarantine in
        the exhaustion/drift paths, so a noisy host clock can never
        quarantine a healthy backend).
        """
        tr = self.tracer
        cat = head.category
        policy = self.retry
        t_first_fault: float | None = None
        for attempt in range(1, policy.max_attempts + 1):
            t0 = self._clock()
            try:
                outs, modeled = be.run(cat, xs, self.ctx,
                                       kernel=head.kernel,
                                       weights=head.weights)
            except FaultError as e:
                if t_first_fault is None:
                    t_first_fault = t0
                self.telemetry.note_fault(cat, e.kind)
                if tr is not None:
                    tr.instant("fault", lane="sched", parent=parent,
                               category=cat, backend=be.name, kind=e.kind,
                               attempt=attempt)
                    tr.metrics.counter("faults", category=cat,
                                       kind=e.kind).inc()
                if attempt >= policy.max_attempts:
                    break
                backoff = policy.backoff_for(attempt)
                rt0 = tr.now() if tr is not None else 0.0
                advance_or_sleep(self._clock, backoff)
                if tr is not None:
                    tr.record("retry", rt0, tr.now(), lane="sched",
                              kind="async", parent=parent, category=cat,
                              backend=be.name, attempt=attempt,
                              backoff_s=backoff)
                    tr.metrics.counter("retries", category=cat,
                                       backend=be.name).inc()
                continue
            elapsed = self._clock() - t0
            base = modeled.total_s if modeled is not None else None
            if self._watchdog.observe((cat, be.name), elapsed, base):
                self.telemetry.note_fault(cat, "straggle")
                if tr is not None:
                    tr.instant("fault", lane="sched", parent=parent,
                               category=cat, backend=be.name,
                               kind="straggle", elapsed_s=elapsed)
                    tr.metrics.counter("faults", category=cat,
                                       kind="straggle").inc()
            else:
                self.quarantine.note_healthy(("category", cat))
            if t_first_fault is not None:
                dt = self._clock() - t_first_fault
                self.telemetry.note_recovery(cat, dt)
                if tr is not None:
                    tr.metrics.histogram("recovery_s",
                                         category=cat).record(dt)
            return outs, modeled, be
        # every attempt faulted: graceful degradation — the fallback is
        # always correct, so the caller still gets its results in order
        fb = self._backend(policy.fallback)
        ev = self.quarantine.quarantine(("category", cat), self._clock(),
                                        reason="retry-exhausted")
        self.telemetry.note_fault(cat, "fallback")
        if tr is not None:
            tr.instant("fallback", lane="sched", parent=parent,
                       category=cat, backend=be.name, to=fb.name,
                       reason="retry-exhausted")
            q0 = tr.now()
            tr.record("quarantine", q0, q0 + (ev.until - ev.t), lane="sched",
                      kind="async", parent=parent, key=str(ev.key),
                      reason=ev.reason, level=ev.level)
            tr.metrics.counter("fallbacks", category=cat,
                               backend=be.name).inc()
            tr.metrics.counter("quarantines", reason=ev.reason).inc()
        outs, modeled = fb.run(cat, xs, self.ctx, kernel=head.kernel,
                               weights=head.weights)
        if t_first_fault is not None:
            dt = self._clock() - t_first_fault
            self.telemetry.note_recovery(cat, dt)
            if tr is not None:
                tr.metrics.histogram("recovery_s", category=cat).record(dt)
        return outs, modeled, fb

    def _dispatch_invocation(self, chunk: list[_Pending], *,
                             reason: str = "flush",
                             parent: Span | None = None,
                             tile: int = 0, tiles: int = 1) -> None:
        # Keep at most one *window* of invocations in flight per engine:
        # retiring here is what makes each engine's pipeline window-deep
        # rather than unbounded (frame buffers are finite), and it blocks
        # on that engine's *oldest* invocation while this chunk's host-side
        # staging below overlaps it.  Engines gate independently — a full
        # fft window never forces a conv retirement (shared_window=True
        # restores the historical single global window).
        head = chunk[0]
        wkey = (head.category, head.backend)
        if self.shared_window:
            depth = self.pipeline_depth
            while len(self._inflight) >= depth:
                self._retire(self._inflight.popleft())
        else:
            depth = self.pipeline_window_for(head.category)
            while sum(1 for g in self._inflight if g.wkey == wkey) >= depth:
                self._retire_matching(wkey)
        occupancy = 1 + sum(1 for g in self._inflight if g.wkey == wkey)
        self.telemetry.note_window(head.category, head.backend,
                                   in_flight=occupancy, depth=depth)
        be = self._reroute_quarantined(head.category,
                                       self._backend(head.backend))
        xs = [p.x for p in chunk]
        # per-category device fan-out and window depth, written the same
        # way warm() writes them (the context's depth feeds the backends'
        # modeled pipeline collapse)
        self.ctx.n_devices = self.n_devices_for(head.category)
        self.ctx.pipeline_depth = depth
        # Queueing delay under admission control: age of the oldest
        # coalesced call at dispatch.  Only priced when a scheduler is in
        # charge — eager flushes dispatch at submit granularity and their
        # sub-microsecond queue residence would just add noise to the
        # deterministic modeled columns benchmarks assert on.
        hold_s = (self._clock() - min(p.t_submit for p in chunk)
                  if self._scheduler is not None else 0.0)
        tr = self.tracer
        inv = None
        t_stage_end = 0.0
        if tr is not None:
            inv = tr.begin("invocation", lane="host", parent=parent,
                           category=head.category, backend=head.backend,
                           batch=len(chunk), tile=tile, tiles=tiles,
                           reason=reason,
                           call_ids=[p.call_id for p in chunk],
                           window_depth=depth,
                           window_occupancy=occupancy)
            if hold_s > 0.0:
                # retrospective: the hold window ended now, at dispatch
                t_now = tr.now()
                tr.record("held", max(t_now - hold_s, 0.0), t_now,
                          lane="sched", kind="async", parent=inv,
                          reason=reason, category=head.category,
                          hold_s=hold_s)
            tr.metrics.counter("invocations", category=head.category,
                               backend=head.backend).inc()
        t0 = time.perf_counter()
        if tr is not None:
            # lexical: backend-side spans (sharded per-device scatter /
            # gather) nest under the stage span via the tracer's stack
            with tr.span("stage", lane="host", parent=inv,
                         batch=len(chunk), tile=tile):
                outs, modeled, be = self._run_guarded(be, head, xs,
                                                      parent=inv)
            t_stage_end = tr.now()
        else:
            outs, modeled, be = self._run_guarded(be, head, xs)
        dispatch_s = time.perf_counter() - t0
        if inv is not None and be.name != head.backend:
            # graceful degradation happened: record who actually served it
            inv.annotate(served_backend=be.name)
        take = getattr(be, "take_device_samples", None)
        device_samples = take() if take is not None else None
        batch = len(chunk)
        if modeled is not None and hold_s > 0.0:
            # the modeled wall honestly prices the time this group spent
            # held open accumulating occupancy (StepCost.hold_s)
            modeled = dataclasses.replace(
                modeled, hold_s=modeled.hold_s + hold_s)
        if inv is not None and modeled is not None:
            # the decomposition the drift report joins measured spans
            # against — the exact batched_step_cost the planner priced
            inv.annotate(modeled_dac_s=modeled.dac_s,
                         modeled_adc_s=modeled.adc_s,
                         modeled_interface_s=modeled.interface_s,
                         modeled_analog_s=modeled.analog_s,
                         modeled_host_s=modeled.host_s,
                         modeled_hold_s=modeled.hold_s,
                         modeled_total_s=modeled.total_s)
        # host-like backends have no modeled price: provisional cost is the
        # staging+dispatch wall share (refined to the full measured wall at
        # retire), so ``cost`` honors the 'valid once ready' contract even
        # between flush_async and drain
        share = modeled.scaled(1.0 / batch) if modeled is not None \
            else StepCost(0.0, 0.0, 0.0, 0.0, host_s=dispatch_s / batch,
                          hold_s=hold_s / batch)
        for p, out in zip(chunk, outs):
            # async fill: the value is dispatched, not yet materialized
            p.result._fill(out, share, be.name, batch, None)
        shadow = (self.fidelity is not None and _shadow_worthy(be)
                  and self.fidelity.should_check(head.category))
        inflight = _Inflight(chunk=chunk, be=be, outs=outs,
                             modeled=modeled, t0=t0, dispatch_s=dispatch_s,
                             device_samples=device_samples, shadow=shadow,
                             hold_s=hold_s, span=inv,
                             t_stage_end=t_stage_end, wkey=wkey)
        if shadow:
            # shadow scoring needs concrete values: validation mode is
            # synchronous by construction (batches the sample_every knob
            # skips keep the async pipeline)
            self._retire(inflight)
        else:
            self._inflight.append(inflight)

    def _retire(self, f: _Inflight) -> None:
        already_done = _is_ready(f.outs)
        _block(f.outs)
        now = time.perf_counter()
        if already_done:
            # deferred retirement: the computation finished while the
            # caller did unrelated host work between flush_async and
            # wait()/drain().  Wall-clock would bill that idle time to the
            # invocation (and poison the profiles replan derives); charge
            # only the host-side staging+dispatch window we observed.
            wall = f.dispatch_s
        else:
            # overlapped invocations must not double-count shared wall
            # time: charge only from where the previous retirement ended
            wall = now - max(f.t0, self._last_retire_end)
        self._last_retire_end = now
        batch = len(f.chunk)
        samples_in = sum(int(p.x.size) for p in f.chunk)
        samples_out = sum(int(o.size) for o in f.outs)
        bytes_in = sum(int(getattr(p.x, "nbytes", p.x.size * 4))
                       for p in f.chunk)
        bytes_out = sum(int(getattr(o, "nbytes", o.size * 4))
                        for o in f.outs)
        self.telemetry.record(
            f.chunk[0].category, f.be.name, calls=batch,
            samples_in=samples_in, samples_out=samples_out, wall_s=wall,
            modeled=f.modeled, per_device=f.device_samples,
            bytes_in=bytes_in, bytes_out=bytes_out)
        tr = self.tracer
        compute_end = 0.0
        if tr is not None and f.span is not None:
            # Charged decomposition: stage takes the host staging+dispatch
            # share of the charged wall, compute the in-flight remainder —
            # so stage + compute == wall exactly, pipeline overlap is never
            # billed twice, and per-stage sums reconcile with the flush's
            # measured wall (the export/drift contract).  Deferred
            # retirement (wall == dispatch_s) yields a zero-length compute
            # span: the device window elapsed under someone else's clock.
            stage_charged = min(f.dispatch_s, wall)
            compute_charged = max(wall - stage_charged, 0.0)
            c0 = max(f.t_stage_end, self._trace_compute_end)
            compute_end = c0 + compute_charged
            tr.record("compute", c0, compute_end, lane="device",
                      parent=f.span, backend=f.be.name,
                      charged_s=compute_charged, deferred=already_done)
            self._trace_compute_end = compute_end
            f.span.annotate(wall_s=wall, stage_s=stage_charged,
                            compute_s=compute_charged, hold_s=f.hold_s,
                            shadow_s=0.0, deferred=already_done)
            tr.metrics.histogram(
                "invocation_wall_s", category=f.chunk[0].category,
                backend=f.be.name).record(wall)
        report = None
        if f.shadow:
            t1 = time.perf_counter()
            sh = None
            if tr is not None and f.span is not None:
                sh = tr.begin("fidelity-shadow", lane="host", kind="sync",
                              parent=f.span, category=f.chunk[0].category)
            # the shadow reference is a validation probe, not workload:
            # it must neither serve from nor populate the residency cache,
            # or shadow traffic would inflate hit rates and evict operands
            # the real dispatch path still needs
            saved_res, self.ctx.residency = self.ctx.residency, None
            try:
                refs, _ = self._backend("host").run(
                    f.chunk[0].category, [p.x for p in f.chunk], self.ctx,
                    kernel=f.chunk[0].kernel, weights=f.chunk[0].weights)
                _block(refs)
            finally:
                self.ctx.residency = saved_res
            spec = self.ctx.spec
            enob = min(spec.dac.effective_bits, spec.adc.effective_bits)
            report = self.fidelity.check(f.chunk[0].category, f.be.name,
                                         f.outs, refs, enob=enob)
            # validation overhead, not workload: keep it out of 'other'
            dt = time.perf_counter() - t1
            if sh is not None:
                tr.end(sh)
                f.span.annotate(shadow_s=dt)
            self.telemetry.discount_window(dt)
            self._last_retire_end += dt
            cat = f.chunk[0].category
            if not report.ok and f.be.name != self.retry.fallback:
                # ENOB-drift violation (a mis-ranged DAC, a drifted
                # detector): the shadow refs are already paid for, so the
                # batch is CORRECTED from them — every caller still gets
                # host-equal results — and the category is quarantined
                # through the same path retry exhaustion uses, so the
                # router's next replan and the reroute fast-path both shrink
                # around the drifting backend until probation clears it.
                ev = self.quarantine.quarantine(("category", cat),
                                                self._clock(),
                                                reason="fidelity-drift")
                self.telemetry.note_fault(cat, "drift")
                self.telemetry.note_recovery(cat, dt)
                for p, ref in zip(f.chunk, refs):
                    p.result.value = ref
                    p.result.backend = self.retry.fallback
                if tr is not None and f.span is not None:
                    tr.instant("fault", lane="sched", parent=f.span,
                               category=cat, backend=f.be.name,
                               kind="drift", rel_err=report.rel_err,
                               bound=report.bound)
                    tr.instant("fallback", lane="sched", parent=f.span,
                               category=cat, backend=f.be.name,
                               to=self.retry.fallback, reason="drift")
                    q0 = tr.now()
                    tr.record("quarantine", q0, q0 + (ev.until - ev.t),
                              lane="sched", kind="async", parent=f.span,
                              key=str(ev.key), reason=ev.reason,
                              level=ev.level)
                    tr.metrics.counter("faults", category=cat,
                                       kind="drift").inc()
                    tr.metrics.counter("quarantines",
                                       reason=ev.reason).inc()
                    tr.metrics.histogram("recovery_s",
                                         category=cat).record(dt)
        if f.modeled is None:
            # refine the provisional dispatch-only share to the measured
            # wall (the hold share survives the refinement: queueing delay
            # is real whichever backend served the release)
            measured = StepCost(0.0, 0.0, 0.0, 0.0, host_s=wall / batch,
                                hold_s=f.hold_s / batch)
            for p in f.chunk:
                p.result.cost = measured
        if report is not None:
            for p in f.chunk:
                p.result.fidelity = report
        if tr is not None and f.span is not None:
            # the invocation container closes at retirement, covering its
            # children (the charged compute window may extend past now
            # when clocks mix — containment wins)
            tr.end(f.span, max(tr.now(), compute_end))
