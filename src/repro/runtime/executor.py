"""The offload executor: turns ``OffloadPlan`` decisions into execution.

Callers ``submit`` accelerable ops (fft / conv / matmul) and the executor
coalesces queued calls of the same shape into one accelerator invocation at
``flush`` time.  That is the paper's §6 batching lever made operational:
per-invocation boundary costs (link handshake latency, SLM settle/exposure,
converter-lane ceil residue) amortize across the batch, so the modeled
per-call conversion + interface time *drops* as the queue deepens, while
results stay bit-identical to unbatched execution (items run one by one
through per-shape jit caches; only the boundary accounting is shared).

Execution is recorded into :class:`RuntimeTelemetry` — call counts, sample
counts, wall time, modeled cost — so ``telemetry.profiles()`` can re-enter
``plan_offload`` and the plan can be re-derived from observed traffic.
Optionally every optical-sim batch is shadowed by the host backend and
scored by a :class:`FidelityChecker`, pairing each speedup with its
quantization-error cost.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax

from repro.core.accelerator import (
    PROTOTYPE_4F,
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
    StepCost,
)
from repro.runtime.backends import (
    BackendContext,
    ExecutionBackend,
    get_backend,
)
from repro.runtime.fidelity import FidelityChecker, FidelityReport
from repro.runtime.telemetry import RuntimeTelemetry

__all__ = ["OffloadResult", "OffloadExecutor"]


def _block(x: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


class OffloadResult:
    """Handle for a submitted call; materializes at ``flush``.

    Attributes (valid once ``ready``):
      value: the op result.
      cost: modeled per-call share of the invocation's :class:`StepCost`.
      backend: backend name that served the call.
      batch: how many calls shared the invocation.
      fidelity: the batch's :class:`FidelityReport` (when checking is on).
    """

    def __init__(self, executor: "OffloadExecutor") -> None:
        self._executor = executor
        self.ready = False
        self.value: jax.Array | None = None
        self.cost: StepCost | None = None
        self.backend: str | None = None
        self.batch: int = 0
        self.fidelity: FidelityReport | None = None

    def get(self) -> jax.Array:
        if not self.ready:
            self._executor.flush()
        return self.value

    def _fill(self, value: jax.Array, cost: StepCost, backend: str,
              batch: int, fidelity: FidelityReport | None) -> None:
        self.value = value
        self.cost = cost
        self.backend = backend
        self.batch = batch
        self.fidelity = fidelity
        self.ready = True


@dataclasses.dataclass
class _Pending:
    category: str
    x: jax.Array
    kernel: jax.Array | None
    weights: jax.Array | None
    backend: str
    result: OffloadResult

    def group_key(self) -> tuple:
        return (self.category, self.backend, tuple(self.x.shape),
                str(self.x.dtype), id(self.kernel), id(self.weights))


class OffloadExecutor:
    """Queue + batcher + cache in front of the backend registry.

    Args:
      spec: accelerator priced/simulated by the analog backends.
      default_backend: where submits go when the caller (or router) does
        not name one.
      telemetry: shared :class:`RuntimeTelemetry` (created if omitted).
      fidelity: optional :class:`FidelityChecker`; when set, optical-sim
        batches are shadowed by the host backend and scored (validation
        mode — the shadow run is excluded from telemetry).
      max_batch: largest number of calls coalesced into one invocation.
    """

    def __init__(self,
                 spec: OpticalFourierAcceleratorSpec |
                       OpticalMVMAcceleratorSpec = PROTOTYPE_4F,
                 *,
                 default_backend: str = "optical-sim",
                 telemetry: RuntimeTelemetry | None = None,
                 fidelity: FidelityChecker | None = None,
                 max_batch: int = 32) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.ctx = BackendContext(spec=spec)
        self.default_backend = default_backend
        self.telemetry = telemetry or RuntimeTelemetry()
        self.fidelity = fidelity
        self.max_batch = max_batch
        self._queue: list[_Pending] = []
        self._backends: dict[str, ExecutionBackend] = {}

    @property
    def spec(self):
        return self.ctx.spec

    def _backend(self, name: str) -> ExecutionBackend:
        if name not in self._backends:
            self._backends[name] = get_backend(name)
        return self._backends[name]

    def _validate(self, category: str, backend: str | None,
                  kernel: jax.Array | None,
                  weights: jax.Array | None) -> str:
        name = backend or self.default_backend
        be = self._backend(name)
        if not be.supports(category, self.ctx):
            raise ValueError(
                f"backend {name!r} does not support category {category!r} "
                f"on spec {self.ctx.spec.name!r}")
        if category == "conv" and kernel is None:
            raise ValueError("conv requires kernel=")
        if category == "matmul" and weights is None:
            raise ValueError("matmul requires weights=")
        return name

    # -- client API ------------------------------------------------------------
    def submit(self, category: str, x: jax.Array, *,
               kernel: jax.Array | None = None,
               weights: jax.Array | None = None,
               backend: str | None = None) -> OffloadResult:
        """Queue one call; returns a handle materialized at ``flush``."""
        name = self._validate(category, backend, kernel, weights)
        result = OffloadResult(self)
        self._queue.append(_Pending(category, x, kernel, weights, name, result))
        return result

    def run(self, category: str, x: jax.Array, **kwargs) -> jax.Array:
        """Convenience: submit one call and flush immediately."""
        return self.submit(category, x, **kwargs).get()

    def warm(self, category: str, x: jax.Array, *,
             kernel: jax.Array | None = None,
             weights: jax.Array | None = None,
             backend: str | None = None) -> None:
        """Execute once without recording: primes the per-shape jit/factor
        caches so first-call compilation time does not pollute measured
        profiles (call before ``telemetry.start()``)."""
        name = self._validate(category, backend, kernel, weights)
        outs, _ = self._backend(name).run(category, [x], self.ctx,
                                          kernel=kernel, weights=weights)
        _block(outs)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- the batcher -----------------------------------------------------------
    def flush(self) -> list[OffloadResult]:
        """Execute everything queued, coalescing same-shape calls.

        Requests group on (category, backend, shape, dtype, operand
        identity); each group dispatches as ceil(K / max_batch) batched
        invocations, preserving submission order within a group.
        """
        queue, self._queue = self._queue, []
        groups: dict[tuple, list[_Pending]] = {}
        for p in queue:
            groups.setdefault(p.group_key(), []).append(p)
        done: list[OffloadResult] = []
        for members in groups.values():
            for i in range(0, len(members), self.max_batch):
                chunk = members[i:i + self.max_batch]
                self._dispatch(chunk)
                done.extend(p.result for p in chunk)
        return done

    def _dispatch(self, chunk: list[_Pending]) -> None:
        head = chunk[0]
        be = self._backend(head.backend)
        xs = [p.x for p in chunk]
        t0 = time.perf_counter()
        outs, modeled = be.run(head.category, xs, self.ctx,
                               kernel=head.kernel, weights=head.weights)
        _block(outs)
        wall = time.perf_counter() - t0
        batch = len(chunk)
        samples_in = sum(int(p.x.size) for p in chunk)
        samples_out = sum(int(o.size) for o in outs)
        self.telemetry.record(
            head.category, be.name, calls=batch, samples_in=samples_in,
            samples_out=samples_out, wall_s=wall, modeled=modeled)
        report = None
        if self.fidelity is not None and be.name == "optical-sim":
            t1 = time.perf_counter()
            refs, _ = self._backend("host").run(
                head.category, xs, self.ctx,
                kernel=head.kernel, weights=head.weights)
            _block(refs)
            spec = self.ctx.spec
            enob = min(spec.dac.effective_bits, spec.adc.effective_bits)
            report = self.fidelity.check(head.category, be.name, outs, refs,
                                         enob=enob)
            # validation overhead, not workload: keep it out of 'other'
            self.telemetry.discount_window(time.perf_counter() - t1)
        share = modeled.scaled(1.0 / batch) if modeled is not None \
            else StepCost(0.0, 0.0, 0.0, 0.0, host_s=wall / batch)
        for p, out in zip(chunk, outs):
            p.result._fill(out, share, be.name, batch, report)
