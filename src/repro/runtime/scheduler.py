"""Admission-controlled continuous batching: who decides when a boundary
crossing happens.

The paper's occupancy argument (and the serving literature's continuous-
batching one) says the conversion boundary only amortizes when every
crossing carries a full batch — but the pre-scheduler runtime drained the
whole queue on every flush, so trickle traffic crossed the boundary one
frame at a time and paid the full per-invocation handshake, settle, and
lane-ceil residue each time.  :class:`OffloadScheduler` closes that gap by
owning the *admission and release* decisions the executor used to make
implicitly:

* submissions accumulate in the executor's queue as usual, but a partially
  filled group may be **held open across flushes** — the scheduler releases
  it only when one of three things is true:

  (a) **full**: the group reached its ``max_batch`` ceiling — waiting
      cannot raise occupancy further, dispatch the full chunks now;
  (b) **due**: the oldest held call's age reached the group's deadline —
      the latency budget is spent, dispatch whatever occupancy was won;
  (c) **futile**: the telemetry-estimated arrival rate
      (:meth:`RuntimeTelemetry.arrival_rate`, from submit timestamps) says
      the *next* arrival is expected after the deadline — holding longer
      buys latency without buying occupancy, so dispatch immediately.

  Until two arrivals have been observed there is no rate estimate and the
  scheduler holds optimistically (rule (b) still bounds the wait).

* released groups dispatch through the executor's existing mechanisms —
  :meth:`OffloadExecutor.release` feeds the same batched, double-buffered,
  optionally sharded pipeline — and the time a group spent held is priced
  into its invocation (``StepCost.hold_s``), so the modeled wall honestly
  charges the queueing delay that bought the occupancy.  At low arrival
  rates this is exactly the regime that feeds the sharded fleet: a held
  group deep enough to scatter across ``n_devices`` apertures, where
  drain-on-flush would have sent ``n`` lonely frames through one device's
  converters serially.

The executor's ``flush``/``flush_async``/``drain``/``get`` remain the
force-release path (they dispatch held work immediately); the scheduler is
the *pacing* path — call :meth:`poll` from an event loop, a serving
engine's decode step, or after each submit (``submit`` polls for you).

Deterministic by construction: every time read goes through the injected
``clock``, so tests and benchmarks drive admission with a
:class:`ManualClock` instead of sleeping.
"""

from __future__ import annotations

import math
from typing import Callable, Mapping

import jax

from repro.runtime.executor import OffloadExecutor, OffloadResult

__all__ = ["ManualClock", "OffloadScheduler"]


class ManualClock:
    """A callable clock tests and benchmarks advance by hand, so admission
    decisions (ages, arrival rates, deadlines) are deterministic instead of
    wall-clock-raced."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = float(t)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time does not run backwards")
        self.t += dt
        return self.t

    def __call__(self) -> float:
        return self.t


class OffloadScheduler:
    """Arrival-aware admission control over an :class:`OffloadExecutor`.

    Args:
      target: the executor to pace — or a ``PlanRouter`` (anything with an
        ``.executor`` and a routing ``submit``); submissions then follow
        the router's category->backend table while the scheduler paces
        their release.
      deadline_s: default per-category queueing-delay budget: no call is
        held longer than this before its group dispatches.
      deadlines: optional ``{category: deadline_s}`` overrides.
      clock: timebase for admission decisions; defaults to the executor's
        own clock so submit timestamps and poll times agree.

    The scheduler registers itself with the executor
    (``attach_scheduler``), which flips the executor into held-queue
    semantics: ``drain`` releases held groups, dispatch prices hold time,
    and eager ``flush`` becomes the force-release escape hatch.
    """

    def __init__(self, target, *,
                 deadline_s: float = 0.05,
                 deadlines: Mapping[str, float] | None = None,
                 clock: Callable[[], float] | None = None) -> None:
        if deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        self.executor: OffloadExecutor = getattr(target, "executor", target)
        self._submitter = target
        self.deadline_s = deadline_s
        self._deadlines = dict(deadlines or {})
        self._clock = clock or self.executor._clock
        self.executor.attach_scheduler(self)

    # -- configuration ---------------------------------------------------------
    def deadline_for(self, category: str) -> float:
        return self._deadlines.get(category, self.deadline_s)

    def set_deadline(self, category: str, deadline_s: float) -> None:
        if deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        self._deadlines[category] = deadline_s

    # -- the client API --------------------------------------------------------
    def submit(self, category: str, x: jax.Array, **kwargs) -> OffloadResult:
        """Queue one call (through the router's table when one was given)
        and run an admission pass: a group that just hit ``max_batch``
        dispatches on the spot — continuous batching without an external
        pump."""
        result = self._submitter.submit(category, x, **kwargs)
        self.poll()
        return result

    def poll(self, now: float | None = None) -> list[OffloadResult]:
        """One admission pass over the held queue: release every group that
        is full, due, or futile to keep holding (see the module docstring
        for the three rules); hold the rest.  Returns the handles released
        by this pass (already dispatched through the async pipeline)."""
        if now is None:
            now = self._clock()
        telemetry = self.executor.telemetry
        released: list[OffloadResult] = []
        for key, members in self.executor.pending_groups().items():
            category = members[0].category
            cap = self.executor.max_batch_for(category)
            # (a) full: dispatch complete chunks, keep the tail held
            full = (len(members) // cap) * cap
            if full:
                released.extend(self.executor.release(key, full,
                                                      reason="full"))
                members = members[full:]
                if not members:
                    continue
            deadline = self.deadline_for(category)
            age = now - members[0].t_submit
            rate = telemetry.arrival_rate(category)
            due = age >= deadline
            # (c) expected next arrival lands past the deadline: holding
            # buys latency but no occupancy (rate inf => next arrival is
            # immediate => keep holding; rate 0 => no estimate yet =>
            # hold until the deadline decides)
            futile = (0.0 < rate < math.inf) and (age + 1.0 / rate > deadline)
            if due or futile:
                released.extend(self.executor.release(
                    key, reason="due" if due else "futile"))
        return released

    def release_all(self) -> list[OffloadResult]:
        """Force-release every held group (deadline and rate ignored)."""
        return self.executor.flush_async()

    def flush(self) -> list[OffloadResult]:
        """Force-release everything and drain the pipeline (blocking) —
        the scheduler-aware equivalent of ``executor.flush()``."""
        return self.executor.flush()

    def drain(self) -> None:
        """Release held groups and retire all in-flight invocations."""
        self.executor.drain()

    # -- introspection ---------------------------------------------------------
    @property
    def pending(self) -> int:
        """Queued calls, held or not (the serving engine's aux gauge)."""
        return self.executor.pending

    @property
    def held(self) -> int:
        """Calls currently held awaiting admission (== queued calls: with a
        scheduler attached the queue *is* the hold buffer)."""
        return self.executor.pending

    def held_groups(self) -> list[dict]:
        """Diagnostics: one row per held group — category, depth, oldest
        age, the deadline it is counting down, and the current arrival-rate
        estimate feeding rule (c)."""
        now = self._clock()
        telemetry = self.executor.telemetry
        rows = []
        for members in self.executor.pending_groups().values():
            category = members[0].category
            rows.append({
                "category": category,
                "held": len(members),
                "max_batch": self.executor.max_batch_for(category),
                "oldest_age_s": now - members[0].t_submit,
                "deadline_s": self.deadline_for(category),
                "arrival_rate_hz": telemetry.arrival_rate(category),
            })
        return rows

    def __enter__(self) -> "OffloadScheduler":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # held + in-flight groups drain even when the body raised (and a
        # drain error never masks the body's exception)
        self.executor.close(unwinding=exc_type is not None)
        return False

    def summary(self) -> str:
        rows = [f"scheduler: deadline={self.deadline_s * 1e3:.1f}ms "
                f"held={self.held}"]
        for g in self.held_groups():
            rows.append(
                f"  {g['category']:>8}: held={g['held']}/{g['max_batch']} "
                f"age={g['oldest_age_s'] * 1e3:.1f}ms "
                f"deadline={g['deadline_s'] * 1e3:.1f}ms "
                f"rate={g['arrival_rate_hz']:.3g}/s")
        return "\n".join(rows)
