"""Device-side operand residency: stop paying the DAC for resident bytes.

The paper's thesis is that conversions — not the analog core — bound
accelerator performance, yet the runtime so far re-stages and re-quantizes
every operand on every flush even when the bytes are unchanged: a conv
layer stack re-sends the same frames once per layer, serving re-sends the
same kernels every decode step.  Real photonic systems exploit exactly the
opposite pattern (weight-stationary MVM: keep one operand resident on the
analog side, stream only the other), and ladder-style DACs make the win
physical — write latency/energy scale with the bits that actually change,
so a resident operand is near-free on the write path.

:class:`ResidencyCache` is that lever, executed:

  * **Content-keyed.**  An entry is keyed by the operand's content digest
    (shape + dtype + SHA1, via ``BackendContext.content_key``) *plus the
    converter operating point* (DAC/ADC bits and ENOB) — retuning a
    converter re-ranges the quantization grid, so every operand staged
    under the old operating point silently stops matching (the resident
    bytes on the device no longer equal what a fresh conversion would
    produce).  Distinct shapes with equal bytes can never collide: the
    shape is part of the digest.
  * **Per-device.**  Resident sets are held per device label (``"host"``
    for the staged-stack path; ``("device", d)`` for sharded placements),
    so a re-scatter ships only the shards missing from each device, and a
    quarantined device's resident set is *dropped* — its bytes are not
    trustworthy after the fault that quarantined it, and re-admission
    must re-stage.  The sharded backend's device-resident placements
    store per-frame shards under kind ``"frame-shard"`` and frame-mode
    row tiles under ``"frame-tile"``; dropping a device's set is what
    invalidates its placement shards.
  * **Budget-priced LRU.**  Capacity is a fraction of the staging
    :class:`~repro.runtime.tiling.MemoryBudget` (residency and tiles
    share the same physical bytes): storing past capacity evicts
    least-recently-used entries, and
    :meth:`ResidencyCache.effective_budget` hands the executor the budget
    *minus* resident bytes so tile depth shrinks as the cache fills.
  * **Observable.**  Every lookup/store/eviction/invalidation is counted
    per category (mirrored into ``RuntimeTelemetry.residency_counts`` and
    emitted as ``cache`` instants on the tracer when either is attached),
    so hit rates are first-class telemetry the router can replan from.

The cache is OPT-IN (``OffloadExecutor(residency=...)``): with it off the
runtime stages exactly as before, bit for bit and price for price.  With
it on, results are still bit-equal to the re-staged path on digital
backends — a hit replays the same jitted computation on the same staged
array — which is how the runtime-equivalence invariant extends to
``cached == re-staged == looped``.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Hashable, Iterable, Sequence

from repro.core.conversion import (code_signature, delta_write_scale,
                                   expected_flip_fraction)

__all__ = ["DELTA_THRESHOLD", "ResidencyCache", "ResidencyEntry",
           "operating_point", "residency_key"]

# Default capacity when no staging budget is supplied (the unlimited-budget
# regime still wants bounded residency: the cache holds live array
# references, and "resident forever" is a leak, not a policy).
DEFAULT_CAPACITY_BYTES = 64 * 1024 * 1024

# Fraction of the staging budget's spendable bytes the cache may pin.  The
# other half stays with tile staging — a cache that ate the whole budget
# would force tile_k to 1 and trade the batching win for the residency win
# instead of keeping both.
BUDGET_FRACTION = 0.5

# Flip fractions at or below this classify a re-staged operand as a
# *delta* write (partial price); above it the rewrite is effectively a new
# operand and pays the full write.  Uncorrelated frames flip ~50% of their
# LSBs, a drifting sensor frame far fewer — 0.35 splits those regimes with
# margin on both sides.
DELTA_THRESHOLD = 0.35

# Per-operand slot signatures retained for delta classification.  The
# ledger is keyed by dispatch slot, not content, so it grows with distinct
# (stream, category, shape, index) shapes — past this it resets wholesale
# (conservative: forgotten slots re-stage in full, never mis-price).
SLOT_LEDGER_MAX = 4096


def operating_point(spec) -> tuple:
    """The converter operating point residency keys must carry.

    Bits AND effective bits (ENOB) on both paths: retuning either
    converter moves the quantization grid, so bytes staged under the old
    point are stale even though the digital source operand is unchanged.
    """
    return ("op", spec.dac.bits, float(spec.dac.effective_bits),
            spec.adc.bits, float(spec.adc.effective_bits))


def residency_key(ctx, xs: Sequence, kind: str) -> tuple:
    """Residency key for an operand group: kind + operating point + the
    per-item content digests (shape, dtype, SHA1 — via the context's
    id-memoized ``content_key``, so repeat flushes of long-lived arrays
    never re-hash)."""
    return (kind, operating_point(ctx.spec),
            tuple(ctx.content_key(x) for x in xs))


@dataclasses.dataclass
class ResidencyEntry:
    """One resident operand: the staged payload and its accounting."""

    device: Hashable
    key: tuple
    payload: object
    nbytes: int
    category: str
    kind: str  # "frame" (staged stack) / "kernel" / "weights" / "shard"


class ResidencyCache:
    """Content-keyed per-device operand residency under the staging budget.

    Args:
      budget: the staging :class:`~repro.runtime.tiling.MemoryBudget` the
        cache shares bytes with.  Capacity is ``BUDGET_FRACTION`` of its
        spendable bytes; an unlimited (or absent) budget falls back to
        :data:`DEFAULT_CAPACITY_BYTES`.
      capacity_bytes: explicit capacity override (wins over ``budget``).
      fraction: the budget share when deriving capacity from ``budget``.
      delta_threshold: flip fraction at or below which a changed operand
        re-staged into a known dispatch slot takes the delta-encoded
        partial write instead of a full re-stage
        (:data:`DELTA_THRESHOLD`).
    """

    def __init__(self, budget=None, *, capacity_bytes: int | None = None,
                 fraction: float = BUDGET_FRACTION,
                 delta_threshold: float = DELTA_THRESHOLD) -> None:
        if capacity_bytes is not None:
            cap = int(capacity_bytes)
        elif budget is not None and not budget.is_unlimited:
            cap = int(budget.spendable_bytes * fraction)
        else:
            cap = DEFAULT_CAPACITY_BYTES
        self.capacity_bytes = max(1, cap)
        # one global LRU order across devices: the budget is a per-host
        # staging pool, so the coldest entry anywhere is the right victim
        self._lru: "collections.OrderedDict[tuple, ResidencyEntry]" = \
            collections.OrderedDict()
        self._bytes = 0
        # category -> Counter of "hit"/"miss"/"eviction"/"invalidation"
        self.counts: dict[str, collections.Counter] = \
            collections.defaultdict(collections.Counter)
        # submit(reuse=) tokens: token -> ((shape, dtype), content key)
        self._tokens: dict[str, tuple] = {}
        # delta classification: dispatch slot -> (content key, signature)
        # of the operand last staged into that slot — the "previously
        # staged codes" a partial rewrite is diffed against
        self.delta_threshold = float(delta_threshold)
        self._slots: dict[tuple, tuple] = {}

    # -- events (cache-local counters + telemetry/tracer mirror) -------------
    def _emit(self, ctx, category: str, event: str, **attrs) -> None:
        self.counts[category][event] += 1
        if ctx is None:
            return
        tel = getattr(ctx, "telemetry", None)
        note = getattr(tel, "note_residency", None)
        if note is not None:
            note(category, event)
        tr = getattr(ctx, "tracer", None)
        if tr is not None:
            tr.instant("cache", lane="host", category=category, event=event,
                       **attrs)

    # -- the cache proper ------------------------------------------------------
    def lookup(self, device: Hashable, key: tuple, *, category: str,
               ctx=None):
        """The resident payload for ``(device, key)``, or None on a miss.
        A hit refreshes the entry's LRU position."""
        entry = self._lru.get((device, key))
        if entry is None:
            self._emit(ctx, category, "miss", device=str(device))
            return None
        self._lru.move_to_end((device, key))
        self._emit(ctx, category, "hit", device=str(device),
                   kind=entry.kind, nbytes=entry.nbytes)
        return entry.payload

    def store(self, device: Hashable, key: tuple, payload, nbytes: int, *,
              category: str, kind: str, ctx=None) -> list[ResidencyEntry]:
        """Insert one resident operand, evicting LRU entries past capacity.

        Returns the evicted entries (empty when none).  An operand larger
        than the whole capacity is not cached at all — evicting everything
        to hold one entry would thrash the working set it shares the
        budget with."""
        nbytes = max(0, int(nbytes))
        if nbytes > self.capacity_bytes:
            return []
        old = self._lru.pop((device, key), None)
        if old is not None:
            self._bytes -= old.nbytes
        evicted: list[ResidencyEntry] = []
        while self._lru and self._bytes + nbytes > self.capacity_bytes:
            _, victim = self._lru.popitem(last=False)
            self._bytes -= victim.nbytes
            evicted.append(victim)
            self._emit(ctx, victim.category, "eviction",
                       device=str(victim.device), kind=victim.kind,
                       nbytes=victim.nbytes)
        entry = ResidencyEntry(device=device, key=key, payload=payload,
                               nbytes=nbytes, category=category, kind=kind)
        self._lru[(device, key)] = entry
        self._bytes += nbytes
        return evicted

    def classify_operand(self, slot_key: tuple, ck: tuple, x, spec, *,
                         category: str, ctx=None) -> tuple[str, float]:
        """Classify one operand re-staged into dispatch slot ``slot_key``
        as ``("hit", 0.0)`` / ``("delta", write_scale)`` /
        ``("full", 1.0)`` against the operand last staged there.

        ``ck`` is the operand's content key (already computed by the
        caller — the slot comparison is digest-equality, so an unchanged
        operand never pays the signature).  A changed operand pays one
        :func:`~repro.core.conversion.code_signature` at the DAC's
        resolution; its flip fraction against the slot's previous
        signature decides delta (≤ ``delta_threshold``, priced at
        :func:`~repro.core.conversion.delta_write_scale`) versus full.
        Every outcome updates the slot ledger; delta/full writes are
        mirrored into ``RuntimeTelemetry.delta_stats`` when the context
        carries telemetry.  Classification never touches the LRU — it is
        the *write-side* price of an operand the group-grain lookup
        already missed."""
        prev = self._slots.get(slot_key)
        if prev is not None and prev[0] == ck:
            return "hit", 0.0
        bits = spec.dac.bits
        sig = code_signature(x, bits)
        if slot_key not in self._slots and len(self._slots) >= SLOT_LEDGER_MAX:
            self._slots.clear()
        self._slots[slot_key] = (ck, sig)
        tel = getattr(ctx, "telemetry", None) if ctx is not None else None
        note = getattr(tel, "note_delta", None)
        if prev is None:
            if note is not None:
                note(category)
            return "full", 1.0
        frac = expected_flip_fraction(prev[1], sig)
        if frac > self.delta_threshold:
            if note is not None:
                note(category)
            return "full", 1.0
        self._emit(ctx, category, "delta", flip=frac)
        if note is not None:
            note(category, flip_fraction=frac)
        return "delta", delta_write_scale(frac, bits)

    def discard(self, device: Hashable, key: tuple, *, ctx=None,
                reason: str = "donation") -> int:
        """Drop one resident entry outright (buffer donation: a placed
        frame about to be re-staged donates its stale device buffer so
        the update never holds two copies against the staging budget).
        Returns the bytes freed, 0 when the entry was not resident."""
        entry = self._lru.pop((device, key), None)
        if entry is None:
            return 0
        self._bytes -= entry.nbytes
        self._emit(ctx, entry.category, reason, device=str(device),
                   kind=entry.kind, nbytes=entry.nbytes)
        return entry.nbytes

    def invalidate_device(self, device: Hashable, *, ctx=None) -> int:
        """Drop ``device``'s whole resident set (fault quarantine: the
        bytes on a device that just faulted are not trustworthy, and
        re-admission must re-stage).  Returns bytes dropped."""
        doomed = [k for k in self._lru if k[0] == device]
        dropped = 0
        for k in doomed:
            entry = self._lru.pop(k)
            self._bytes -= entry.nbytes
            dropped += entry.nbytes
            self._emit(ctx, entry.category, "invalidation",
                       device=str(device), kind=entry.kind,
                       nbytes=entry.nbytes)
        # the device's slot signatures go too: delta-diffing against codes
        # staged on a quarantined device would price a partial write the
        # hardware cannot be trusted to hold
        for sk in [s for s in self._slots if s and s[0] == device]:
            del self._slots[sk]
        return dropped

    def clear(self) -> None:
        """Drop every entry (counters and tokens survive — they are the
        run's ledger, not the cache's contents)."""
        self._lru.clear()
        self._bytes = 0
        self._slots.clear()

    # -- views -----------------------------------------------------------------
    def resident_bytes(self, device: Hashable | None = None) -> int:
        if device is None:
            return self._bytes
        return sum(e.nbytes for (d, _k), e in self._lru.items()
                   if d == device)

    def resident_keys(self, device: Hashable | None = None,
                      ) -> Iterable[tuple]:
        return [k for (d, k) in self._lru if device is None or d == device]

    def __len__(self) -> int:
        return len(self._lru)

    def hit_rate(self, category: str | None = None) -> float | None:
        """hits / (hits + misses) for ``category`` (overall when None);
        None before any lookup — no traffic is no claim."""
        hits = misses = 0
        for cat, c in self.counts.items():
            if category is not None and cat != category:
                continue
            hits += c.get("hit", 0)
            misses += c.get("miss", 0)
        total = hits + misses
        return None if total == 0 else hits / total

    # -- budget sharing --------------------------------------------------------
    def effective_budget(self, budget):
        """The staging budget left after the cache's resident bytes: tiles
        and residency share the same physical pool, so a fuller cache
        means a shallower tile (``MemoryBudget.minus``)."""
        if budget is None:
            return budget
        return budget.minus(self.resident_bytes())

    # -- submit(reuse=) tokens -------------------------------------------------
    def note_token(self, token: str, x, ctx) -> tuple:
        """Register (or re-assert) a reuse token for operand ``x``.

        The explicit-token path of ``OffloadExecutor.submit(reuse=...)``:
        the caller promises that every submission under ``token`` carries
        the same content, so after the first digest the token's key is
        seeded straight into the context's digest memo and later
        submissions never re-hash.  A token re-used with a different
        shape/dtype is treated as a new operand (re-digested, token
        re-bound) rather than trusted."""
        sig = (tuple(x.shape), str(x.dtype))
        rec = self._tokens.get(token)
        if rec is not None and rec[0] == sig:
            # trust the token: seed the memo so content_key(x) is free
            ctx._digest_memo[id(x)] = (x, rec[1])
            return rec[1]
        key = ctx.content_key(x)
        self._tokens[token] = (sig, key)
        return key

    def summary(self) -> str:
        rows = [f"residency: {len(self._lru)} entries, "
                f"{self._bytes}/{self.capacity_bytes} bytes"]
        for cat, c in sorted(self.counts.items()):
            parts = [f"{k} x{v}" for k, v in sorted(c.items())]
            rate = self.hit_rate(cat)
            row = f"  {cat}: " + "; ".join(parts)
            if rate is not None:
                row += f" (hit rate {rate:.0%})"
            rows.append(row)
        return "\n".join(rows)
