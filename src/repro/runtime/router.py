"""Plan-driven routing: consume an ``OffloadPlan``, don't just print it.

``PlanRouter`` is the piece that finally *uses* the planner's output: each
category the plan marked ``offload=True`` routes to the analog backend,
everything else stays on the host.  Because the executor records telemetry
as traffic flows, the router can then re-plan from *measured* profiles —
the closed loop the paper's methodology implies:

    router = PlanRouter(executor)          # starts all-host (profiling mode)
    ... serve traffic via router.run(...) ...
    plan = router.replan()                 # plan from observed workload
    ... keep serving; offload-worthy categories now hit the analog engine ...

``replan`` prices the observed profiles on the executor's spec with
``plan_offload`` and atomically swaps the routing table to match the new
plan's decisions.
"""

from __future__ import annotations

import dataclasses

from repro.core.conversion import delta_write_scale
from repro.core.planner import CategoryProfile, OffloadPlan, plan_offload
from repro.runtime.backends import CATEGORIES, CONV_CAPTURES
from repro.runtime.executor import OffloadExecutor, OffloadResult
from repro.runtime.metrics import DriftReport, drift_report

__all__ = ["PlanRouter"]


class PlanRouter:
    """Routes op categories to backends according to an ``OffloadPlan``."""

    def __init__(self, executor: OffloadExecutor, plan: OffloadPlan | None = None,
                 *, offload_backend: str = "optical-sim",
                 host_backend: str = "host") -> None:
        self.executor = executor
        self.offload_backend = offload_backend
        self.host_backend = host_backend
        self.routes: dict[str, str] = {c: host_backend for c in CATEGORIES}
        self.plan: OffloadPlan | None = None
        # Operator-set per-category ceilings are constraints the adaptive
        # choice never exceeds — and never destroys: the original value is
        # snapshotted before the router writes a (possibly deadline-
        # lowered) ceiling of its own, so relaxing a deadline can raise
        # the ceiling back up to the operator's bound.  A ceiling is
        # recognized as operator-set when it differs from what this router
        # last wrote.  The same bookkeeping covers the sharded device
        # fan-out (``set_n_devices``).
        self._operator_caps: dict[str, int] = {}
        self._router_set: dict[str, int] = {}
        self._operator_dev_caps: dict[str, int] = {}
        self._router_set_dev: dict[str, int] = {}
        self._operator_tile_caps: dict[str, int] = {}
        self._router_set_tile: dict[str, int] = {}
        self._operator_window_caps: dict[str, int] = {}
        self._router_set_window: dict[str, int] = {}
        # modeled-vs-measured attribution from the executor's tracer,
        # refreshed by each replan (None when tracing is off / no spans)
        self.drift: DriftReport | None = None
        if plan is not None:
            self.apply(plan)

    @classmethod
    def from_plan(cls, executor: OffloadExecutor, plan: OffloadPlan,
                  **kwargs) -> "PlanRouter":
        return cls(executor, plan, **kwargs)

    # -- routing table ---------------------------------------------------------
    def apply(self, plan: OffloadPlan) -> None:
        """Swap the routing table to match ``plan``'s offload decisions."""
        routes = {c: self.host_backend for c in CATEGORIES}
        for d in plan.decisions:
            if d.category in routes and d.offload:
                routes[d.category] = self.offload_backend
        self.routes = routes
        self.plan = plan

    def backend_for(self, category: str) -> str:
        return self.routes.get(category, self.host_backend)

    def offloaded_categories(self) -> tuple[str, ...]:
        return tuple(c for c, b in self.routes.items()
                     if b != self.host_backend)

    # -- execution (delegates to the executor with the routed backend) ---------
    def submit(self, category: str, x, **kwargs) -> OffloadResult:
        kwargs.setdefault("backend", self.backend_for(category))
        return self.executor.submit(category, x, **kwargs)

    def run(self, category: str, x, **kwargs):
        return self.submit(category, x, **kwargs).get()

    def flush(self) -> list[OffloadResult]:
        return self.executor.flush()

    @property
    def pending(self) -> int:
        return self.executor.pending

    # -- adaptive batching + device fan-out + tile depth -----------------------
    def choose_sharding(self, deadline_s: float | None = None,
                        ) -> dict[str, tuple[int, int, int]]:
        """Pick per-category ``(max_batch, n_devices, tile_k)`` from
        measured telemetry.

        The amortization side of the trade wants the deepest batch the
        executor allows (every coalesced call shares the handshake, settle,
        and lane-ceil residue); the latency side caps it: with a
        ``deadline_s``, the modeled batched invocation — priced from the
        category's *observed* per-call boundary traffic at the executor's
        pipeline depth, its sharded device fan-out (max-over-devices plus
        sync), its memory-budgeted tile depth (each tile pays its own
        prologue, tiles overlap two-deep) AND its measured residency hit
        rate (frames the device already holds skip the write-side DAC
        crossing) — must still finish within the deadline, so the depth is
        halved until it fits.  Categories with no recorded traffic are
        left at the executor's global ceilings.

        The device count rides the batch (group sharding can never use
        more devices than the group has items: ``n = min(device cap, k)``)
        and the tile depth rides both: ``tile_k`` is what
        :func:`~repro.runtime.tiling.choose_tile` picks for a ``k``-deep
        group of the category's observed frame size under the executor's
        budget — the SAME resolution dispatch uses, so the chosen tile is
        the dispatched tile.  The chosen ``max_batch`` and ``n_devices``
        are monotone non-increasing as the deadline tightens (the halving
        sequence is fixed, so a smaller deadline only ever stops it
        later); ``tile_k`` never exceeds the chosen batch or the budget's
        frame cap, but its even-split refinement may legitimately pick a
        *larger* divisor at a smaller batch (a 6-deep group tiles 3+3
        where a 16-deep one tiles 2x8 under the same cap).

        Per-category ceilings the *operator* set directly
        (``executor.set_max_batch`` / ``set_n_devices`` / ``set_tile_k``)
        are bounds the adaptive choice never exceeds; ceilings this router
        itself installed are re-derived from scratch on each call (so
        relaxing a deadline raises them again, up to the operator's bound
        where one exists).
        """
        from repro.runtime.tiling import choose_tile

        ex, telemetry = self.executor, self.executor.telemetry
        spec = ex.spec
        chosen: dict[str, tuple[int, int, int]] = {}
        for cat in telemetry.categories():
            k = min(ex.max_batch, self._operator_bound(cat))
            n_cap = min(ex.n_devices, self._operator_device_bound(cat))
            q = getattr(ex, "quarantine", None)
            if q is not None:
                # quarantined devices are not capacity: the plan shrinks
                # its fan-out around them (at least one device always
                # remains — the sharded scatter falls back the same way)
                avail = ex.n_devices - q.active_device_count(ex.now())
                n_cap = max(1, min(n_cap, avail))
            tile_cap = self._operator_tile_bound(cat)
            n_in, n_out = telemetry.samples_per_call(cat)

            def tile_for(depth: int) -> int:
                if n_in <= 0:
                    return depth
                # resident operands occupy the same staging budget tiles
                # spend from, so the tile choice here must see the budget
                # the dispatcher will actually have left
                t = choose_tile(n_in, depth, ex.effective_mem_budget(),
                                n_out=n_out or None,
                                pipeline_depth=ex.pipeline_depth).tile_k
                if tile_cap is not None:
                    t = min(t, tile_cap)
                return max(1, min(t, depth))

            # the measured residency hit rate projects how many of a
            # k-deep group's frames the device already holds: a cache that
            # is absorbing most of the write traffic lets a deeper batch
            # fit the same deadline, so the halving loop prices it in
            hit_rate = telemetry.residency_hit_rate(cat) or 0.0
            # ...and the observed delta rate projects how many of the
            # remaining (written) frames take the delta-encoded partial
            # write at the observed mean flip fraction rather than a full
            # re-stage — the same write-side deadline relief, one notch
            # weaker than a hit
            d_rate = telemetry.delta_rate(cat) or 0.0
            mean_flip = telemetry.mean_flip_fraction(cat)
            dac_bits = getattr(getattr(spec, "dac", None), "bits", 1)

            def delta_proj(depth: int, resident: int) -> tuple:
                written = depth - resident
                n_delta = min(written, int(round(d_rate * written)))
                if n_delta <= 0:
                    return ()
                return (delta_write_scale(mean_flip, dac_bits),) * n_delta

            if (deadline_s is not None and n_in > 0
                    and hasattr(spec, "batched_step_cost")):
                pricing_spec = spec
                if cat == "conv" and hasattr(spec, "phase_shift_captures"):
                    # conv pays interferometric complex recovery: the
                    # backend prices it at 4 captures, so the deadline
                    # check must too or the chosen depth blows the bound
                    pricing_spec = dataclasses.replace(
                        spec, phase_shift_captures=CONV_CAPTURES)
                while k > 1:
                    resident = min(k, int(round(hit_rate * k)))
                    cost = pricing_spec.batched_step_cost(
                        n_in, n_out or None, batch=k,
                        pipeline_depth=ex.pipeline_depth,
                        n_devices=max(1, min(n_cap, k)),
                        tile_k=tile_for(k),
                        resident_frames=resident,
                        delta_fractions=delta_proj(k, resident))
                    if cost.total_s <= deadline_s:
                        break
                    k //= 2
            k = max(k, 1)
            chosen[cat] = (k, max(1, min(n_cap, k)), tile_for(k))
        return chosen

    def choose_windows(self) -> dict[str, int]:
        """Pick per-category pipeline *window* depths from measured
        telemetry.

        A category's window is how many of its invocations the executor
        lets ride in flight before retirement blocks the next submit
        (:meth:`~repro.runtime.executor.OffloadExecutor.set_pipeline_window`).
        The useful depth is what the traffic actually achieved: a category
        whose invocations never overlapped (mean in-flight-at-dispatch
        occupancy ~1, from ``telemetry.window_occupancy``) collapses to a
        window of 1 and the cost model stops crediting it with pipelined
        hiding; a category that genuinely rode the window deep keeps the
        executor's full global depth.  The pick is
        ``min(operator bound, global pipeline_depth, round(measured
        occupancy))`` (floor 1) — monotone in the observed overlap, and
        never above the global depth so the back-compat alias stays the
        ceiling.

        Window depths the *operator* pinned directly
        (``executor.set_pipeline_window``) are bounds the adaptive choice
        never exceeds, with the same snapshot-before-overwrite bookkeeping
        as the batch/device/tile ceilings.
        """
        ex, telemetry = self.executor, self.executor.telemetry
        chosen: dict[str, int] = {}
        for cat in telemetry.categories():
            cap = self._operator_window_bound(cat)
            occ = max(1, round(telemetry.window_occupancy(cat)))
            chosen[cat] = max(1, min(cap, ex.pipeline_depth, occ))
        return chosen

    def choose_max_batch(self, deadline_s: float | None = None) -> dict[str, int]:
        """The batch slice of :meth:`choose_sharding` (kept for callers
        that predate sharded/tiled offload)."""
        return {cat: k for cat, (k, _n, _t)
                in self.choose_sharding(deadline_s).items()}

    def _operator_bound(self, cat: str) -> int:
        """Upper bound the operator imposed on ``cat``'s ceiling (the
        executor's global cap when they never set one).  A current ceiling
        that is not the router's own last write is (re-)snapshotted as the
        operator's."""
        current = self.executor.category_max_batches().get(cat)
        if current is not None and current != self._router_set.get(cat):
            self._operator_caps[cat] = current
        return self._operator_caps.get(cat, self.executor.max_batch)

    def _operator_device_bound(self, cat: str) -> int:
        """Like :meth:`_operator_bound`, for the sharded device fan-out."""
        current = self.executor.category_n_devices().get(cat)
        if current is not None and current != self._router_set_dev.get(cat):
            self._operator_dev_caps[cat] = current
        return self._operator_dev_caps.get(cat, self.executor.n_devices)

    def _operator_tile_bound(self, cat: str) -> int | None:
        """Like :meth:`_operator_bound`, for the tile depth — except the
        executor has no global tile ceiling (the budget is the default
        authority), so "no operator pin" is None, not a cap."""
        current = self.executor.category_tile_ks().get(cat)
        if current is not None and current != self._router_set_tile.get(cat):
            self._operator_tile_caps[cat] = current
        return self._operator_tile_caps.get(cat)

    def _operator_window_bound(self, cat: str) -> int:
        """Like :meth:`_operator_bound`, for the per-engine pipeline
        window depth (the executor's global ``pipeline_depth`` when the
        operator never pinned one)."""
        current = self.executor.category_windows().get(cat)
        if current is not None and current != self._router_set_window.get(cat):
            self._operator_window_caps[cat] = current
        return self._operator_window_caps.get(cat, self.executor.pipeline_depth)

    # -- the loop-closer -------------------------------------------------------
    def replan(self, spec=None,
               extra_profiles: tuple[CategoryProfile, ...] = (),
               apply: bool = True, max_batch: int | None = None,
               deadline_s: float | None = None) -> OffloadPlan:
        """Re-derive the plan from the executor's measured telemetry.

        By default pricing batches at the *observed* queue occupancy
        (capped by the adaptively chosen per-category ceiling): traffic
        that arrived one call per flush gets no handshake amortization
        credit, traffic that arrived in deep groups does — so the plan's
        verdict matches how this runtime actually executed.  Pass
        ``max_batch=1`` for the paper's serial model, or an explicit value
        to price a hypothetical batching depth (explicit values disable
        adaptation).

        Adaptive batching + sharding + tiling: when ``max_batch`` is
        omitted, the router also *sets* the executor's per-category
        coalescing ceilings, sharded device fan-outs AND memory-budgeted
        tile depths to :meth:`choose_sharding`'s ``(max_batch, n_devices,
        tile_k)`` picks (observed traffic + optional ``deadline_s``
        latency bound) as part of ``apply`` — the caps stop being fixed
        constructor arguments and follow the workload.  The per-engine
        pipeline windows follow too: :meth:`choose_windows` collapses a
        category's window to its observed in-flight occupancy so the
        modeled pipelined hiding matches the overlap the traffic actually
        achieved.

        Fidelity gating: when the executor shadows offloaded batches
        (``fidelity=``), each profile carries the checker's worst observed
        ``rel_err`` for its category into ``plan_offload``, which vetoes
        offload for categories whose error exceeds the converters' ENOB
        budget *regardless of speedup* (``OffloadDecision.fidelity_bound``).
        Applying such a plan routes the degraded category back to the host
        — the profile -> plan -> execute -> re-profile loop now closes over
        accuracy as well as time.

        ``extra_profiles`` lets callers append workload the runtime never
        saw (e.g. a known non-offloadable phase); ``apply=False`` prices
        without touching the routing table or the executor's ceilings.
        """
        telemetry = self.executor.telemetry
        tracer = getattr(self.executor, "tracer", None)
        if tracer is not None:
            # modeled-vs-measured attribution for the traffic this replan
            # prices: the worst-drifting stage names where the cost model
            # and the measured runtime disagree most
            rep = drift_report(tracer.spans())
            self.drift = rep if rep.invocations else None
        profiles = list(telemetry.profiles())
        profiles.extend(extra_profiles)
        checker = self.executor.fidelity
        if checker is not None:
            profiles = [
                dataclasses.replace(p, rel_err=w.rel_err)
                if (w := checker.worst(p.name)) is not None else p
                for p in profiles
            ]
        chosen: dict[str, tuple[int, int, int]] | None = None
        if max_batch is None:
            chosen = self.choose_sharding(deadline_s)
            # price at what the traffic achieved, bounded by the adaptive
            # ceiling: one category's deep batches must not credit another
            # category's serial traffic with amortization
            batch: int | dict[str, int] = {
                cat: min(chosen[cat][0], telemetry.observed_occupancy(cat))
                for cat in telemetry.categories()}
        else:
            batch = max_batch
        # the gate must judge with the checker's own slack, or the plan's
        # fidelity verdicts disagree with the checker's VIOLATION reports
        gate_kw = {} if checker is None \
            else {"fidelity_slack": checker.slack}
        plan = plan_offload(profiles, spec or self.executor.spec,
                            max_batch=batch, **gate_kw)
        if apply:
            self.apply(plan)
            if chosen is not None:
                for cat, (k, n, t) in chosen.items():
                    self.executor.set_max_batch(cat, k)
                    self._router_set[cat] = k
                    self.executor.set_n_devices(cat, n)
                    self._router_set_dev[cat] = n
                    self.executor.set_tile_k(cat, t)
                    self._router_set_tile[cat] = t
                for cat, w in self.choose_windows().items():
                    self.executor.set_pipeline_window(cat, w)
                    self._router_set_window[cat] = w
        return plan

    def summary(self) -> str:
        rows = ["router: " + ", ".join(
            f"{c}->{b}" for c, b in sorted(self.routes.items()))]
        if self.drift is not None and self.drift.worst is not None:
            w = self.drift.worst
            rows.append(
                f"  drift: worst stage '{w.stage}' measured/modeled="
                f"{w.drift:.3g} over {self.drift.invocations} traced "
                f"invocations")
        if self.plan is not None:
            rows.append(self.plan.summary())
        return "\n".join(rows)
