"""Plan-driven routing: consume an ``OffloadPlan``, don't just print it.

``PlanRouter`` is the piece that finally *uses* the planner's output: each
category the plan marked ``offload=True`` routes to the analog backend,
everything else stays on the host.  Because the executor records telemetry
as traffic flows, the router can then re-plan from *measured* profiles —
the closed loop the paper's methodology implies:

    router = PlanRouter(executor)          # starts all-host (profiling mode)
    ... serve traffic via router.run(...) ...
    plan = router.replan()                 # plan from observed workload
    ... keep serving; offload-worthy categories now hit the analog engine ...

``replan`` prices the observed profiles on the executor's spec with
``plan_offload`` and atomically swaps the routing table to match the new
plan's decisions.
"""

from __future__ import annotations

from repro.core.planner import CategoryProfile, OffloadPlan, plan_offload
from repro.runtime.backends import CATEGORIES
from repro.runtime.executor import OffloadExecutor, OffloadResult

__all__ = ["PlanRouter"]


class PlanRouter:
    """Routes op categories to backends according to an ``OffloadPlan``."""

    def __init__(self, executor: OffloadExecutor, plan: OffloadPlan | None = None,
                 *, offload_backend: str = "optical-sim",
                 host_backend: str = "host") -> None:
        self.executor = executor
        self.offload_backend = offload_backend
        self.host_backend = host_backend
        self.routes: dict[str, str] = {c: host_backend for c in CATEGORIES}
        self.plan: OffloadPlan | None = None
        if plan is not None:
            self.apply(plan)

    @classmethod
    def from_plan(cls, executor: OffloadExecutor, plan: OffloadPlan,
                  **kwargs) -> "PlanRouter":
        return cls(executor, plan, **kwargs)

    # -- routing table ---------------------------------------------------------
    def apply(self, plan: OffloadPlan) -> None:
        """Swap the routing table to match ``plan``'s offload decisions."""
        routes = {c: self.host_backend for c in CATEGORIES}
        for d in plan.decisions:
            if d.category in routes and d.offload:
                routes[d.category] = self.offload_backend
        self.routes = routes
        self.plan = plan

    def backend_for(self, category: str) -> str:
        return self.routes.get(category, self.host_backend)

    def offloaded_categories(self) -> tuple[str, ...]:
        return tuple(c for c, b in self.routes.items()
                     if b != self.host_backend)

    # -- execution (delegates to the executor with the routed backend) ---------
    def submit(self, category: str, x, **kwargs) -> OffloadResult:
        kwargs.setdefault("backend", self.backend_for(category))
        return self.executor.submit(category, x, **kwargs)

    def run(self, category: str, x, **kwargs):
        return self.submit(category, x, **kwargs).get()

    def flush(self) -> list[OffloadResult]:
        return self.executor.flush()

    @property
    def pending(self) -> int:
        return self.executor.pending

    # -- the loop-closer -------------------------------------------------------
    def replan(self, spec=None,
               extra_profiles: tuple[CategoryProfile, ...] = (),
               apply: bool = True, max_batch: int | None = None) -> OffloadPlan:
        """Re-derive the plan from the executor's measured telemetry.

        By default pricing batches at the *observed* queue occupancy
        (capped by the executor's ``max_batch``): traffic that arrived one
        call per flush gets no handshake amortization credit, traffic that
        arrived in deep groups does — so the plan's verdict matches how
        this runtime actually executed.  Pass ``max_batch=1`` for the
        paper's serial model, or an explicit value to price a hypothetical
        batching depth.  ``extra_profiles`` lets callers append workload
        the runtime never saw (e.g. a known non-offloadable phase);
        ``apply=False`` prices without touching the routing table.
        """
        telemetry = self.executor.telemetry
        profiles = list(telemetry.profiles())
        profiles.extend(extra_profiles)
        if max_batch is None:
            # per-category: one category's deep batches must not credit
            # another category's serial traffic with amortization
            batch: int | dict[str, int] = {
                cat: min(self.executor.max_batch,
                         telemetry.observed_occupancy(cat))
                for cat in telemetry.categories()}
        else:
            batch = max_batch
        plan = plan_offload(profiles, spec or self.executor.spec,
                            max_batch=batch)
        if apply:
            self.apply(plan)
        return plan

    def summary(self) -> str:
        rows = ["router: " + ", ".join(
            f"{c}->{b}" for c, b in sorted(self.routes.items()))]
        if self.plan is not None:
            rows.append(self.plan.summary())
        return "\n".join(rows)
