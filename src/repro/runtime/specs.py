"""Shared demo/benchmark accelerator design points for the runtime.

``BATCHED_4F`` is the batched 4f variant used by
``examples/optical_offload.py`` and ``benchmarks/runtime_bench.py``: the
prototype's architecture with upgraded peripherals — a 2048x2048
ferroelectric SLM, PCIe/CoaXPress-class pixel links, column-parallel
camera-class converters (higher resolution at lower rate, still
frontier-plausible) — but the 60 Hz display-class *frame-sync latency*
retained: a liquid-crystal SLM refreshes per frame no matter how fast the
data link is.  That per-invocation latency is the paper's §6 overhead, and
it amortizes exactly when the runtime packs many inputs into one aperture
frame (the batching executor's job).

The interferometric conv path genuinely needs the extra ADC bits: with
the paper's 6 b/8 b frontier converters the fidelity checker flags conv
results as outside the ENOB budget.
"""

from __future__ import annotations

import dataclasses

from repro.core.accelerator import PROTOTYPE_4F, OpticalFourierAcceleratorSpec
from repro.core.conversion import ConverterSpec

__all__ = ["SLM_DAC", "CAMERA_ADC", "BATCHED_4F"]

SLM_DAC = ConverterSpec(name="slm-dac", kind="dac", bits=8, rate_hz=1.0e9,
                        power_w=0.050, enob=7.0)

# 14 b column-parallel scientific-camera class readout.  The auto-ranged
# ADC digitizes a DC-dominated Fourier-plane intensity, so effective
# resolution for off-DC content is what the extra bits buy.  Walden FoM
# 29 fJ/c-s at 500 MS/s — above the survey envelope (~5 fJ), realizable.
CAMERA_ADC = ConverterSpec(name="camera-adc", kind="adc", bits=14,
                           rate_hz=5.0e8, power_w=0.060, enob=12.0)

BATCHED_4F: OpticalFourierAcceleratorSpec = dataclasses.replace(
    PROTOTYPE_4F, name="batched-4f", slm_pixels=(2048, 2048),
    interface_latency_s=16.7e-3,
    dac=SLM_DAC, adc=CAMERA_ADC, dac_lanes=48, adc_lanes=48,
    slm_interface_hz=1.0e9, camera_interface_hz=1.0e9,
    slm_settle_s=1.0e-4, exposure_s=5.0e-5,
    # multi-aperture (sharded) execution: a host-side barrier of ~10 us per
    # participating device — small next to the frame-sync latency, but it
    # keeps max-over-devices pricing honest (free sync would make infinite
    # fan-out look free)
    device_sync_s=1.0e-5)
