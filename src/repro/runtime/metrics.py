"""Counters, streaming percentile histograms, and the modeled-vs-measured
drift report.

Two halves:

* A tiny metrics kernel (:class:`Counter`, :class:`Histogram`,
  :class:`MetricsRegistry`) with the same mergeability contract
  ``RuntimeTelemetry.merge`` has: histograms use *fixed log-spaced bins*,
  so merging two histograms is exact bin-count addition (associative,
  commutative) — per-worker registries roll up without resampling.
  Percentiles (p50/p95/p99) come from a cumulative walk over the bins; the
  answer is the geometric midpoint of the rank's bin, clamped to the
  observed [min, max], so a single-sample histogram reports the sample
  itself exactly and every estimate carries at most one bin of relative
  error (~15% at the default 16 bins/decade — plenty for latency
  attribution spanning microseconds to seconds).

* :func:`drift_report`: joins each traced invocation's *measured* stage
  decomposition (from its span attributes) against the *modeled*
  ``batched_step_cost`` decomposition the planner priced, per stage:

    ========  =============================  ===========================
    stage     modeled (StepCost)             measured (span attrs)
    ========  =============================  ===========================
    hold      ``hold_s``                     scheduler hold (exact by
                                             construction — the sanity
                                             anchor, drift ~= 1)
    stage     ``dac_s + interface_s``        host staging + DAC-prep +
                                             dispatch (``stage_s``)
    compute   ``analog_s + adc_s + host_s``  in-flight device window
                                             (``compute_s``; the sim runs
                                             the ADC quantize inside the
                                             device computation, so the
                                             read-side conversion lands
                                             here)
    total     ``total_s``                    charged wall + hold
    ========  =============================  ===========================

  ``drift = measured / modeled``.  Drift below 1 on ``stage`` is the
  expected regime (the digital host stages frames faster than the modeled
  optical boundary would convert them — the headroom that makes offload
  worth planning); drift above 1 means the runtime's own overhead exceeds
  the boundary price it claims to amortize, which is exactly the
  divergence the CI gate fails on.  The worst-drifting stage (largest
  ``|log(drift)|``) is surfaced in ``PlanRouter.replan`` telemetry.

Zero dependencies beyond the stdlib; importable before jax is.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable, Mapping

__all__ = ["Counter", "Histogram", "MetricsRegistry",
           "StageDrift", "DriftReport", "drift_report"]


@dataclasses.dataclass
class Counter:
    """A monotone event count."""

    value: int = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value

    def merge(self, other: "Counter") -> None:
        self.value += other.value


class Histogram:
    """Streaming histogram over fixed log-spaced bins.

    Args:
      lo: values at or below ``lo`` land in the underflow bin.
      hi: values at or above ``hi`` land in the overflow bin.
      bins_per_decade: bin resolution; percentile estimates carry at most
        one bin of relative error (``10 ** (1/bins_per_decade) - 1``).

    The bin layout is part of the histogram's identity: :meth:`merge`
    refuses mismatched layouts rather than resampling (resampling would
    break merge associativity, the property that makes per-worker
    histograms roll up exactly).
    """

    def __init__(self, lo: float = 1e-9, hi: float = 1e4,
                 bins_per_decade: int = 16) -> None:
        if not (0.0 < lo < hi):
            raise ValueError("need 0 < lo < hi")
        if bins_per_decade < 1:
            raise ValueError("bins_per_decade must be >= 1")
        self.lo = float(lo)
        self.hi = float(hi)
        self.bins_per_decade = int(bins_per_decade)
        decades = math.log10(self.hi / self.lo)
        # interior bins + one underflow + one overflow
        self._n_bins = int(math.ceil(decades * self.bins_per_decade)) + 2
        self.counts = [0] * self._n_bins
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _layout(self) -> tuple[float, float, int]:
        return (self.lo, self.hi, self.bins_per_decade)

    def _bin(self, v: float) -> int:
        if v <= self.lo:
            return 0
        if v >= self.hi:
            return self._n_bins - 1
        i = 1 + int(math.log10(v / self.lo) * self.bins_per_decade)
        return min(max(i, 1), self._n_bins - 2)

    def _bin_mid(self, i: int) -> float:
        if i <= 0:
            return self.lo
        if i >= self._n_bins - 1:
            return self.hi
        # geometric midpoint of interior bin i
        exp = (i - 0.5) / self.bins_per_decade
        return self.lo * (10.0 ** exp)

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[self._bin(v)] += 1
        self.n += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else math.nan

    def percentile(self, p: float) -> float:
        """The p-th percentile estimate (p in [0, 100]); NaN when empty."""
        if not 0.0 <= p <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        if self.n == 0:
            return math.nan
        rank = max(1, math.ceil(self.n * p / 100.0))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return min(max(self._bin_mid(i), self.min), self.max)
        return self.max  # unreachable: counts sum to n

    def percentiles(self, ps: Iterable[float] = (50.0, 95.0, 99.0),
                    ) -> dict[float, float]:
        return {p: self.percentile(p) for p in ps}

    def merge(self, other: "Histogram") -> None:
        if self._layout() != other._layout():
            raise ValueError(
                f"histogram layouts differ: {self._layout()} vs "
                f"{other._layout()} — merging would need resampling")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def copy(self) -> "Histogram":
        h = Histogram(self.lo, self.hi, self.bins_per_decade)
        h.merge(self)
        return h

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        if self.n == 0:
            return "Histogram(empty)"
        return (f"Histogram(n={self.n}, p50={self.percentile(50):.3g}, "
                f"p95={self.percentile(95):.3g}, "
                f"p99={self.percentile(99):.3g})")


def _key(name: str, labels: Mapping[str, Any]) -> tuple:
    return (name,) + tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Name+label-keyed counters and histograms, mergeable across workers."""

    def __init__(self) -> None:
        self._counters: dict[tuple, Counter] = {}
        self._hists: dict[tuple, Histogram] = {}

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._hists.setdefault(_key(name, labels), Histogram())

    def counters(self) -> dict[tuple, int]:
        return {k: c.value for k, c in sorted(self._counters.items())}

    def histograms(self) -> dict[tuple, Histogram]:
        return dict(self._hists)

    def merge(self, other: "MetricsRegistry") -> None:
        for k, c in other._counters.items():
            self._counters.setdefault(k, Counter()).merge(c)
        for k, h in other._hists.items():
            if k in self._hists:
                self._hists[k].merge(h)
            else:
                self._hists[k] = h.copy()

    def reset(self) -> None:
        self._counters.clear()
        self._hists.clear()

    def summary(self) -> str:
        rows = ["metrics:"]
        for k, v in self.counters().items():
            name = k[0] + "".join(f" {a}={b}" for a, b in k[1:])
            rows.append(f"  {name}: {v}")
        for k, h in sorted(self._hists.items()):
            name = k[0] + "".join(f" {a}={b}" for a, b in k[1:])
            rows.append(f"  {name}: {h!r}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# modeled-vs-measured drift
# ---------------------------------------------------------------------------

# measured span attr -> the modeled StepCost fields it is judged against
_STAGE_MODEL = {
    "hold": ("modeled_hold_s",),
    "stage": ("modeled_dac_s", "modeled_interface_s"),
    "compute": ("modeled_analog_s", "modeled_adc_s", "modeled_host_s"),
}
STAGES = ("hold", "stage", "compute", "total")


@dataclasses.dataclass(frozen=True)
class StageDrift:
    """One stage's modeled-vs-measured join across traced invocations."""

    stage: str
    modeled_s: float
    measured_s: float

    @property
    def drift(self) -> float:
        """measured / modeled; inf when unmodeled time was measured, NaN
        when the stage had neither modeled nor measured time."""
        if self.modeled_s > 0.0:
            return self.measured_s / self.modeled_s
        return math.inf if self.measured_s > 0.0 else math.nan

    @property
    def log_drift(self) -> float:
        d = self.drift
        if math.isnan(d):
            return 0.0
        if d == 0.0 or math.isinf(d):
            return math.inf
        return abs(math.log(d))


@dataclasses.dataclass
class DriftReport:
    """Per-stage modeled-vs-measured attribution over traced invocations."""

    stages: dict[str, StageDrift]
    invocations: int          # modeled invocations joined
    unmodeled: int            # invocations with no StepCost (host-like)
    per_device_s: dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def worst(self) -> StageDrift | None:
        """The worst-drifting stage (largest ``|log(drift)|``); ``total``
        is excluded — it aggregates the others and would mask which stage
        actually diverged."""
        rows = [d for s, d in self.stages.items()
                if s != "total" and not math.isnan(d.drift)]
        if not rows:
            return None
        return max(rows, key=lambda d: d.log_drift)

    def drift_for(self, stage: str) -> float:
        d = self.stages.get(stage)
        return math.nan if d is None else d.drift

    def to_json(self) -> dict:
        out: dict[str, Any] = {
            "invocations": self.invocations,
            "unmodeled": self.unmodeled,
            "stages": {s: {"modeled_s": d.modeled_s,
                           "measured_s": d.measured_s,
                           "drift": None if math.isnan(d.drift) else (
                               "inf" if math.isinf(d.drift) else d.drift)}
                       for s, d in self.stages.items()},
        }
        w = self.worst
        if w is not None:
            out["worst_stage"] = w.stage
        if self.per_device_s:
            out["per_device_stage_s"] = {str(i): v for i, v
                                         in sorted(self.per_device_s.items())}
        return out

    def table(self) -> str:
        rows = [f"drift (modeled vs measured, {self.invocations} "
                f"invocations):",
                f"  {'stage':>8}  {'modeled':>10}  {'measured':>10}  "
                f"{'drift':>7}"]
        for s in STAGES:
            d = self.stages.get(s)
            if d is None:
                continue
            drift = d.drift
            tag = "   --" if math.isnan(drift) else (
                "  inf" if math.isinf(drift) else f"{drift:7.3f}")
            rows.append(f"  {s:>8}  {d.modeled_s:10.3e}  "
                        f"{d.measured_s:10.3e}  {tag}")
        w = self.worst
        if w is not None:
            rows.append(f"  worst: {w.stage} (drift "
                        f"{'inf' if math.isinf(w.drift) else f'{w.drift:.3f}'}"
                        ")")
        if self.per_device_s:
            parts = [f"d{i}: {v:.3e}s"
                     for i, v in sorted(self.per_device_s.items())]
            rows.append("  per-device scatter staging: " + "; ".join(parts))
        return "\n".join(rows)


def drift_report(spans, category: str | None = None,
                 backend: str | None = None) -> DriftReport:
    """Join traced invocation spans against the modeled ``batched_step_cost``
    decomposition they were priced with (see module docstring for the
    stage mapping).  ``spans`` is any iterable of completed
    :class:`~repro.runtime.tracing.Span` objects — typically
    ``tracer.spans()``; pass ``category``/``backend`` to restrict the join.
    Invocations served by host-like backends carry no modeled cost and are
    counted in ``unmodeled`` rather than polluting the drift ratios."""
    spans = list(spans)
    modeled = {s: 0.0 for s in STAGES}
    measured = {s: 0.0 for s in STAGES}
    n = unmodeled = 0
    per_device: dict[int, float] = {}
    inv_ids = set()
    for s in spans:
        if s.name != "invocation" or s.t1 is None:
            continue
        if category is not None and s.attrs.get("category") != category:
            continue
        if backend is not None and s.attrs.get("backend") != backend:
            continue
        inv_ids.add(s.span_id)
        if "modeled_total_s" not in s.attrs:
            unmodeled += 1
            continue
        n += 1
        for stage, fields in _STAGE_MODEL.items():
            modeled[stage] += sum(float(s.attrs.get(f, 0.0)) for f in fields)
        modeled["total"] += float(s.attrs["modeled_total_s"])
        measured["hold"] += float(s.attrs.get("hold_s", 0.0))
        measured["stage"] += float(s.attrs.get("stage_s", 0.0))
        measured["compute"] += float(s.attrs.get("compute_s", 0.0))
        measured["total"] += (float(s.attrs.get("wall_s", 0.0))
                              + float(s.attrs.get("hold_s", 0.0)))
    by_id = {s.span_id: s for s in spans}

    def _inv_ancestor(s) -> int | None:
        hops = 0
        while s.parent_id is not None and hops < 16:
            s = by_id.get(s.parent_id)
            if s is None:
                return None
            if s.name == "invocation":
                return s.span_id
            hops += 1
        return None

    for s in spans:  # per-device scatter staging under the joined invocations
        if s.name != "scatter" or s.t1 is None:
            continue
        if _inv_ancestor(s) not in inv_ids:
            continue
        d = int(s.attrs.get("device", 0))
        per_device[d] = per_device.get(d, 0.0) + s.duration_s
    stages = {s: StageDrift(s, modeled[s], measured[s]) for s in STAGES}
    return DriftReport(stages=stages, invocations=n, unmodeled=unmodeled,
                       per_device_s=per_device)
