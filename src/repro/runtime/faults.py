"""Fault injection + fault handling for the offload boundary.

Real analog hardware makes the conversion boundary *unreliable*, not just
expensive: converters drift out of their ENOB budget, apertures mis-range,
links drop dispatches, devices stall or disappear.  This module gives the
runtime both halves of that story:

**Injection** — :class:`ChaosBackend` wraps any registered backend and
perturbs its dispatches according to a deterministic, seeded
:class:`FaultSchedule`:

  ``error``        the dispatch raises :class:`TransientDispatchError`
                   before touching the inner backend (a dropped link
                   handshake / failed launch).
  ``straggle``     the dispatch completes but takes ``straggle_s`` longer
                   (a slow host, a congested link) — injected through the
                   executor's clock (``ManualClock.advance`` in tests, a
                   real ``time.sleep`` otherwise), so straggler detection
                   is exactly as deterministic as the clock.
  ``drift``        the inner result is scaled by ``drift_gain`` (a DAC
                   mis-range / detector drift): numerically wrong in a way
                   only the :class:`~repro.runtime.fidelity.FidelityChecker`
                   shadow can catch.
  ``device_loss``  under sharded dispatch (``ctx.n_devices > 1``) one
                   logical device is marked lost via ``ctx.lost_devices``
                   and the sharded backend's shard on it raises
                   :class:`DeviceLostError` mid-scatter; unsharded, the
                   whole dispatch raises it.

**Handling** — the pieces :class:`~repro.runtime.executor.OffloadExecutor`
and :class:`~repro.runtime.sharded.ShardedOpticalBackend` thread through
every dispatch:

  :class:`RetryPolicy`       per-dispatch fault policy: max attempts,
                             exponential backoff with seeded jitter (slept
                             through the injected clock), the fallback
                             backend for graceful degradation, and the
                             straggler-deadline / quarantine-window knobs.
  :class:`DispatchWatchdog`  keyed :class:`TrailingMedianDeadline`
                             detectors (shared with the training runner's
                             fault story): a dispatch whose wall exceeds
                             ``factor x max(trailing median, modeled
                             batched_step_cost wall, floor)`` is a
                             straggler.
  :class:`Quarantine`        time-windowed exclusion of failing devices
                             (``("device", d)``) and categories
                             (``("category", cat)``): quarantined keys are
                             skipped by sharded scatter / rerouted to the
                             fallback backend; after the window a
                             *probation* period follows — re-offending on
                             probation doubles the next window, staying
                             clean resets it.

The equivalence invariant under faults: every submitted frame retires, in
submit order, with results equal to the fault-free run of the same backend
(bit-for-bit on digital backends; frames served by the host fallback are
bit-equal to the looped host baseline).  Faults change *when and where* a
frame executes, never *what* it returns.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Mapping, Sequence

from repro.distributed.straggler import TrailingMedianDeadline
from repro.runtime.backends import (
    BackendContext,
    ExecutionBackend,
    get_backend,
    register_backend,
)

__all__ = [
    "Fault",
    "FaultError",
    "TransientDispatchError",
    "DeviceLostError",
    "FaultSchedule",
    "ChaosBackend",
    "register_chaos",
    "RetryPolicy",
    "DispatchWatchdog",
    "QuarantineEvent",
    "Quarantine",
    "advance_or_sleep",
]

FAULT_KINDS = ("error", "straggle", "drift", "device_loss")


class FaultError(RuntimeError):
    """Base of every injectable/handleable dispatch fault.

    The executor's retry policy catches exactly this hierarchy: anything
    else a backend raises is a programming error and propagates."""

    kind = "fault"


class TransientDispatchError(FaultError):
    """A dispatch that failed before producing results (dropped handshake,
    failed launch) — retryable on the same backend."""

    kind = "error"


class DeviceLostError(FaultError):
    """A (logical) device disappeared mid-dispatch."""

    kind = "device_loss"

    def __init__(self, device: int, msg: str | None = None) -> None:
        super().__init__(msg or f"device {device} lost mid-dispatch")
        self.device = int(device)


def advance_or_sleep(clock: Callable[[], float] | None, dt_s: float) -> None:
    """Let ``dt_s`` pass on whatever timebase the runtime runs on: a
    ``ManualClock`` is advanced (deterministic tests/benches — no real
    sleeping), anything else costs a real ``time.sleep``."""
    if dt_s <= 0.0:
        return
    adv = getattr(clock, "advance", None)
    if adv is not None:
        adv(dt_s)
    else:
        time.sleep(dt_s)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault: what goes wrong with one dispatch."""

    kind: str                # one of FAULT_KINDS
    delay_s: float = 0.0     # straggle: extra dispatch latency
    gain: float = 1.0        # drift: multiplicative result corruption
    device: int = 0          # device_loss: which logical device drops

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")


class FaultSchedule:
    """Deterministic per-dispatch fault sequence.

    Two authoring modes, composable:

    * **seeded rate**: each dispatch draws from a ``random.Random(seed)``
      stream; with probability ``rate`` it gets a fault of a uniformly
      chosen kind from ``kinds``.  The draw sequence depends only on
      ``(seed, dispatch index)``, so two identical runs fault identically.
    * **scripted**: ``script={dispatch_index: Fault(...)}`` pins exact
      faults to exact dispatches (the unit-test mode); scripted entries
      take precedence over the rate draw at their index.

    Schedules are stateful (they count dispatches); :meth:`fresh` returns
    an unconsumed copy with the same parameters — the registration helper
    hands every backend instantiation its own copy, so executors never
    share (and therefore never race on) a draw stream.
    """

    def __init__(self, rate: float = 0.0, *, seed: int = 0,
                 kinds: Sequence[str] = FAULT_KINDS,
                 straggle_s: float = 0.25, drift_gain: float = 8.0,
                 script: Mapping[int, Fault] | None = None) -> None:
        if not 0.0 <= rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self.rate = float(rate)
        self.seed = int(seed)
        self.kinds = tuple(kinds)
        self.straggle_s = float(straggle_s)
        self.drift_gain = float(drift_gain)
        self.script = dict(script or {})
        self.index = 0          # dispatches drawn so far
        self.injected = 0       # faults actually handed out
        self._rng = random.Random(self.seed)

    def fresh(self) -> "FaultSchedule":
        """An unconsumed copy: same parameters, rewound draw stream."""
        return FaultSchedule(self.rate, seed=self.seed, kinds=self.kinds,
                             straggle_s=self.straggle_s,
                             drift_gain=self.drift_gain, script=self.script)

    def draw(self) -> Fault | None:
        """The fault (or None) for the next dispatch."""
        i = self.index
        self.index += 1
        # the rate draw happens unconditionally so scripted entries do not
        # shift the stream for later indices
        hit = self.rate > 0.0 and self._rng.random() < self.rate
        if i in self.script:
            self.injected += 1
            return self.script[i]
        if not hit or not self.kinds:
            return None
        kind = self._rng.choice(self.kinds)
        self.injected += 1
        if kind == "straggle":
            return Fault("straggle", delay_s=self.straggle_s)
        if kind == "drift":
            return Fault("drift", gain=self.drift_gain)
        if kind == "device_loss":
            return Fault("device_loss", device=self._rng.randrange(1 << 16))
        return Fault("error")


class ChaosBackend(ExecutionBackend):
    """Any registered backend, with a :class:`FaultSchedule` between the
    executor and it.

    Transparent when the schedule draws nothing (same results, same
    modeled cost, same device samples — the < 2% overhead contract);
    otherwise the drawn fault is applied exactly as documented in the
    module docstring.  ``inner_name`` exposes the wrapped backend's public
    name so the executor's fidelity shadowing and quarantine rerouting
    treat a chaos-wrapped optical backend like the optical backend itself.
    """

    def __init__(self, inner: str | ExecutionBackend = "optical-sim",
                 schedule: FaultSchedule | None = None,
                 name: str | None = None) -> None:
        self.inner: ExecutionBackend = (get_backend(inner)
                                        if isinstance(inner, str) else inner)
        self.inner_name = self.inner.name
        self.name = name or f"chaos-{self.inner.name}"
        self.schedule = schedule or FaultSchedule()

    def supports(self, category: str, ctx: BackendContext) -> bool:
        return self.inner.supports(category, ctx)

    def take_device_samples(self):
        take = getattr(self.inner, "take_device_samples", None)
        return take() if take is not None else None

    def run(self, category, xs, ctx, *, kernel=None, weights=None):
        fault = self.schedule.draw()
        if fault is None:
            return self.inner.run(category, xs, ctx, kernel=kernel,
                                  weights=weights)
        if fault.kind == "error":
            raise TransientDispatchError(
                f"injected dispatch fault (index {self.schedule.index - 1})")
        if fault.kind == "device_loss":
            n = max(1, int(getattr(ctx, "n_devices", 1)))
            if n > 1:
                # sharded dispatch: mark one logical device lost; the
                # sharded backend's scatter loop raises DeviceLostError
                # for the shard placed on it and recovers on a survivor
                ctx.lost_devices = frozenset({fault.device % n})
                try:
                    return self.inner.run(category, xs, ctx, kernel=kernel,
                                          weights=weights)
                finally:
                    ctx.lost_devices = frozenset()
            raise DeviceLostError(0)
        if fault.kind == "straggle":
            outs, cost = self.inner.run(category, xs, ctx, kernel=kernel,
                                        weights=weights)
            advance_or_sleep(getattr(ctx, "clock", None), fault.delay_s)
            return outs, cost
        # drift: results come back numerically wrong (DAC mis-range /
        # detector drift) — only the fidelity shadow can tell
        outs, cost = self.inner.run(category, xs, ctx, kernel=kernel,
                                    weights=weights)
        return [o * fault.gain for o in outs], cost


def register_chaos(inner: str = "optical-sim", *, name: str | None = None,
                   schedule: FaultSchedule | None = None,
                   **schedule_kwargs) -> str:
    """Register a chaos-wrapped backend; returns its registered name.

    ``schedule_kwargs`` build a :class:`FaultSchedule` when ``schedule``
    is not given.  Every ``get_backend`` instantiation receives a
    :meth:`FaultSchedule.fresh` copy, so each executor's fault sequence is
    deterministic from dispatch 0 and independent of other executors.
    """
    sched = schedule if schedule is not None else FaultSchedule(
        **schedule_kwargs)
    reg_name = name or f"chaos-{inner}"

    def factory() -> ChaosBackend:
        return ChaosBackend(inner, schedule=sched.fresh(), name=reg_name)

    register_backend(reg_name, factory)
    return reg_name


@dataclasses.dataclass
class RetryPolicy:
    """Per-dispatch fault policy the executor runs every invocation under.

    A dispatch that raises :class:`FaultError` is retried on the same
    backend up to ``max_attempts`` total attempts, sleeping an
    exponentially growing, jittered backoff between attempts (through the
    injected clock — a ManualClock makes the whole sequence
    deterministic).  When every attempt faults, the dispatch **degrades
    gracefully**: it re-runs on ``fallback`` (the host backend — always
    correct, never faulted) and the category is quarantined for
    ``quarantine_s`` so subsequent dispatches reroute immediately instead
    of re-paying the retry ladder.

    The straggler knobs configure the :class:`DispatchWatchdog` deadline
    (``factor x max(trailing median, modeled wall, floor)``) and the
    per-device quarantine patience used by sharded dispatch.
    """

    max_attempts: int = 3
    backoff_s: float = 1e-3          # first backoff
    backoff_factor: float = 2.0      # growth per attempt
    jitter: float = 0.5              # uniform [0, jitter] multiplier on top
    seed: int = 0                    # jitter stream seed
    fallback: str = "host"
    straggler_factor: float = 3.0
    straggler_window: int = 32
    straggler_floor_s: float = 0.05
    straggler_patience: int = 3
    quarantine_s: float = 0.25
    probation_s: float = 0.25

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0.0 or self.backoff_factor < 1.0:
            raise ValueError("backoff_s >= 0 and backoff_factor >= 1 required")
        if self.jitter < 0.0:
            raise ValueError("jitter must be >= 0")
        self._rng = random.Random(self.seed)

    def backoff_for(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based), jittered so
        concurrent retriers do not re-collide in lockstep."""
        base = self.backoff_s * self.backoff_factor ** (attempt - 1)
        return base * (1.0 + self.jitter * self._rng.random())


class DispatchWatchdog:
    """Keyed straggler detectors over dispatch wall times.

    One :class:`TrailingMedianDeadline` per key — the executor keys by
    ``(category, backend)``, the sharded backend by ``("device", name,
    d)`` — so one traffic class's healthy baseline never judges another's.
    """

    def __init__(self, *, factor: float = 3.0, window: int = 32,
                 floor_s: float = 0.05, patience: int = 3) -> None:
        self.factor = factor
        self.window = window
        self.floor_s = floor_s
        self.patience = patience
        self._detectors: dict = {}

    def _detector(self, key) -> TrailingMedianDeadline:
        det = self._detectors.get(key)
        if det is None:
            det = self._detectors[key] = TrailingMedianDeadline(
                factor=self.factor, window=self.window,
                floor_s=self.floor_s, patience=self.patience)
        return det

    def deadline_s(self, key, base_s: float | None = None) -> float:
        return self._detector(key).deadline_s(base_s)

    def observe(self, key, dt_s: float, base_s: float | None = None) -> bool:
        """Score one dispatch wall time; True means straggler."""
        return self._detector(key).observe(dt_s, base_s)


@dataclasses.dataclass(frozen=True)
class QuarantineEvent:
    """One quarantine decision, for observability and tests."""

    key: tuple
    reason: str
    t: float
    until: float
    probation_until: float
    level: int


class Quarantine:
    """Time-windowed exclusion of failing devices and categories.

    Lifecycle of a key (``("device", d)`` or ``("category", cat)``):

      healthy -> quarantined (``window_s * 2**level``) -> **probation**
      (``probation_s``) -> healthy

    Re-offending *during probation* escalates ``level`` (doubling the
    next window); surviving probation clean resets it.  Straggler strikes
    accumulate per key via :meth:`note_straggle` and quarantine after
    ``patience`` consecutive ones; :meth:`note_healthy` forgives the
    streak.  All time comes from the caller's clock, so the whole
    lifecycle is deterministic under a ManualClock.
    """

    def __init__(self, *, window_s: float = 0.25,
                 probation_s: float = 0.25, patience: int = 3) -> None:
        if window_s <= 0.0 or probation_s < 0.0:
            raise ValueError("window_s > 0 and probation_s >= 0 required")
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.window_s = float(window_s)
        self.probation_s = float(probation_s)
        self.patience = int(patience)
        self.events: list[QuarantineEvent] = []
        self._until: dict[tuple, float] = {}
        self._probation_until: dict[tuple, float] = {}
        self._level: dict[tuple, int] = {}
        self._strikes: dict[tuple, int] = {}

    def is_quarantined(self, key: tuple, now: float) -> bool:
        return now < self._until.get(key, float("-inf"))

    def on_probation(self, key: tuple, now: float) -> bool:
        return (not self.is_quarantined(key, now)
                and now < self._probation_until.get(key, float("-inf")))

    def until(self, key: tuple) -> float | None:
        """End of ``key``'s latest quarantine window (None if never)."""
        return self._until.get(key)

    def quarantine(self, key: tuple, now: float,
                   reason: str = "fault") -> QuarantineEvent:
        """Exclude ``key`` starting ``now``; returns the decision.

        A key quarantined while on probation is a repeat offender: its
        window doubles.  A key whose probation expired cleanly starts over
        at the base window.
        """
        level = self._level.get(key, 0) + 1 if self.on_probation(key, now) \
            else 0
        until = now + self.window_s * (2 ** level)
        self._until[key] = until
        self._probation_until[key] = until + self.probation_s
        self._level[key] = level
        self._strikes[key] = 0
        ev = QuarantineEvent(key=key, reason=reason, t=now, until=until,
                             probation_until=until + self.probation_s,
                             level=level)
        self.events.append(ev)
        return ev

    def note_straggle(self, key: tuple, now: float) -> QuarantineEvent | None:
        """One straggler strike against ``key``; quarantines (and returns
        the event) when the streak reaches ``patience``."""
        if self.is_quarantined(key, now):
            return None
        strikes = self._strikes.get(key, 0) + 1
        if strikes >= self.patience:
            return self.quarantine(key, now, reason="straggler")
        self._strikes[key] = strikes
        return None

    def note_healthy(self, key: tuple) -> None:
        """A healthy observation forgives the straggler streak."""
        self._strikes[key] = 0

    def active(self, now: float) -> tuple[tuple, ...]:
        """Keys currently quarantined, sorted."""
        return tuple(sorted(k for k, t in self._until.items() if now < t))

    def active_device_count(self, now: float) -> int:
        """How many logical devices are currently quarantined (the router
        shrinks the sharded fan-out by this)."""
        return sum(1 for k in self.active(now) if k and k[0] == "device")

    def summary(self, now: float) -> str:
        act = self.active(now)
        rows = [f"quarantine: {len(act)} active, "
                f"{len(self.events)} events"]
        for k in act:
            rows.append(f"  {k}: until={self._until[k]:.3f}s "
                        f"level={self._level.get(k, 0)}")
        return "\n".join(rows)
