"""Boundary-attributed span tracing: where did a flush's wall time go?

The paper's whole argument is an *attribution* claim — the DAC/ADC
conversion boundary, not the analog core, bounds end-to-end speedup — yet
``RuntimeTelemetry`` only accumulates per-(category, backend) totals.  This
module adds the missing axis: one span tree per batched invocation, so a
single flush decomposes into

    submit -> held(reason) -> release(full|due|futile) -> tile[t]
           -> stage (host staging + DAC-prep + dispatch)
           -> compute (in-flight analog propagation + ADC/readout)
           -> fidelity-shadow

with sharded dispatch additionally emitting one ``scatter`` child span per
device, so host-side scatter/gather staging — the ROADMAP's suspect for the
sharded wall regression — is finally visible rather than inferred.

Fault handling (``repro.runtime.faults``) adds its own span vocabulary on
the ``sched`` and per-device lanes: ``fault`` instants (kind = error /
straggle / drift / device_loss), ``retry`` spans covering each backoff
window, ``fallback`` instants marking graceful degradation to the host
backend, and ``quarantine`` spans covering a device's or category's
exclusion window.  The operand residency cache
(``repro.runtime.residency``) emits ``cache`` instants on the host lane
(kind = hit / miss / eviction / invalidation, with the operand category
and byte count), so every boundary crossing the cache *avoided* is as
visible as the ones that were paid.  None of these carry charged time —
the reconcile / drift contract reads only ``invocation`` trees — so
fault and cache observability can never unbalance the wall accounting.

Design constraints (all load-bearing):

* **Zero dependencies, zero default overhead.**  Tracing is opt-in
  (``OffloadExecutor(tracer=...)``); every instrumentation site guards on
  ``tracer is not None``, so the default path adds nothing but an
  attribute read.
* **Injectable clock.**  ``Tracer(clock=ManualClock())`` shares the
  executor's manual timebase, so tests assert span durations *exactly*
  (a group held 30 ms under a ManualClock yields a held span of exactly
  0.030 s).  The default is ``time.perf_counter`` — the same timebase the
  executor's wall accounting uses.
* **Thread-safe ring buffer.**  Spans land in a bounded ``deque``
  (``capacity`` completed spans; the oldest drop and ``dropped`` counts
  them), guarded by a lock, so a long-running serving loop can leave the
  tracer attached without unbounded growth.
* **Charged-time semantics.**  Leaf ``stage``/``compute`` spans mirror the
  executor's retirement accounting (charge from where the previous
  retirement ended, never bill pipeline overlap twice), so per-stage sums
  reconcile with the measured flush wall — the invariant the bench gate
  and the Perfetto export both rely on.

Consumers: :mod:`repro.runtime.trace_export` (Chrome/Perfetto
``trace_event`` JSON), :func:`repro.runtime.metrics.drift_report`
(modeled-vs-measured per stage), and the trace summary printed by
``examples/optical_offload.py``.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Iterator

from repro.runtime.metrics import MetricsRegistry

__all__ = ["Span", "Tracer"]


@dataclasses.dataclass
class Span:
    """One timed interval on a lane of the runtime.

    ``kind`` distinguishes rendering semantics:
      ``sync``     a lexically scoped duration (Perfetto "complete" slice);
                   sync spans on one lane either nest or do not overlap.
      ``async``    a container that outlives its dispatch scope (release,
                   invocation, held) — may overlap other containers on the
                   same lane, exported as async begin/end events.
      ``instant``  a point event (submit).
    """

    name: str
    span_id: int
    trace_id: int
    parent_id: int | None
    lane: str
    kind: str = "sync"
    t0: float = 0.0
    t1: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def annotate(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self


class Tracer:
    """Thread-safe span recorder with an injectable clock.

    Args:
      clock: timebase for span timestamps.  Pass the executor's
        ``ManualClock`` for exact assertions; the default
        ``time.perf_counter`` matches the executor's wall accounting.
      capacity: completed spans retained (ring buffer); the oldest are
        dropped beyond it and counted in :attr:`dropped`.

    Spans parent two ways: explicitly (``parent=``) or lexically — the
    :meth:`span` context manager keeps a per-thread active-span stack, so
    a backend that opens spans inside an instrumented dispatch nests under
    the invocation without the executor threading handles through every
    call signature.  :attr:`metrics` is a :class:`MetricsRegistry` the
    instrumented runtime feeds alongside spans (release-reason counters,
    span-latency histograms).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 65536) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._clock = clock
        self.capacity = capacity
        self._lock = threading.Lock()
        self._done: collections.deque[Span] = collections.deque()
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self.dropped = 0
        self.metrics = MetricsRegistry()

    # -- timebase --------------------------------------------------------------
    def now(self) -> float:
        return self._clock()

    # -- the active-span stack (per thread) ------------------------------------
    def _stack(self) -> list[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current(self) -> Span | None:
        """The innermost lexically active span on this thread (if any)."""
        st = self._stack()
        return st[-1] if st else None

    # -- span creation ---------------------------------------------------------
    def _new(self, name: str, lane: str, kind: str, t0: float,
             parent: "Span | int | None", attrs: dict[str, Any]) -> Span:
        if isinstance(parent, Span):
            pid, tid = parent.span_id, parent.trace_id
        elif parent is not None:
            pid, tid = int(parent), None
        else:
            active = self.current()
            pid = active.span_id if active is not None else None
            tid = active.trace_id if active is not None else None
        with self._lock:
            sid = next(self._ids)
        if tid is None:
            tid = sid if pid is None else pid
        return Span(name=name, span_id=sid, trace_id=tid, parent_id=pid,
                    lane=lane, kind=kind, t0=t0, attrs=dict(attrs))

    def _finish(self, span: Span) -> Span:
        with self._lock:
            if len(self._done) >= self.capacity:
                self._done.popleft()
                self.dropped += 1
            self._done.append(span)
        return span

    def begin(self, name: str, *, lane: str = "host", kind: str = "async",
              parent: "Span | int | None" = None, **attrs: Any) -> Span:
        """Open a non-lexical span (ends later via :meth:`end` — the
        dispatch->retire pattern).  Not pushed on the lexical stack."""
        return self._new(name, lane, kind, self.now(), parent, attrs)

    def end(self, span: Span, t1: float | None = None) -> Span:
        """Close a span opened with :meth:`begin` and commit it."""
        span.t1 = self.now() if t1 is None else t1
        if span.t1 < span.t0:  # a clock respecting causality only
            span.t1 = span.t0
        return self._finish(span)

    @contextlib.contextmanager
    def span(self, name: str, *, lane: str = "host", kind: str = "sync",
             parent: "Span | int | None" = None,
             **attrs: Any) -> Iterator[Span]:
        """Lexically scoped span; children opened inside nest under it."""
        s = self._new(name, lane, kind, self.now(), parent, attrs)
        st = self._stack()
        st.append(s)
        try:
            yield s
        finally:
            st.pop()
            self.end(s)

    def instant(self, name: str, *, lane: str = "host",
                parent: "Span | int | None" = None, **attrs: Any) -> Span:
        """A point event (t0 == t1)."""
        t = self.now()
        s = self._new(name, lane, "instant", t, parent, attrs)
        s.t1 = t
        return self._finish(s)

    def record(self, name: str, t0: float, t1: float, *, lane: str = "host",
               kind: str = "sync", parent: "Span | int | None" = None,
               **attrs: Any) -> Span:
        """Commit a retrospective span whose window is already known (the
        executor learns an invocation's charged compute window only at
        retirement)."""
        s = self._new(name, lane, kind, t0, parent, attrs)
        s.t1 = max(t1, t0)
        return self._finish(s)

    # -- views -----------------------------------------------------------------
    def spans(self) -> list[Span]:
        """Snapshot of completed spans in completion order."""
        with self._lock:
            return list(self._done)

    def find(self, name: str | None = None,
             lane: str | None = None) -> list[Span]:
        return [s for s in self.spans()
                if (name is None or s.name == name)
                and (lane is None or s.lane == lane)]

    def children(self, parent: Span) -> list[Span]:
        return [s for s in self.spans() if s.parent_id == parent.span_id]

    def clear(self) -> None:
        with self._lock:
            self._done.clear()
            self.dropped = 0
