"""Memory-budgeted tiled dispatch: how big a batched stack may get.

Batching amortizes the conversion boundary (one handshake, one settle, one
lane-ceil residue per invocation instead of per call), but the *stack* that
buys the amortization is a real allocation on the digital side of the
boundary: a ``(K, H, W)`` flush group materializes K frames plus the
pipeline's complex intermediates before anything crosses the DAC.  At
128x128 that working set is noise; at 512x512 and K=16 it is ~64 MB — it
falls out of the CPU's last-level cache off-TPU (a monolithic batched FFT
measures *slower* than a Python loop of singles) and exceeds a TPU core's
~16 MB VMEM budget on-chip.  The photonic case studies make the same
point from the hardware side: sustained throughput is set by how operands
are *staged* into the analog aperture, not by the transform itself.

This module decides the staging granularity from a per-device byte budget:

  :class:`MemoryBudget`   where the bytes come from — VMEM-derived on TPU,
                          LLC-derived off-TPU, or operator-pinned — and how
                          many frames of a given working set fit inside it.
  :func:`choose_tile`     pick ``tile_k``: the deepest sub-stack whose
                          working set (times the pipeline depth — two tiles
                          are in flight under double buffering) fits the
                          budget.  A released flush group of K calls then
                          streams through the executor's existing two-deep
                          async pipeline as ``ceil(K / tile_k)``
                          sub-invocations with write/analog/read overlap
                          *between* tiles, instead of one monolithic stack.
  :func:`choose_blocks`   pick the batched Pallas DFT grid's block sizes
                          ``(bb, bm, bk, bn)`` from the VMEM budget instead
                          of the fixed 128-cube defaults.

``tile_k = 1`` degenerates to the looped regime (one call per crossing),
``tile_k >= K`` to the monolithic one — both are valid points on the same
curve, which is exactly why the runtime-equivalence invariant extends to
tiling: tiled == monolithic == looped on every backend, ragged tails
included (``tests/test_tiling.py``).

The same model is consumed by the cost side: both accelerator families'
``batched_step_cost`` accept ``tile_k=`` / ``mem_budget=`` (duck-typed via
:meth:`MemoryBudget.tile_for`, so ``repro.core`` never imports this
package) and price the tiled stream as executed — every tile pays its own
per-invocation prologue, the tiles overlap two-deep.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import subprocess

# One definition of the group split, shared with both accelerator
# families' cost models: dispatch, warm(), and batched_step_cost(tile_k=)
# all slice a group identically (re-exported here for runtime callers).
from repro.core.accelerator import tile_sizes

__all__ = [
    "BYTES_F32",
    "TPU_VMEM_BYTES",
    "LLC_FALLBACK_BYTES",
    "MemoryBudget",
    "TilePlan",
    "BlockPlan",
    "choose_tile",
    "choose_blocks",
    "tile_sizes",
]

BYTES_F32 = 4

# A TPU core's on-chip vector memory (the Pallas guide's ~16 MB/core): the
# stack, the (re, im) stage-1 intermediates, and the accumulator scratch
# all want to live here while a batched DFT invocation runs.
TPU_VMEM_BYTES = 16 * 1024 * 1024

# Off-TPU fallback when the platform exposes no cache topology: a
# mainstream server LLC.  Detection prefers the real number (sysfs /
# getconf) — the fallback only anchors containers that hide both.
LLC_FALLBACK_BYTES = 32 * 1024 * 1024

# Working-set multiplier per boundary sample: one float32 in, one float32
# out, plus ~two floats of complex/stage intermediates per sample while
# the batched pipeline runs (fft carries (re, im) stage-1 planes; conv a
# complex Fourier product; matmul a differential readout pair).  A model,
# not a measurement — telemetry records the *measured* bytes/frame of real
# traffic (``RuntimeTelemetry.bytes_per_frame``) so a replan can see how
# tight the model ran.
_INTERMEDIATE_FACTOR = 2.0


def _parse_size(text: str) -> int:
    """Parse a sysfs cache size string ('56623104', '32768K', '54M')."""
    text = text.strip()
    mult = 1
    if text[-1:].upper() in ("K", "M", "G"):
        mult = {"K": 1024, "M": 1024 ** 2, "G": 1024 ** 3}[text[-1].upper()]
        text = text[:-1]
    return int(text) * mult


@functools.lru_cache(maxsize=1)
def _llc_bytes() -> int:
    """Last-level cache size in bytes (largest of L3/L2 reported).

    Tries sysfs, then ``getconf LEVEL{3,2}_CACHE_SIZE`` (glibc reads the
    same CPUID leaves sysfs exposes; containers often mount neither), then
    falls back to :data:`LLC_FALLBACK_BYTES`.
    """
    for idx in (3, 2):
        try:
            with open("/sys/devices/system/cpu/cpu0/cache/"
                      f"index{idx}/size") as f:
                size = _parse_size(f.read())
            if size > 0:
                return size
        except (OSError, ValueError):
            pass
    for level in ("LEVEL3_CACHE_SIZE", "LEVEL2_CACHE_SIZE"):
        try:
            out = subprocess.run(["getconf", level], capture_output=True,
                                 text=True, timeout=5)
            size = int(out.stdout.strip() or 0)
            if size > 0:
                return size
        except (OSError, ValueError, subprocess.SubprocessError):
            pass
    return LLC_FALLBACK_BYTES


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """A per-device byte budget for staging batched operand stacks.

    Attributes:
      bytes_limit: total budgeted bytes; ``0`` (or negative) means
        *unlimited* — tiling is disabled and every group dispatches
        monolithically, the pre-tiling behavior.
      source: where the number came from (``"vmem"`` / ``"llc"`` /
        ``"manual"`` / ``"unlimited"``) — stamped into benchmarks so a
        recorded ``tile_k`` stays interpretable across machines.
      reserve: fraction of ``bytes_limit`` actually spendable on operand
        staging.  The rest is headroom for everything the model does not
        count — XLA temporaries, the host program, other cores sharing the
        LLC.  ``spendable = bytes_limit * reserve``.
    """

    bytes_limit: int
    source: str = "manual"
    reserve: float = 0.5

    def __post_init__(self) -> None:
        if not 0.0 < self.reserve <= 1.0:
            raise ValueError("reserve must be in (0, 1]")

    @classmethod
    def detect(cls, platform: str | None = None) -> "MemoryBudget":
        """The platform's budget: VMEM-derived on TPU, LLC-derived off it.

        On TPU the binding constraint is the ~16 MB/core VMEM the batched
        Pallas pipeline tiles through (reserve 0.75: block scratch is
        already counted, only compiler temporaries need headroom).  Off
        TPU it is the last-level cache — a batched stack larger than the
        LLC turns every XLA pass over it into a DRAM stream, which is
        precisely where monolithic batching measures slower than looping
        (reserve 0.5: the LLC is shared with everything else on the host).
        """
        if platform is None:
            import jax
            platform = jax.default_backend()
        if platform == "tpu":
            return cls(TPU_VMEM_BYTES, source="vmem", reserve=0.75)
        return cls(_llc_bytes(), source="llc", reserve=0.5)

    @classmethod
    def unlimited(cls) -> "MemoryBudget":
        """No budget: monolithic dispatch (the pre-tiling regime)."""
        return cls(0, source="unlimited", reserve=1.0)

    @property
    def is_unlimited(self) -> bool:
        return self.bytes_limit <= 0

    @property
    def spendable_bytes(self) -> int:
        return int(self.bytes_limit * self.reserve)

    def frames_within(self, bytes_per_frame: int,
                      pipeline_depth: int = 1) -> int | None:
        """How many frames of ``bytes_per_frame`` working set fit.

        ``pipeline_depth`` multiplies the footprint: under double
        buffering two tiles are alive at once (tile t's analog+read in
        flight while tile t+1 stages), so each budgeted frame costs
        ``depth`` times its bytes.  Returns None when unlimited; always
        at least 1 otherwise (a single frame must dispatch even when it
        alone overflows the budget — there is no smaller unit).
        """
        if self.is_unlimited:
            return None
        if bytes_per_frame <= 0:
            raise ValueError("bytes_per_frame must be positive")
        depth = max(1, int(pipeline_depth))
        return max(1, self.spendable_bytes // (bytes_per_frame * depth))

    def tile_for(self, n_in: int, n_out: int | None = None, *,
                 pipeline_depth: int = 2,
                 dtype_bytes: int = BYTES_F32) -> int | None:
        """Budget frame cap under the standard working-set model.

        One frame's working set = ``dtype_bytes * (n_in + n_out) *
        _INTERMEDIATE_FACTOR`` (operand in, result out, pipeline
        intermediates).  This is the ONE place the model lives — every
        consumer goes through it.  Returns None when unlimited.
        """
        if n_out is None:
            n_out = n_in
        bytes_per_frame = int(dtype_bytes * (n_in + n_out)
                              * _INTERMEDIATE_FACTOR)
        return self.frames_within(max(1, bytes_per_frame), pipeline_depth)

    def minus(self, resident_bytes: int) -> "MemoryBudget":
        """The budget left for staging after ``resident_bytes`` of the
        spendable pool are pinned elsewhere (the operand residency cache:
        resident stacks are live allocations in the same physical pool the
        tiles stage through, so a fuller cache must mean a shallower
        tile).  An unlimited budget stays unlimited; otherwise the limit
        shrinks by the pinned bytes' pre-reserve share, floored at 1 byte —
        never at 0, which would read as *unlimited* and hand a saturated
        cache an infinite staging budget."""
        if self.is_unlimited or resident_bytes <= 0:
            return self
        limit = max(1, self.bytes_limit - int(resident_bytes / self.reserve))
        return dataclasses.replace(self, bytes_limit=limit)

    def tile_for_group(self, n_in: int, n_out: int | None, k: int, *,
                       pipeline_depth: int = 2,
                       dtype_bytes: int = BYTES_F32) -> int:
        """The tile depth a ``k``-deep group actually dispatches at: the
        budget frame cap refined by :func:`choose_tile`'s even-split
        divisor preference.  This is the resolution the executor, the
        router, AND both accelerator families'
        ``batched_step_cost(mem_budget=)`` share (the cost model
        duck-types this method so ``repro.core`` never imports this
        package) — the modeled tiling is the executed tiling, divisor
        refinement included."""
        return choose_tile(n_in, k, self, n_out=n_out,
                           dtype_bytes=dtype_bytes,
                           pipeline_depth=pipeline_depth).tile_k




@dataclasses.dataclass(frozen=True)
class TilePlan:
    """The result of :func:`choose_tile`: how one flush group streams.

    Attributes:
      tile_k: frames per sub-invocation (1 = looped, >= k = monolithic).
      k: the group depth the plan covers.
      bytes_per_frame: the modeled working-set bytes one frame costs.
      budget: the budget the choice was made under.
    """

    tile_k: int
    k: int
    bytes_per_frame: int
    budget: MemoryBudget

    @property
    def monolithic(self) -> bool:
        return self.tile_k >= self.k

    @property
    def tiles(self) -> int:
        return math.ceil(self.k / self.tile_k)

    def sizes(self) -> list[int]:
        return tile_sizes(self.k, self.tile_k)


def choose_tile(n_in: int, k: int, budget: MemoryBudget, *,
                n_out: int | None = None, dtype_bytes: int = BYTES_F32,
                pipeline_depth: int = 2) -> TilePlan:
    """Pick ``tile_k`` for a K-deep group of ``n_in``-sample frames.

    The deepest tile whose working set (times ``pipeline_depth`` — two
    tiles in flight under double buffering) fits the budget, with one
    refinement: when a *divisor* of ``k`` no smaller than half the
    budgeted depth exists, prefer it — an even split avoids a ragged tail
    tile, which is one fewer compiled stack shape and one fewer
    under-filled boundary crossing, at the cost of at most half the
    budgeted amortization depth.
    """
    if n_out is None:
        n_out = n_in
    bytes_per_frame = int(dtype_bytes * (n_in + n_out) * _INTERMEDIATE_FACTOR)
    cap = budget.tile_for(n_in, n_out, pipeline_depth=pipeline_depth,
                          dtype_bytes=dtype_bytes)
    if cap is None or cap >= k:
        tile = k
    else:
        div = max(d for d in range(1, cap + 1) if k % d == 0)
        tile = div if 2 * div > cap else cap
    return TilePlan(tile_k=max(1, tile), k=max(1, k),
                    bytes_per_frame=bytes_per_frame, budget=budget)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Budget-driven block sizes for the batched Pallas DFT grid.

    ``bb`` frames ride each grid step (sharing one load of the factor
    blocks); ``bm/bk/bn`` tile the matmul itself.  ``key`` is the
    signature compiled kernels and cached factor matrices are keyed by —
    replanning the budget (hence the blocks) must never silently reuse a
    kernel or factor cached under the old layout.
    """

    bb: int
    bm: int
    bk: int
    bn: int

    @property
    def key(self) -> tuple[int, int, int, int]:
        return (self.bb, self.bm, self.bk, self.bn)


def _stage_block_bytes(bb: int, b: int, dtype_bytes: int = BYTES_F32) -> int:
    """VMEM bytes one batched-DFT grid step holds at square block size
    ``b`` with ``bb`` frames per step: two (b, b) factor blocks, a
    (bb, b, b) operand block, (bb, b, b) of accumulator scratch x2, and a
    (bb, b, b) output block (stage 2's is the widest; stage 1 writes two
    outputs but reads one operand — same total)."""
    return dtype_bytes * (2 * b * b + 4 * bb * b * b)


def choose_blocks(batch: int, m: int, k: int, n: int,
                  budget: MemoryBudget | None, *,
                  preferred: int = 128, max_bb: int = 8) -> BlockPlan:
    """Block sizes for one batched DFT stage from the VMEM budget.

    Starts from the MXU-shaped ``preferred`` cube and halves until one
    grid step's working set (:func:`_stage_block_bytes`) fits the
    spendable budget; then grows ``bb`` (frames per grid step — they share
    one load of the factor blocks) through the divisors of ``batch`` while
    the footprint still fits, capped at ``max_bb`` to bound kernel unroll.
    With no budget (None / unlimited) the classic ``pick_block`` defaults
    come back unchanged (``bb=1``), so off-budget callers compile exactly
    the kernels they always did.
    """
    from repro.kernels.common import pick_block

    def resolve(b: int) -> tuple[int, int, int]:
        return (pick_block(m, b, 8), pick_block(k, b, 128),
                pick_block(n, b, 128))

    if budget is None or budget.is_unlimited:
        bm, bk, bn = resolve(preferred)
        return BlockPlan(bb=1, bm=bm, bk=bk, bn=bn)
    spend = budget.spendable_bytes
    b = preferred
    while b > 8 and _stage_block_bytes(1, b) > spend:
        b //= 2
    bm, bk, bn = resolve(b)
    side = max(bm, bk, bn)
    bb = 1
    for d in range(2, min(batch, max_bb) + 1):
        if batch % d == 0 and _stage_block_bytes(d, side) <= spend:
            bb = d
    return BlockPlan(bb=bb, bm=bm, bk=bk, bn=bn)
