"""Fidelity checking: pair every offloaded result with its accuracy cost.

The paper's argument cuts both ways: conversion costs time, and *skimping*
on conversion costs accuracy (fewer DAC/ADC bits -> cheaper boundary ->
worse results).  A speedup claim for the analog engine is only meaningful
next to the quantization error it introduces, so the runtime can shadow
every optical-sim batch with the host reference and report the relative
error against the bound implied by the converters' ENOB.

The bound: a b-bit uniform quantizer on a full-scale signal contributes
RMS error ~ q / sqrt(12) with q = 1 / (2^b - 1), i.e. a relative L2 error
on the order of 2^-b (see :func:`repro.core.conversion.enob_error_bound`,
shared with the planner's fidelity gate).  The optical pipeline squares the
field at the detector (intensity doubles relative error) and auto-ranges
the ADC, so we allow a configurable slack factor over the ideal-quantizer
floor; what the checker *guarantees* is the paper-relevant direction:
error decreases as converter resolution increases, and a result that blows
through the bound flags a broken offload rather than silently serving
garbage.

Scoring is vectorized: the whole batch reduces to per-frame L2 norms in
ONE fused device computation and ONE host sync (a per-frame ``float()``
loop would pay a blocking device round-trip per frame — K syncs for a
K-deep batch on the hot path).  ``sample_every`` bounds the shadowing cost
further: only every Nth batch per category is scored (the skipped batches
also keep the executor's async pipeline, since shadow scoring is the part
that forces synchronous retirement).
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.conversion import enob_error_bound

__all__ = ["FidelityReport", "FidelityChecker", "enob_error_bound"]


@dataclasses.dataclass(frozen=True)
class FidelityReport:
    category: str
    backend: str
    batch: int
    rel_err: float          # max over the batch of ||got-ref|| / ||ref||
    enob: float             # limiting converter ENOB used for the bound
    bound: float

    @property
    def ok(self) -> bool:
        return self.rel_err <= self.bound

    def __str__(self) -> str:
        flag = "ok" if self.ok else "VIOLATION"
        return (f"fidelity[{self.category}/{self.backend} x{self.batch}] "
                f"rel_err={self.rel_err:.3e} bound={self.bound:.3e} "
                f"(enob={self.enob:.1f}) {flag}")


@jax.jit
def _batch_rel_err(got: jax.Array, ref: jax.Array) -> jax.Array:
    """Worst per-frame relative L2 error over a ``(K, n)`` stacked batch —
    one reduction, one scalar out (the caller's ``float()`` is the only
    device sync for the whole batch).

    Zero-norm reference frames are well-defined rather than
    denominator-clamped garbage: a zero reference reproduced exactly scores
    0; any nonzero output against a zero reference scores ``inf`` (the
    offload fabricated signal out of nothing — always a violation for any
    finite bound)."""
    err = jnp.linalg.norm(got - ref, axis=1)
    refn = jnp.linalg.norm(ref, axis=1)
    rel = jnp.where(refn > 0.0, err / jnp.where(refn > 0.0, refn, 1.0),
                    jnp.where(err > 0.0, jnp.inf, 0.0))
    return jnp.max(rel)


class FidelityChecker:
    """Accumulates per-batch quantization-error reports.

    ``slack`` widens the ideal-quantizer floor to cover detector squaring,
    ADC auto-ranging, and error accumulation across the DFT; tune it down
    to make the checker stricter.

    ``sample_every=N`` scores only every Nth shadowed batch per category
    (the executor consults :meth:`should_check` before paying the shadow
    reference run), bounding validation overhead on hot paths; 1 (default)
    scores everything.
    """

    def __init__(self, slack: float = 16.0, sample_every: int = 1) -> None:
        if sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        self.slack = slack
        self.sample_every = sample_every
        self.reports: list[FidelityReport] = []
        self._seen: collections.Counter[str] = collections.Counter()

    def should_check(self, category: str) -> bool:
        """Sampling decision for the next shadowed batch of ``category``
        (consumes one tick of the per-category ``sample_every`` cycle; the
        first batch of every category is always scored)."""
        n = self._seen[category]
        self._seen[category] += 1
        return n % self.sample_every == 0

    def check(self, category: str, backend: str, got: list[jax.Array],
              ref: list[jax.Array], *, enob: float) -> FidelityReport:
        g = jnp.stack([jnp.ravel(jnp.asarray(x, jnp.float32)) for x in got])
        r = jnp.stack([jnp.ravel(jnp.asarray(x, jnp.float32)) for x in ref])
        rel = float(_batch_rel_err(g, r))
        report = FidelityReport(category=category, backend=backend,
                                batch=len(got), rel_err=rel, enob=enob,
                                bound=enob_error_bound(enob, self.slack))
        self.reports.append(report)
        return report

    # -- rollups ---------------------------------------------------------------
    def violations(self, category: str | None = None) -> list[FidelityReport]:
        """Reports whose relative error blew through the ENOB bound — the
        drifted/mis-ranged batches.  The executor's drift-correction path
        quarantines on these; operators read them to see what drifted."""
        return [r for r in self.reports
                if not r.ok and (category is None or r.category == category)]

    def worst(self, category: str | None = None) -> FidelityReport | None:
        pool = [r for r in self.reports
                if category is None or r.category == category]
        return max(pool, key=lambda r: r.rel_err) if pool else None

    @property
    def all_ok(self) -> bool:
        return all(r.ok for r in self.reports)

    def summary(self) -> str:
        if not self.reports:
            return "fidelity: no checks recorded"
        lines = [str(r) for r in self.reports[-8:]]
        w = self.worst()
        lines.append(f"fidelity worst: {w.category} rel_err={w.rel_err:.3e} "
                     f"({'within' if self.all_ok else 'OUTSIDE'} ENOB budget)")
        return "\n".join(lines)
