"""Fidelity checking: pair every offloaded result with its accuracy cost.

The paper's argument cuts both ways: conversion costs time, and *skimping*
on conversion costs accuracy (fewer DAC/ADC bits -> cheaper boundary ->
worse results).  A speedup claim for the analog engine is only meaningful
next to the quantization error it introduces, so the runtime can shadow
every optical-sim batch with the host reference and report the relative
error against the bound implied by the converters' ENOB.

The bound: a b-bit uniform quantizer on a full-scale signal contributes
RMS error ~ q / sqrt(12) with q = 1 / (2^b - 1), i.e. a relative L2 error
on the order of 2^-b.  The optical pipeline squares the field at the
detector (intensity doubles relative error) and auto-ranges the ADC, so we
allow a configurable slack factor over the ideal-quantizer floor; what the
checker *guarantees* is the paper-relevant direction: error decreases as
converter resolution increases, and a result that blows through the bound
flags a broken offload rather than silently serving garbage.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["FidelityReport", "FidelityChecker", "enob_error_bound"]


def enob_error_bound(enob: float, slack: float = 16.0) -> float:
    """Relative-error budget implied by ``enob`` effective bits."""
    if enob <= 0:
        return math.inf
    return slack * 2.0 ** (-enob)


@dataclasses.dataclass(frozen=True)
class FidelityReport:
    category: str
    backend: str
    batch: int
    rel_err: float          # max over the batch of ||got-ref|| / ||ref||
    enob: float             # limiting converter ENOB used for the bound
    bound: float

    @property
    def ok(self) -> bool:
        return self.rel_err <= self.bound

    def __str__(self) -> str:
        flag = "ok" if self.ok else "VIOLATION"
        return (f"fidelity[{self.category}/{self.backend} x{self.batch}] "
                f"rel_err={self.rel_err:.3e} bound={self.bound:.3e} "
                f"(enob={self.enob:.1f}) {flag}")


def _rel_err(got: jax.Array, ref: jax.Array) -> float:
    got = jnp.asarray(got, jnp.float32)
    ref = jnp.asarray(ref, jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(ref.reshape(-1)), 1e-12)
    return float(jnp.linalg.norm((got - ref).reshape(-1)) / denom)


class FidelityChecker:
    """Accumulates per-batch quantization-error reports.

    ``slack`` widens the ideal-quantizer floor to cover detector squaring,
    ADC auto-ranging, and error accumulation across the DFT; tune it down
    to make the checker stricter.
    """

    def __init__(self, slack: float = 16.0) -> None:
        self.slack = slack
        self.reports: list[FidelityReport] = []

    def check(self, category: str, backend: str, got: list[jax.Array],
              ref: list[jax.Array], *, enob: float) -> FidelityReport:
        rel = max(_rel_err(g, r) for g, r in zip(got, ref))
        report = FidelityReport(category=category, backend=backend,
                                batch=len(got), rel_err=rel, enob=enob,
                                bound=enob_error_bound(enob, self.slack))
        self.reports.append(report)
        return report

    # -- rollups ---------------------------------------------------------------
    def worst(self, category: str | None = None) -> FidelityReport | None:
        pool = [r for r in self.reports
                if category is None or r.category == category]
        return max(pool, key=lambda r: r.rel_err) if pool else None

    @property
    def all_ok(self) -> bool:
        return all(r.ok for r in self.reports)

    def summary(self) -> str:
        if not self.reports:
            return "fidelity: no checks recorded"
        lines = [str(r) for r in self.reports[-8:]]
        w = self.worst()
        lines.append(f"fidelity worst: {w.category} rel_err={w.rel_err:.3e} "
                     f"({'within' if self.all_ok else 'OUTSIDE'} ENOB budget)")
        return "\n".join(lines)
