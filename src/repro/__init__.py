"""repro — conversion-aware analog-offload framework (Meech et al. 2023)."""

__version__ = "1.0.0"
