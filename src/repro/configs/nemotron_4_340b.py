"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — squared-ReLU MLP (2 matrices, ungated) [arXiv:2402.16819]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, mlp_kind="relu2",
    param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
    vocab_size=512, vocab_pad_multiple=64, param_dtype="float32",
    logit_chunks=2,
)
