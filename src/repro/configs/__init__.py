"""Architecture registry + input specs.

``get_config(arch)`` / ``get_smoke_config(arch)`` return the full and
reduced configs; ``input_specs(cfg, shape)`` returns ShapeDtypeStruct
stand-ins for every model input of a (config, shape) cell — weak-type
correct, shardable, zero allocation — the dry-run's only input source.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, Shape, applicable, applicable_shapes
from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "get_smoke_config", "input_specs",
           "SHAPES", "Shape", "applicable", "applicable_shapes"]

ARCHS: dict[str, str] = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-72b": "qwen2_72b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llava-next-34b": "llava_next_34b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "xlstm-125m": "xlstm_125m",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def input_specs(cfg: ModelConfig, shape: str | Shape,
                *, with_labels: bool | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell's step inputs.

    train  -> the ``loss``/train-step batch;
    prefill-> the prefill batch (no labels);
    decode -> the one-token batch (the cache comes from
              ``jax.eval_shape(model.init_cache, ...)``, not from here).
    """
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = sh.global_batch, sh.seq_len
    i32 = jnp.int32
    act = cfg.activation_dtype
    if sh.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    labels = sh.kind == "train" if with_labels is None else with_labels
    out: dict = {}
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct((b, s // 2, cfg.d_model), act)
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if labels:
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return out
    s_text = s - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    out["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
    if cfg.frontend == "vision":
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_tokens, cfg.d_model), act)
    if labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
    return out
