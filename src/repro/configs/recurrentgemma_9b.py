"""recurrentgemma-9b [hybrid]: 38L RG-LRU + local attention in a 2:1
pattern, d_model=4096, 16H MQA (kv=1), d_ff=12288, vocab=256000,
window=2048, lru_width=4096 [arXiv:2402.19427].

38 = 12 x (rglru, rglru, attn) + 2 trailing rglru layers; the framework
scans the 12 super-blocks and unrolls the 2-layer tail."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, pattern=("rglru", "rglru", "attn"),
    local_window=2048, lru_width=4096, mlp_kind="geglu",
    param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    lru_width=64, local_window=8, vocab_size=500, vocab_pad_multiple=64,
    param_dtype="float32", logit_chunks=2,
)
