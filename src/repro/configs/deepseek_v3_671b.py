"""deepseek-v3-671b [moe]: 61L d_model=7168, MLA (128 heads, kv_rank=512,
rope=64), 256 routed experts top-8 + 1 shared (expert d_ff=2048), first 3
layers dense (d_ff=18432), vocab=129280 [arXiv:2412.19437].

MTP (multi-token prediction) is a training-objective add-on and is not
implemented; see DESIGN.md.  Expert sharding: ``ep`` (256 = 16 x 16)."""
import dataclasses
from repro.models.config import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280, dense_prefix=3,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, top_k=8, d_expert=2048, n_shared=1,
                  d_shared=2048, shard_mode="ep"),
    param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, dense_prefix=1, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=500, vocab_pad_multiple=64, param_dtype="float32",
    logit_chunks=2,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                  qk_rope_head_dim=4, v_head_dim=8),
    moe=MoEConfig(n_routed=8, top_k=2, d_expert=32, n_shared=1,
                  d_shared=32, shard_mode="ep"),
)
