"""Assigned input shapes and their applicability rules.

Every LM arch is paired with four shapes; ``decode_*`` / ``long_*`` lower
``serve``/``decode_step`` (one token against a seq_len cache), not
``train_step``.  ``long_500k`` requires sub-quadratic sequence mixing and
is skipped for the eight full-attention archs (incl. DeepSeek-V3 — MLA
compresses the cache but attention is still O(L^2)); it runs for the
hybrid (RG-LRU + local attention) and xLSTM families.  No assigned arch is
encoder-only, so decode shapes run everywhere.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Shape", "SHAPES", "applicable", "applicable_shapes"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

# families whose sequence mixing is sub-quadratic end to end
_SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def applicable(family: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return family in _SUBQUADRATIC_FAMILIES
    return True


def applicable_shapes(family: str) -> list[str]:
    return [s for s in SHAPES if applicable(family, s)]
