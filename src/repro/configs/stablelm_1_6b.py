"""stablelm-1.6b [dense]: 24L d_model=2048 32H (kv=32 => MHA) d_ff=5632
vocab=100352 — 25% partial rotary [hf:stabilityai/stablelm-2-1_6b]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, rope_pct=0.25, mlp_kind="swiglu",
    param_dtype="float32", logit_chunks=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=500, vocab_pad_multiple=64, logit_chunks=2,
)
