"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16 => MHA),
d_ff=8192, vocab=256206 [arXiv:2308.11596; hf].  The audio frontend is a
STUB per the brief: ``input_specs`` provides precomputed frame embeddings
at d_model; only a linear adapter is learned in-repo.
Divergence noted in DESIGN.md: RoPE + gated MLP replace the original
sinusoidal positions + plain ReLU FFN (backbone dims are exact).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206, encoder_layers=24, frontend="audio",
    mlp_kind="swiglu", param_dtype="float32", logit_chunks=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=503, vocab_pad_multiple=64, logit_chunks=2,
)
