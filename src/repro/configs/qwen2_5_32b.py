"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-*; hf]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab_size=152064, attn_bias=True, rope_theta=1e6,
    mlp_kind="swiglu", param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=80, n_heads=5, n_kv_heads=1, d_ff=192,
    vocab_size=512, vocab_pad_multiple=64, param_dtype="float32",
    logit_chunks=2,
)
