"""xlstm-125m [ssm]: 12L d_model=768 4H vocab=50304 — mLSTM (matrix
memory) + sLSTM (scalar memory) blocks [arXiv:2405.04517].

Block ratio: (5 mLSTM : 1 sLSTM) x 2 approximates the paper's 7:1 at this
depth.  d_ff=0 per the brief: mLSTM blocks carry their own pf=2
up/down-projection; sLSTM blocks a pf-4/3 gated FFN.  125M-class: inner
matrices replicate (DP-only), only vocab tables shard (DESIGN.md §6)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, mlp_kind="none",
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    param_dtype="float32", logit_chunks=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    pattern=("mlstm", "slstm"), vocab_size=500, vocab_pad_multiple=64,
    logit_chunks=2,
)
