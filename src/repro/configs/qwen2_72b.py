"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA with QKV bias [arXiv:2407.10671; hf]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, attn_bias=True, rope_theta=1e6,
    mlp_kind="swiglu", param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_ff=160,
    vocab_size=511, vocab_pad_multiple=64, param_dtype="float32",
    logit_chunks=2,
)
