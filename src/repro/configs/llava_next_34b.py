"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling frontend is a STUB: ``input_specs`` provides
576 precomputed patch embeddings prepended to the token sequence
[hf:llava-hf/llava-v1.6-*]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, rope_theta=5e6, frontend="vision", frontend_tokens=576,
    mlp_kind="swiglu", param_dtype="bfloat16", logit_chunks=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    frontend_tokens=4, vocab_size=500, vocab_pad_multiple=64,
    param_dtype="float32", logit_chunks=2,
)
