"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (MHA kv=16) vocab=151936,
60 routed experts top-4 + 4 shared, expert d_ff=1408
[hf:Qwen/Qwen1.5-MoE-A2.7B].

Expert sharding: ``tp`` — 60 experts don't divide the 16-chip model axis,
so each expert's ffn dim (1408 = 16 x 88) is tensor-sharded instead."""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=5632,
    vocab_size=151936, attn_bias=True, rope_theta=1e6,
    moe=MoEConfig(n_routed=60, top_k=4, d_expert=1408, n_shared=4,
                  d_shared=1408, shard_mode="tp"),
    param_dtype="bfloat16", logit_chunks=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=500, vocab_pad_multiple=64, param_dtype="float32",
    logit_chunks=2,
    moe=MoEConfig(n_routed=8, top_k=2, d_expert=32, n_shared=2,
                  d_shared=32, shard_mode="tp"),
)
