"""PartitionSpec trees for everything that isn't a parameter:
batches, decode caches, and optimizer states.

These are the dry-run's in/out shardings; without them the 2.5 TB
Nemotron decode cache would be lowered replicated per chip.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec  # noqa: F401  (doc reference)

__all__ = ["batch_pspecs", "cache_pspecs", "opt_pspecs", "DP"]

DP = ("pod", "data")  # logical data-parallel axes (filtered per mesh)


def _dp(mesh_axes: tuple[str, ...]):
    got = tuple(a for a in DP if a in mesh_axes)
    return got if got else None


def batch_pspecs(batch_like: Any, mesh_axes: tuple[str, ...],
                 dp_total: int = 32) -> Any:
    """Shard dim0 (global batch) over the data axes; rest replicated.
    Leaves whose batch dim doesn't divide the dp extent (long_500k: B=1)
    stay replicated."""
    dp = _dp(mesh_axes)

    def one(x):
        lead = dp if (dp is not None and x.shape
                      and x.shape[0] % dp_total == 0) else None
        return P(lead, *([None] * (len(x.shape) - 1)))
    return jax.tree_util.tree_map(one, batch_like)


def _shard_last(dim: int, tp: int):
    return "model" if dim % tp == 0 else None


def cache_pspecs(cfg: ModelConfig, cache_like: Any,
                 mesh_axes: tuple[str, ...], tp: int,
                 batch: int) -> Any:
    """Decode-cache shardings, keyed on leaf shapes.

    GQA k/v (B, Hkv, S, hd): batch over data axes; heads over ``model``
    when divisible, else head_dim (128/192/256 all divide 16).  MLA latent
    (B, S, D_lat): D_lat over model.  Recurrent states: width over model
    when divisible.  Scan-stacked leaves get a leading None.
    When the global batch doesn't cover the dp axes (long_500k B=1), batch
    stays replicated.
    """
    dp_axes = _dp(mesh_axes)
    # conservative: shard batch only when it divides the largest dp extent
    # we deploy (2 pods x 16 = 32); long_500k (B=1) stays replicated.
    dp = dp_axes if (dp_axes is not None and batch % 32 == 0) else None

    def leaf_spec(path, x) -> P:
        keys = [getattr(pp, "key", "") for pp in path]
        stacked = "stack" in keys
        shape = x.shape[1:] if stacked else x.shape
        name = keys[-1] if keys else ""
        if name in ("k", "v") and len(shape) == 4:
            b, hk, s, hd = shape
            if hk % tp == 0:
                spec = (dp, "model", None, None)
            elif hd % tp == 0:
                spec = (dp, None, None, "model")
            else:
                spec = (dp, None, None, None)
        elif name == "latent" and len(shape) == 3:
            spec = (dp, None, _shard_last(shape[-1], tp))
        elif name == "slot_pos" or name in ("pos",):
            spec = tuple([None] * len(shape))
        elif name == "enc_out":
            spec = (dp,) + (None,) * (len(shape) - 1)
        elif name in ("c",) and len(shape) == 4:   # mLSTM matrix memory
            spec = (dp, None, None, None)
        elif len(shape) >= 2:
            spec = (dp,) + (None,) * (len(shape) - 2) + (
                _shard_last(shape[-1], tp),)
        elif len(shape) == 1:
            spec = (dp,) if dp is not None and shape[0] % 32 == 0 else (None,)
        else:
            spec = ()
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, x) for p, x in flat])


def opt_pspecs(opt_like: Any, params_pspecs: Any) -> Any:
    """Optimizer-state shardings derived from parameter shardings.

    adamw m/v mirror the param spec exactly; adafactor vr/vc take the spec
    minus the reduced dim.  Works structurally: opt leaves live under the
    same param path with an extra {'m'|'v'|'vr'|'vc'} level.
    """
    def build(opt_node, pspec_node):
        if isinstance(opt_node, dict):
            out = {}
            for k, v in opt_node.items():
                if k == "vr" and not isinstance(v, dict):
                    out[k] = P(*pspec_node[:-1])
                elif k == "vc" and not isinstance(v, dict):
                    out[k] = P(*(tuple(pspec_node[:-2]) + (pspec_node[-1],)))
                elif k in ("m", "v") and not isinstance(v, dict):
                    out[k] = pspec_node
                else:
                    out[k] = build(v, pspec_node[k] if isinstance(pspec_node, dict)
                                   and k in pspec_node else pspec_node)
            return out
        return pspec_node

    return build(opt_like, params_pspecs)
