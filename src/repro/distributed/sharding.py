"""Mesh-aware sharding helpers.

``constrain`` is the single entry point models use to pin activation
shardings: it is a no-op outside a mesh context (CPU smoke tests) and drops
axis names the current mesh does not define (so the same model code runs on
the single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh,
and tiny test meshes).
"""

from __future__ import annotations

import os
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["constrain", "batch_axes", "current_axis_names", "logical_to_mesh",
           "activation_sharding_mode", "constrain_residual", "shard_devices"]


def activation_sharding_mode() -> str:
    """'baseline' = parameter-driven SPMD propagation only;
    'dp' = residual stream pinned batch-sharded at block boundaries
    (EXPERIMENTS.md §Perf iteration 1: prevents XLA's contraction-dim psum
    strategy from all-reducing full unsharded activations under FSDP).
    Controlled by REPRO_ACT_SHARDING so the dry-run can A/B the two
    lowerings without code changes."""
    return os.environ.get("REPRO_ACT_SHARDING", "baseline")


def constrain_residual(x: jax.Array) -> jax.Array:
    """Pin a (B, S, D) residual-stream tensor between blocks.

    mode 'dp':  batch over the data axes.
    mode 'sp':  batch over data + *sequence over model* — Megatron-style
    sequence parallelism: the per-block TP all-reduce of the full (B, S, D)
    activation becomes a reduce-scatter(S) going in and an all-gather(S)
    coming out, cutting per-device TP collective bytes by ~the TP degree
    (norms/residual adds are elementwise over D, so they run on the
    S-sharded tensor for free).
    """
    mode = activation_sharding_mode()
    if mode not in ("dp", "sp"):
        return x
    if x.shape[0] % 32 != 0:   # must divide the largest dp extent (2x16)
        return x
    if mode == "sp" and x.ndim == 3 and x.shape[1] % 16 == 0:
        return constrain(x, ("pod", "data"), "model", None)
    return constrain(x, ("pod", "data"), None, None)


def current_axis_names() -> tuple[str, ...]:
    from repro.distributed.compat import current_mesh_axis_names
    return current_mesh_axis_names()


def _filter_spec(spec: Any, axes: tuple[str, ...]) -> Any:
    if spec is None:
        return None
    if isinstance(spec, (tuple, list)):
        kept = tuple(a for a in spec if a in axes)
        return kept if kept else None
    return spec if spec in axes else None


def logical_to_mesh(pspec: P) -> P | None:
    """Drop unknown axis names from a PartitionSpec for the active mesh."""
    axes = current_axis_names()
    if not axes:
        return None
    return P(*(_filter_spec(s, axes) for s in pspec))


def constrain(x: jax.Array, *spec: Any) -> jax.Array:
    """with_sharding_constraint that degrades gracefully off-mesh."""
    resolved = logical_to_mesh(P(*spec))
    if resolved is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolved)


def batch_axes() -> tuple[str, ...] | None:
    """Axes the global batch shards over: ("pod","data") when both exist."""
    axes = current_axis_names()
    got = tuple(a for a in ("pod", "data") if a in axes)
    return got if got else None


def shard_devices(n: int) -> list[jax.Device] | None:
    """Pick ``n`` distinct devices to scatter work shards onto.

    Prefers the active context mesh's devices (so a sharded offload running
    inside a mesh program lands on the mesh's own chips), falling back to
    ``jax.devices()``.  Returns None when fewer than ``n`` devices exist —
    the caller's cue to take the sequential off-mesh fallback (CPU tests:
    one device, shards dispatch in turn with identical numerics).
    """
    if n <= 1:
        return None
    from repro.distributed.compat import current_mesh
    mesh = current_mesh()
    devs = list(mesh.devices.flat) if mesh is not None else list(jax.devices())
    if len(devs) < n:
        return None
    return devs[:n]
