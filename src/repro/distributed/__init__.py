"""Distributed runtime: sharding rules, collectives, elasticity, fault tolerance."""

from repro.distributed.sharding import batch_axes, constrain, logical_to_mesh

__all__ = ["constrain", "batch_axes", "logical_to_mesh"]
