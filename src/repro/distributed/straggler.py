"""Trailing-median straggler deadlines, shared by training and runtime.

The fault-tolerant training runner (``repro.distributed.fault``) and the
offload runtime's dispatch watchdog (``repro.runtime.faults``) detect the
same pathology — a step or dispatch that takes far longer than its healthy
siblings — and before this module each grew its own copy of the detection
logic.  :class:`TrailingMedianDeadline` is the one shared policy:

* a **trailing median** of recent healthy durations is the robust baseline
  (a mean would be dragged by the very stragglers it must detect);
* the deadline is ``factor x max(median, modeled baseline, floor)`` — the
  modeled baseline (e.g. a dispatch's ``batched_step_cost`` wall) arms the
  detector from the *first* observation, before any history exists, and
  the floor keeps sub-millisecond jitter from tripping it;
* stragglers do **not** enter the healthy history (they would poison the
  median they are judged against) and are counted as consecutive
  ``strikes``; a healthy observation resets the streak.  Past ``patience``
  consecutive strikes (:attr:`exhausted`) the caller escalates — the
  training runner restarts from checkpoint, the runtime quarantines the
  device or category.

With neither history nor a baseline the deadline is ``inf`` (no signal is
no claim): the first few observations of a cold detector are always
healthy, exactly the original runner semantics.
"""

from __future__ import annotations

__all__ = ["TrailingMedianDeadline"]


class TrailingMedianDeadline:
    """Straggler detector over a stream of durations.

    Args:
      factor: deadline multiple over the healthy baseline (3.0 means a
        duration 3x the trailing median is a straggler).
      window: how many recent healthy durations back the median.
      patience: consecutive strikes before :attr:`exhausted`.
      floor_s: smallest baseline the deadline is derived from — durations
        under ``factor * floor_s`` are never stragglers, whatever the
        median says (0.0 disables the floor: pure relative detection,
        the training runner's historical behavior).
    """

    def __init__(self, *, factor: float = 3.0, window: int = 32,
                 patience: int = 3, floor_s: float = 0.0) -> None:
        if factor <= 0.0:
            raise ValueError("factor must be > 0")
        if window < 1:
            raise ValueError("window must be >= 1")
        if patience < 1:
            raise ValueError("patience must be >= 1")
        if floor_s < 0.0:
            raise ValueError("floor_s must be >= 0")
        self.factor = float(factor)
        self.window = int(window)
        self.patience = int(patience)
        self.floor_s = float(floor_s)
        self.strikes = 0
        self._healthy: list[float] = []

    @property
    def median(self) -> float:
        """Trailing median of healthy durations (``inf`` when cold)."""
        s = sorted(self._healthy)
        return s[len(s) // 2] if s else float("inf")

    @property
    def exhausted(self) -> bool:
        """True when ``patience`` consecutive stragglers have been seen."""
        return self.strikes >= self.patience

    def deadline_s(self, base_s: float | None = None) -> float:
        """Current straggler deadline in seconds.

        ``base_s`` is an optional modeled baseline for the *next*
        observation (a dispatch's modeled wall); it arms the detector
        before any healthy history exists.  ``inf`` when there is neither
        history nor a baseline.
        """
        est = self.median if self._healthy else 0.0
        if base_s is not None and base_s > 0.0:
            est = max(est, float(base_s))
        if est <= 0.0:
            return float("inf")
        return self.factor * max(est, self.floor_s)

    def observe(self, dt_s: float, base_s: float | None = None) -> bool:
        """Score one duration; True means straggler.

        Healthy durations enter the trailing window and reset the strike
        streak; stragglers are excluded from the window (they must not
        drag the median they are judged against) and extend it.
        """
        if dt_s > self.deadline_s(base_s):
            self.strikes += 1
            return True
        self.strikes = 0
        self._healthy.append(float(dt_s))
        if len(self._healthy) > self.window:
            del self._healthy[:-self.window]
        return False

    def reset_strikes(self) -> None:
        """Forgive the current streak (the training runner's post-restart
        reset: a recovered run starts with a clean record)."""
        self.strikes = 0

    def reset(self) -> None:
        """Full reset: history and strikes."""
        self.strikes = 0
        self._healthy.clear()
