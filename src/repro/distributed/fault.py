"""Fault tolerance & straggler mitigation for the training driver.

Single-controller runtime model (what a real pod deployment uses):
  * every step runs under a watchdog deadline derived from a trailing
    median of healthy step times (the shared
    :class:`~repro.distributed.straggler.TrailingMedianDeadline` — the
    same detector the offload runtime's dispatch watchdog uses, so the
    training and serving fault stories cannot diverge) — a straggling
    step (slow host, flaky ICI link) is *detected* and counted; past
    ``straggler_patience`` consecutive stragglers the runner treats the
    step as a failure (on real fleets: reschedule the slow host, shrink
    the mesh, or restart from checkpoint — here: restart path);
  * any exception in a step (preemption, device loss — simulated in tests
    by injected faults) triggers restore-from-latest-checkpoint and replay;
    the data pipeline is step-keyed so replayed batches are bit-identical;
  * checkpoint cadence is decoupled from the loop via async saves.

The runner is deliberately jit-agnostic: it wraps *any* step callable
operating on an opaque state pytree.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.straggler import TrailingMedianDeadline

__all__ = ["FaultTolerantRunner", "RunReport"]


@dataclasses.dataclass
class RunReport:
    steps_run: int = 0
    failures_recovered: int = 0
    stragglers_detected: int = 0
    checkpoints_written: int = 0
    final_step: int = 0
    step_times_s: list[float] = dataclasses.field(default_factory=list)


class FaultTolerantRunner:
    def __init__(self, step_fn: Callable[[Any, int], Any],
                 manager: CheckpointManager, *,
                 checkpoint_every: int = 50,
                 straggler_factor: float = 3.0,
                 straggler_patience: int = 3,
                 max_restarts: int = 10) -> None:
        self.step_fn = step_fn
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.max_restarts = max_restarts

    def run(self, state: Any, start_step: int, num_steps: int,
            *, fault_hook: Callable[[int], None] | None = None) -> tuple[Any, RunReport]:
        """Run ``num_steps`` steps with recovery.  ``fault_hook(step)`` may
        raise to simulate a failure (used by the failure-injection tests)."""
        report = RunReport(final_step=start_step)
        step = start_step
        restarts = 0
        detector = TrailingMedianDeadline(factor=self.straggler_factor,
                                          patience=self.straggler_patience)
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.perf_counter()
                if fault_hook is not None:
                    fault_hook(step)
                state = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                report.step_times_s.append(dt)
                if detector.observe(dt):
                    report.stragglers_detected += 1
                    if detector.exhausted:
                        raise RuntimeError(
                            f"persistent straggler: step {step} took {dt:.3f}s "
                            f"(median {detector.median:.3f}s) "
                            f"x{self.straggler_patience}")
                step += 1
                report.steps_run += 1
                if step % self.checkpoint_every == 0:
                    self.manager.save_async(step, state)
                    report.checkpoints_written += 1
            except Exception:
                restarts += 1
                report.failures_recovered += 1
                if restarts > self.max_restarts:
                    raise
                self.manager.wait()
                restored_step, restored = self.manager.restore_latest(state)
                if restored_step is None:
                    # no checkpoint yet: replay from the segment start
                    step = start_step
                else:
                    state, step = restored, restored_step
                detector.reset_strikes()
        self.manager.wait()
        report.final_step = step
        return state, report
