"""jax version compatibility for mesh construction and activation.

The repo targets the current jax mesh API (``jax.make_mesh(...,
axis_types=...)`` + ``jax.set_mesh`` + ``jax.sharding.get_abstract_mesh``)
but must also run on jax 0.4.x, where axis types don't exist, the context
mesh is the ``with mesh:`` resource env, and the abstract mesh is not
threaded through tracing.  All mesh touch-points go through this module so
the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import jax

__all__ = ["make_auto_mesh", "enter_mesh", "current_mesh_axis_names",
           "current_mesh"]

# Last mesh activated through enter_mesh — the version-agnostic fallback for
# current_mesh() when neither the new concrete-mesh API nor the 0.4.x
# resource env can report one.
_LAST_ENTERED: jax.sharding.Mesh | None = None


def make_auto_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    try:
        auto = jax.sharding.AxisType.Auto
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(auto,) * len(axes))
    except (AttributeError, TypeError):  # jax 0.4.x: no axis_types
        return jax.make_mesh(tuple(shape), tuple(axes))


def enter_mesh(mesh: jax.sharding.Mesh) -> None:
    """Make ``mesh`` the context mesh for the rest of the process.

    New jax: ``jax.set_mesh``.  jax 0.4.x: enter the ``with mesh:`` resource
    env and deliberately never exit (callers are process-scoped scripts —
    dry-run cells and subprocess lowering tests)."""
    global _LAST_ENTERED
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        set_mesh(mesh)
    else:
        mesh.__enter__()
    _LAST_ENTERED = mesh


def current_mesh_axis_names() -> tuple[str, ...]:
    """Axis names of the active (abstract or resource-env) context mesh."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is None:
        from jax._src import mesh as _mesh_lib
        get_abstract = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)
    mesh = get_abstract()
    if mesh is not None and not getattr(mesh, "empty", True):
        return tuple(mesh.axis_names)
    try:  # jax 0.4.x ``with mesh:`` resource env
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if not env_mesh.empty:
            return tuple(env_mesh.axis_names)
    except (ImportError, AttributeError):
        pass
    return ()


def current_mesh() -> jax.sharding.Mesh | None:
    """The active *concrete* context mesh, or None off-mesh.

    Unlike :func:`current_mesh_axis_names` this must return a mesh with
    real devices attached (the sharded offload backend scatters work onto
    them), so the abstract-mesh path is skipped: new jax goes through
    ``get_concrete_mesh``, 0.4.x through the resource env, and the
    :func:`enter_mesh` bookkeeping covers whichever API recorded neither.
    """
    get_concrete = getattr(jax.sharding, "get_concrete_mesh", None)
    if get_concrete is not None:
        mesh = get_concrete()
        if mesh is not None and not getattr(mesh, "empty", True):
            return mesh
    try:  # jax 0.4.x ``with mesh:`` resource env
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if not env_mesh.empty:
            return env_mesh
    except (ImportError, AttributeError):
        pass
    return _LAST_ENTERED
