"""jax version compatibility for mesh construction and activation.

The repo targets the current jax mesh API (``jax.make_mesh(...,
axis_types=...)`` + ``jax.set_mesh`` + ``jax.sharding.get_abstract_mesh``)
but must also run on jax 0.4.x, where axis types don't exist, the context
mesh is the ``with mesh:`` resource env, and the abstract mesh is not
threaded through tracing.  All mesh touch-points go through this module so
the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import jax

__all__ = ["make_auto_mesh", "enter_mesh", "current_mesh_axis_names"]


def make_auto_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    try:
        auto = jax.sharding.AxisType.Auto
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(auto,) * len(axes))
    except (AttributeError, TypeError):  # jax 0.4.x: no axis_types
        return jax.make_mesh(tuple(shape), tuple(axes))


def enter_mesh(mesh: jax.sharding.Mesh) -> None:
    """Make ``mesh`` the context mesh for the rest of the process.

    New jax: ``jax.set_mesh``.  jax 0.4.x: enter the ``with mesh:`` resource
    env and deliberately never exit (callers are process-scoped scripts —
    dry-run cells and subprocess lowering tests)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        set_mesh(mesh)
    else:
        mesh.__enter__()


def current_mesh_axis_names() -> tuple[str, ...]:
    """Axis names of the active (abstract or resource-env) context mesh."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is None:
        from jax._src import mesh as _mesh_lib
        get_abstract = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)
    mesh = get_abstract()
    if mesh is not None and not getattr(mesh, "empty", True):
        return tuple(mesh.axis_names)
    try:  # jax 0.4.x ``with mesh:`` resource env
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if not env_mesh.empty:
            return tuple(env_mesh.axis_names)
    except (ImportError, AttributeError):
        pass
    return ()
