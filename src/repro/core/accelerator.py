"""Analog accelerator specifications and end-to-end step cost models.

The paper's Fig. 7a architecture: a digital host talks to an analog optical
engine through (i) a DAC + spatial-light-modulator write path and (ii) a
camera detector + ADC read path.  The analog compute itself (diffraction)
runs at the speed of light; everything else is the data-conversion /
data-movement boundary that this paper identifies as the bottleneck.

Two accelerator families are modeled:

* ``OpticalFourierAcceleratorSpec`` — the paper's own 4f Fourier/convolution
  engine (Appendix A/B).
* ``OpticalMVMAcceleratorSpec`` — the optical matrix-vector-multiply engine
  of Anderson et al. that the paper's §2 critique targets; included so the
  offload planner can evaluate the "more promising" MVM target (§5.1) under
  honest conversion costs.

Cost model conventions: times in seconds, energies in joules, ``n`` counts
scalar samples crossing the conversion boundary.  ``step_cost`` prices one
serial invocation; ``batched_step_cost`` prices one invocation carrying a
coalesced batch (fixed per-frame costs amortize), and its
``pipeline_depth >= 2`` mode prices *double-buffered* execution where the
write path of frame f+1 overlaps the analog+read path of frame f — the
steady-state boundary cost becomes max(write, analog+read) per stage
instead of their sum (see the method docstrings for the exact accounting).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.conversion import ConverterSpec, KIM_2019_DAC, LIU_2022_ADC

__all__ = [
    "StepCost",
    "OpticalFourierAcceleratorSpec",
    "OpticalMVMAcceleratorSpec",
    "PROTOTYPE_4F",
    "IDEAL_4F",
    "ANDERSON_MVM",
    "SPEED_OF_LIGHT_M_S",
    "tile_sizes",
]

SPEED_OF_LIGHT_M_S = 299_792_458.0


def tile_sizes(k: int, tile_k: int) -> list[int]:
    """Sub-invocation sizes for a K-deep group at ``tile_k`` frames/tile:
    ``ceil(k / tile_k)`` tiles, the last one ragged when ``tile_k`` does
    not divide ``k``.  The ONE definition of the split — the runtime's
    dispatcher/warmer (via ``repro.runtime.tiling``) and both cost models
    below share it, so the modeled tile stream can never desync from the
    dispatched one."""
    if k < 1:
        raise ValueError("k must be >= 1")
    tile_k = max(1, min(int(tile_k), k))
    sizes = [tile_k] * (k // tile_k)
    if k % tile_k:
        sizes.append(k % tile_k)
    return sizes


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Cost breakdown for one accelerator invocation (the Fig. 8 split)."""

    dac_s: float
    adc_s: float
    interface_s: float      # host<->peripheral link (SLM write + camera read)
    analog_s: float         # the physics (time of flight / settle / exposure)
    host_s: float = 0.0     # digital post-processing (e.g. the host iFFT)
    hold_s: float = 0.0     # queueing delay: how long the batch was held
                            # open accumulating occupancy before dispatch

    @property
    def total_s(self) -> float:
        return (self.dac_s + self.adc_s + self.interface_s + self.analog_s
                + self.host_s + self.hold_s)

    @property
    def conversion_s(self) -> float:
        return self.dac_s + self.adc_s

    @property
    def data_movement_fraction(self) -> float:
        """Fraction of wall time spent moving/converting data (paper: 99.599%).

        Hold time is queueing, not movement: it sits in neither the
        numerator nor this fraction's story, but it does stretch
        ``total_s`` — an invocation that waited for its batch is slower
        end to end, honestly."""
        tot = self.total_s
        if tot <= 0:
            return 0.0
        return (self.dac_s + self.adc_s + self.interface_s) / tot

    def scaled(self, k: float) -> "StepCost":
        return StepCost(self.dac_s * k, self.adc_s * k, self.interface_s * k,
                        self.analog_s * k, self.host_s * k, self.hold_s * k)

    def __add__(self, other: "StepCost") -> "StepCost":
        if not isinstance(other, StepCost):
            return NotImplemented
        return StepCost(self.dac_s + other.dac_s, self.adc_s + other.adc_s,
                        self.interface_s + other.interface_s,
                        self.analog_s + other.analog_s,
                        self.host_s + other.host_s,
                        self.hold_s + other.hold_s)


def _compose_sides(sides: dict, *, host_s: float = 0.0,
                   hold_s: float = 0.0) -> StepCost:
    """Collapse per-engine side tuples ``(dac_s, adc_s, intf_in, intf_out,
    analog_s, serial_s, stages)`` into one pipelined :class:`StepCost`.

    The executor's per-engine windows share one host staging/DAC write
    path but each engine owns its analog core and readout, so the composed
    wall is ``max(sum of write sides, slowest engine's read side)``: the
    binding side is kept whole and every hidden side is charged only its
    exposed ``1/total_stages`` prologue share — the same convention the
    single-engine ``pipeline_depth`` collapse uses, applied across
    engines.  Serial components (handshakes whose split is unknown, sync
    barriers) never overlap.
    """
    writes = {n: s[0] + s[2] for n, s in sides.items()}
    reads = {n: s[1] + s[3] + s[4] for n, s in sides.items()}
    serial = sum(s[5] for s in sides.values())
    total_stages = sum(s[6] for s in sides.values())
    w_total = sum(writes.values())
    r_name = max(reads, key=lambda n: reads[n])
    r_max = reads[r_name]
    dac_s = adc_s = intf_in = intf_out = analog_s = 0.0
    hidden = 1.0 / total_stages if total_stages > 1 else 1.0
    for name, (d, a, i1, i2, an, _sy, _st) in sides.items():
        if total_stages > 1:
            if w_total >= r_max:
                # the shared host write path binds: every engine's
                # analog+read side hides behind it
                a *= hidden
                i2 *= hidden
                an *= hidden
            elif name == r_name:
                # the slowest engine's read side binds: its own write
                # prologue is the only exposed write share
                d *= hidden
                i1 *= hidden
            else:
                d *= hidden
                a *= hidden
                i1 *= hidden
                i2 *= hidden
                an *= hidden
        dac_s += d
        adc_s += a
        intf_in += i1
        intf_out += i2
        analog_s += an
    return StepCost(dac_s=dac_s, adc_s=adc_s,
                    interface_s=intf_in + intf_out + serial,
                    analog_s=analog_s, host_s=host_s, hold_s=hold_s)


@dataclasses.dataclass(frozen=True)
class OpticalFourierAcceleratorSpec:
    """A 4f optical Fourier/convolution accelerator (paper Appendix A/B).

    Attributes:
      name: identifier.
      slm_pixels: (rows, cols) of the programmable aperture.
      dac / adc: converter design points on the write/read paths.
      dac_lanes / adc_lanes: parallel converter lanes (column-parallel
        readout in modern image sensors; 1 for the serial prototype).
      slm_interface_hz: pixel-write rate of the peripheral link into the SLM
        local memory (the paper's prototype uses a 60 Hz-display-class link).
      camera_interface_hz: pixel-read rate of the camera link.
      slm_settle_s: liquid-crystal settle time per frame.
      exposure_s: detector integration time per frame.
      path_length_m: optical path (4f => 4 * focal_length).
      macro_pixel: aggregation factor per axis for crosstalk mitigation
        (Anderson et al. aggregate 3x3 -> macro_pixel=3, costing 9x pixels).
      phase_shift_captures: captures per result; 1 = magnitude-only detector,
        4 = four-step phase-shifting interferometry (complex recovery).
      interface_latency_s: fixed host<->peripheral round-trip latency charged
        once per accelerator invocation (link handshake / frame sync — e.g.
        one 60 Hz display frame period for the prototype's USB/DSI links).
        This is the term batching amortizes (§6); 0 preserves the paper's
        throughput-only calibration.
      device_sync_s: per-device synchronization epsilon for multi-aperture
        (sharded) execution: when one invocation is scattered across
        ``n_devices`` replicated accelerators, the host pays this barrier
        cost once per participating device on top of the slowest device's
        boundary crossing (see ``batched_step_cost(n_devices=...)``).
    """

    name: str
    slm_pixels: tuple[int, int] = (1024, 768)
    dac: ConverterSpec = KIM_2019_DAC
    adc: ConverterSpec = LIU_2022_ADC
    dac_lanes: int = 1
    adc_lanes: int = 1
    slm_interface_hz: float = 1.0e6
    camera_interface_hz: float = 1.0e6
    slm_settle_s: float = 1.0e-3
    exposure_s: float = 1.0e-3
    path_length_m: float = 0.5
    macro_pixel: int = 1
    phase_shift_captures: int = 1
    interface_latency_s: float = 0.0
    device_sync_s: float = 0.0

    @property
    def usable_pixels(self) -> int:
        r, c = self.slm_pixels
        return (r // self.macro_pixel) * (c // self.macro_pixel)

    def time_of_flight_s(self) -> float:
        return self.path_length_m / SPEED_OF_LIGHT_M_S

    def step_cost(self, n_in: int, n_out: int | None = None,
                  host_s: float = 0.0) -> StepCost:
        """Cost of one accelerated op moving ``n_in`` samples in, ``n_out`` out.

        The conversion complexity is the paper's C = 2N (Fig. 3) when
        n_out == n_in.  Every capture repeats the read path
        (``phase_shift_captures`` of them) but the write path is programmed
        once per input.
        """
        if n_out is None:
            n_out = n_in
        caps = self.phase_shift_captures
        dac_s = self.dac.time_for(n_in, self.dac_lanes)
        adc_s = self.adc.time_for(n_out, self.adc_lanes) * caps
        interface_s = (n_in / self.slm_interface_hz
                       + caps * n_out / self.camera_interface_hz
                       + self.interface_latency_s)
        analog_s = (self.slm_settle_s + self.exposure_s) * caps + self.time_of_flight_s()
        return StepCost(dac_s=dac_s, adc_s=adc_s, interface_s=interface_s,
                        analog_s=analog_s, host_s=host_s)

    def _batched_sides(self, n_in: int, n_out: int, batch: int,
                       write_batch: int | None = None,
                       write_scale: float = 1.0,
                       ) -> tuple[float, float, float, float, float, int]:
        """Unoverlapped resource totals of ONE invocation carrying
        ``batch`` inputs on one device: (dac_s, adc_s, intf_in, intf_out,
        analog_s, frames).  The write side is dac + intf_in; the
        analog+read side is adc + intf_out + analog.  Shared by the
        monolithic, tiled, and sharded pricing paths so all three charge
        identical per-invocation physics.

        ``write_batch`` (default: ``batch``) is how many of the inputs
        actually cross the write path this invocation — the rest are
        *resident* on the device from an earlier staging, so they pay no
        DAC conversion, no SLM link transfer, and no write-side frame
        handshake.  The read side always prices the full ``batch``: every
        result still crosses the detector + ADC.

        ``write_scale`` (default 1.0) scales the per-sample write terms —
        DAC conversion and SLM link transfer — for *delta-encoded* writes:
        an X2X-ladder DAC rewriting a staged operand pays only for the
        LSBs that flip, so a low-delta write crosses a fraction of the
        write path.  The per-frame handshake stays whole (the frame sync
        does not shrink with the payload)."""
        caps = self.phase_shift_captures
        px = max(self.usable_pixels, 1)
        frames = max(1, math.ceil(batch * n_in / px))
        wb = batch if write_batch is None else max(0, min(write_batch, batch))
        wframes = frames if wb == batch \
            else math.ceil(wb * n_in / px)
        dac_s = self.dac.time_for(wb * n_in, self.dac_lanes) if wb else 0.0
        adc_s = self.adc.time_for(batch * n_out, self.adc_lanes) * caps
        link_in = wb * n_in / self.slm_interface_hz
        if write_scale != 1.0:
            dac_s *= write_scale
            link_in *= write_scale
        intf_in = link_in + wframes * self.interface_latency_s
        intf_out = caps * batch * n_out / self.camera_interface_hz
        analog_s = (frames * (self.slm_settle_s + self.exposure_s) * caps
                    + self.time_of_flight_s())
        return dac_s, adc_s, intf_in, intf_out, analog_s, frames

    def _group_sides(self, n_in: int, n_out: int | None, *, batch: int,
                     pipeline_depth: int, n_devices: int,
                     tile_k: int | None, mem_budget,
                     resident_frames: int, weight_samples: int,
                     resident_weights: int,
                     delta_fractions: tuple = (),
                     ) -> tuple[float, float, float, float, float, float,
                                int]:
        """Unoverlapped totals of one (possibly tiled, sharded, partially
        resident) invocation: ``(dac_s, adc_s, intf_in, intf_out, analog_s,
        sync_s, stages)``.  This is the accounting both
        :meth:`batched_step_cost` (which then applies the intra-invocation
        pipeline collapse) and the ``engines=`` composition mode (which
        applies a cross-engine collapse instead) price from — one
        definition of the physics, two overlap disciplines.

        ``delta_fractions`` are per-frame write scales in (0, 1] for the
        *delta-staged* subset of the written frames: frame order within
        each tile is resident → delta → full, so the tile's written share
        crosses the write path at the mean of its delta scales (full
        writes count 1.0).  ``resident_frames + len(delta_fractions)``
        must not exceed ``batch``."""
        if n_out is None:
            n_out = n_in
        if batch < 1:
            raise ValueError("batch must be >= 1")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if resident_frames < 0 or weight_samples < 0 or resident_weights < 0:
            raise ValueError("residency counts must be >= 0")
        deltas = tuple(float(f) for f in delta_fractions)
        for f in deltas:
            if not 0.0 < f <= 1.0:
                raise ValueError("delta fractions must be in (0, 1]")
        if len(deltas) + min(int(resident_frames), batch) > batch:
            raise ValueError(
                "resident_frames + len(delta_fractions) exceeds batch")
        if tile_k is None and mem_budget is not None:
            tile_k = mem_budget.tile_for_group(
                n_in, n_out, batch, pipeline_depth=pipeline_depth)
        if tile_k is not None and tile_k < 1:
            raise ValueError("tile_k must be >= 1")
        sizes = tile_sizes(batch, batch if tile_k is None else tile_k)
        dac_s = adc_s = intf_in = intf_out = analog_s = sync_s = 0.0
        stages = 0
        remaining = min(int(resident_frames), batch)
        di = 0
        for b in sizes:
            eff = min(n_devices, b)
            pb = math.ceil(b / eff)
            res_b = min(remaining, b)
            remaining -= res_b
            # the tile's non-resident share crosses the write path, split
            # per device the same way the frames themselves are
            wb = pb - min(math.ceil(res_b / eff), pb)
            written = b - res_b
            take = min(len(deltas) - di, written)
            if take > 0 and written:
                tile_deltas = deltas[di:di + take]
                di += take
                ws = (math.fsum(tile_deltas) + (written - take)) / written
            else:
                ws = 1.0
            d, a, i1, i2, an, fr = self._batched_sides(
                n_in, n_out, pb, write_batch=wb, write_scale=ws)
            dac_s += d
            adc_s += a
            intf_in += i1
            intf_out += i2
            analog_s += an
            stages += fr
            if n_devices > 1:
                sync_s += eff * self.device_sync_s
        w_extra = max(0, int(weight_samples) - int(resident_weights))
        if w_extra:
            dac_s += self.dac.time_for(w_extra, self.dac_lanes)
            intf_in += w_extra / self.slm_interface_hz
        # the stages slot counts OVERLAPPABLE stages: a strictly serial
        # engine (pipeline_depth 1) exposes every prologue whole, so it
        # must compose as a single stage — this is what keeps a
        # degenerate one-engine composition exactly equal to the
        # pipeline_depth price at every depth
        if pipeline_depth < 2:
            stages = 1
        return dac_s, adc_s, intf_in, intf_out, analog_s, sync_s, stages

    def _compose_engines(self, engines, *, host_s: float = 0.0,
                         hold_s: float = 0.0) -> StepCost:
        """Price concurrent per-engine pipeline windows (the executor's
        DAG mode): each engine's write path (DAC + SLM link) serializes on
        the shared host staging resource while the analog+read paths run
        concurrently on their own hardware, so the composed wall is
        ``max(sum of write sides, slowest engine's read side)`` with the
        hidden sides charged only their exposed 1/stages prologue share —
        the same keep-the-binding-side-whole convention the
        ``pipeline_depth`` mode uses, applied across engines."""
        if not engines:
            raise ValueError("engines must name at least one engine")
        sides: dict = {}
        for name, e in engines.items():
            if isinstance(e, StepCost):
                # pre-priced engine: write = DAC, read = ADC + analog, the
                # interface split is unknown so it stays serial
                sides[name] = (e.dac_s, e.adc_s, 0.0, 0.0, e.analog_s,
                               e.interface_s, 1)
                continue
            kw = dict(e)
            sides[name] = self._group_sides(
                kw.pop("n_in"), kw.pop("n_out", None),
                batch=kw.pop("batch", 1),
                pipeline_depth=kw.pop("pipeline_depth", 1),
                n_devices=kw.pop("n_devices", 1),
                tile_k=kw.pop("tile_k", None),
                mem_budget=kw.pop("mem_budget", None),
                resident_frames=kw.pop("resident_frames", 0),
                weight_samples=kw.pop("weight_samples", 0),
                resident_weights=kw.pop("resident_weights", 0),
                delta_fractions=kw.pop("delta_fractions", ()))
            if kw:
                raise ValueError(f"unknown engine kwargs for {name!r}: "
                                 f"{sorted(kw)}")
        return _compose_sides(sides, host_s=host_s, hold_s=hold_s)

    def batched_step_cost(self, n_in: int, n_out: int | None = None, *,
                          batch: int = 1, host_s: float = 0.0,
                          pipeline_depth: int = 1,
                          n_devices: int = 1,
                          hold_s: float = 0.0,
                          tile_k: int | None = None,
                          mem_budget=None,
                          resident_frames: int = 0,
                          weight_samples: int = 0,
                          resident_weights: int = 0,
                          delta_fractions: tuple = (),
                          engines=None) -> StepCost:
        """Cost of one invocation carrying ``batch`` same-shape inputs.

        ``hold_s`` is the queueing delay a continuous-batching scheduler
        spent holding this group open to accumulate occupancy (age of the
        oldest coalesced call at dispatch).  It is charged whole to the
        invocation's wall clock — amortization bought by waiting is only a
        win when the handshake savings exceed the wait, and pricing the
        wait is what keeps that trade honest.

        The batch is packed spatially onto the aperture (the runtime's §6
        amortization lever): the converters still touch every sample
        (conversion stays C = 2N per datum), but the fixed per-invocation
        costs — link handshake latency, SLM settle, exposure — are charged
        once per *frame* instead of once per call, and lane-parallel
        converters amortize their ceil() residue across the whole batch.
        ``batch=1`` reproduces :meth:`step_cost` exactly whenever the input
        fits one frame.

        ``pipeline_depth >= 2`` additionally models *double-buffered* frame
        streaming (the runtime executor's async flush): while frame f is
        settling, exposing, and reading out through the ADC, the DAC + SLM
        link are already writing frame f+1 into the second buffer.  The two
        resources — the write path (DAC, SLM link, frame handshake) and the
        analog+read path (settle, exposure, ADC, camera link) — then run
        concurrently, so each steady-state stage costs
        ``max(write_path, analog + read_path)`` instead of their *sum*; only
        the first write and the last read stick out of the overlap.  The
        returned :class:`StepCost` keeps the slower side whole and charges
        the faster (hidden) side only its exposed 1/stages prologue share,
        so ``total_s`` equals the pipelined wall clock while the breakdown
        still says which side bounds throughput.  With a single frame there
        is nothing to overlap and the depth is ignored.

        ``n_devices >= 2`` prices *multi-aperture* (sharded) execution —
        how photonic systems actually scale: replicate apertures rather
        than grow one.  The batch scatters across ``n_devices`` replicated
        accelerators, each carrying ``ceil(batch / n_devices)`` inputs
        through its OWN converters and links (per-invocation fixed costs do
        NOT amortize across devices — every device pays its own handshake,
        settle, and exposure).  The devices run concurrently, so the wall
        cost is the slowest (largest) shard's cost — max-over-devices —
        plus one ``device_sync_s`` of barrier overhead per *participating*
        device charged to the interface (a group shallower than the fleet
        occupies only ``batch`` devices, matching the runtime's
        ``shard_sizes`` split).

        ``tile_k`` prices *memory-budgeted tiled dispatch* (the runtime's
        ``choose_tile`` lever): the batch streams as ``ceil(batch /
        tile_k)`` sub-invocations of at most ``tile_k`` inputs each —
        exactly how the executor dispatches a group whose monolithic stack
        would overflow the staging budget.  Every tile pays its OWN
        per-invocation prologue (frame handshake, settle, exposure,
        time-of-flight; under sharding, each tile scatters across the
        devices and re-pays the sync barrier), but with ``pipeline_depth
        >= 2`` consecutive tiles overlap through the executor's two-deep
        async pipeline — tile t+1's write path behind tile t's analog+read
        — so the steady-state wall is max-side over the whole tile stream,
        with the faster side charged only its exposed prologue share.
        ``tile_k >= batch`` is exactly the monolithic price; ``tile_k=1``
        prices the looped regime.  Alternatively pass ``mem_budget`` (any
        object with a ``tile_for_group(n_in, n_out, k, pipeline_depth=...)``
        method, e.g. ``repro.runtime.tiling.MemoryBudget``) and the tile
        depth is derived from the byte budget exactly as the executor
        derives it — same frame cap, same even-split divisor refinement.

        ``resident_frames`` prices *operand residency* (the runtime's
        ``ResidencyCache``): that many of the batch's inputs are already
        staged on the device from an earlier invocation, so they skip the
        whole write side — no DAC conversion, no SLM link transfer, no
        write-side frame handshake — while the read side still prices the
        full batch (every result crosses the detector + ADC).  A fully
        resident batch therefore costs ``dac_s == 0``: a hit is
        read-side-only, which is exactly what the dispatcher does with a
        residency hit.  ``weight_samples`` is the kernel/weight operand's
        sample count written to the Fourier-plane SLM this invocation
        (charged once, on the write side), and ``resident_weights`` the
        subset of those samples already resident — a resident kernel
        writes nothing.  All three default to 0: the historical price,
        bit for bit.

        ``delta_fractions`` prices *delta-encoded* staging (the residency
        cache's third price between free hit and full re-stage): each
        entry is the write scale in (0, 1] of one written frame whose
        staged codes differ from the new operand by only that fraction of
        LSB flips — an X2X-ladder DAC pays for flipped LSBs, not whole
        words.  Delta frames scale the per-sample write terms (DAC
        conversion, SLM link transfer) while the frame handshake and the
        entire read side stay whole, so the price is guaranteed to land
        between the residency-hit price (``delta_fractions`` can never
        reach 0) and the full-write price (scales cap at 1.0).
        ``resident_frames + len(delta_fractions)`` must not exceed
        ``batch``; the default empty tuple reproduces the historical
        price bit for bit.

        ``engines`` switches to the *composition* mode pricing the
        executor's per-engine pipeline windows: a mapping of engine name →
        either a kwargs dict for this method (``n_in`` required, same
        levers as above minus ``engines`` itself) or a pre-priced
        :class:`StepCost`.  All other keyword levers are ignored in this
        mode except ``host_s``/``hold_s`` — see :meth:`_compose_engines`
        for the overlap discipline.
        """
        if engines is not None:
            return self._compose_engines(engines, host_s=host_s,
                                         hold_s=hold_s)
        dac_s, adc_s, intf_in, intf_out, analog_s, sync_s, stages = (
            self._group_sides(n_in, n_out, batch=batch,
                              pipeline_depth=pipeline_depth,
                              n_devices=n_devices, tile_k=tile_k,
                              mem_budget=mem_budget,
                              resident_frames=resident_frames,
                              weight_samples=weight_samples,
                              resident_weights=resident_weights,
                              delta_fractions=delta_fractions))
        if pipeline_depth >= 2 and stages > 1:
            write_side = dac_s + intf_in
            read_side = adc_s + intf_out + analog_s
            hidden = 1.0 / stages  # exposed prologue share of the faster side
            if write_side <= read_side:
                dac_s *= hidden
                intf_in *= hidden
            else:
                adc_s *= hidden
                intf_out *= hidden
                analog_s *= hidden
        return StepCost(dac_s=dac_s, adc_s=adc_s,
                        interface_s=intf_in + intf_out + sync_s,
                        analog_s=analog_s, host_s=host_s, hold_s=hold_s)

    def step_energy_j(self, n_in: int, n_out: int | None = None) -> float:
        if n_out is None:
            n_out = n_in
        return (self.dac.energy_for(n_in)
                + self.adc.energy_for(n_out) * self.phase_shift_captures)


@dataclasses.dataclass(frozen=True)
class OpticalMVMAcceleratorSpec:
    """An optical matrix-vector multiply engine (Anderson et al. class).

    Weights are assumed held in the optical domain (amortized); activations
    cross the conversion boundary every pass: DAC in, ADC out.  One pass
    computes ``rows x cols`` MACs.
    """

    name: str
    rows: int = 512
    cols: int = 512
    dac: ConverterSpec = KIM_2019_DAC
    adc: ConverterSpec = LIU_2022_ADC
    dac_lanes: int = 512          # wavelength/space multiplexed input lanes
    adc_lanes: int = 512
    optical_pass_s: float = 1.0e-9
    mac_energy_j: float = 1.0e-17  # sub-fJ optical MAC (their claim)
    interface_latency_s: float = 0.0  # per-invocation host<->engine handshake
    device_sync_s: float = 0.0        # per-device sync epsilon (sharded mode)

    def macs_per_pass(self) -> int:
        return self.rows * self.cols

    def step_cost(self, n_in: int, n_out: int, host_s: float = 0.0) -> StepCost:
        dac_s = self.dac.time_for(n_in, self.dac_lanes)
        adc_s = self.adc.time_for(n_out, self.adc_lanes)
        return StepCost(dac_s=dac_s, adc_s=adc_s,
                        interface_s=self.interface_latency_s,
                        analog_s=self.optical_pass_s, host_s=host_s)

    def _group_sides(self, n_in: int, n_out: int | None, *, batch: int,
                     pipeline_depth: int, n_devices: int,
                     tile_k: int | None, mem_budget,
                     resident_frames: int, weight_samples: int,
                     resident_weights: int,
                     delta_fractions: tuple = (),
                     ) -> tuple[float, float, float, float, float, float,
                                int]:
        """Unoverlapped totals of one invocation in the shared side layout
        ``(dac_s, adc_s, intf_in, intf_out, analog_s, serial_s, stages)``.
        The MVM handshake has no known write/read split, so it rides the
        serial slot (with the sync barriers) and the in/out interface
        slots stay zero.  ``delta_fractions`` scale the written frames'
        DAC term exactly as on the 4f family (resident → delta → full
        frame order per tile; the handshake stays whole)."""
        if n_out is None:
            n_out = n_in
        if batch < 1:
            raise ValueError("batch must be >= 1")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if resident_frames < 0 or weight_samples < 0 or resident_weights < 0:
            raise ValueError("residency counts must be >= 0")
        deltas = tuple(float(f) for f in delta_fractions)
        for f in deltas:
            if not 0.0 < f <= 1.0:
                raise ValueError("delta fractions must be in (0, 1]")
        if len(deltas) + min(int(resident_frames), batch) > batch:
            raise ValueError(
                "resident_frames + len(delta_fractions) exceeds batch")
        if tile_k is None and mem_budget is not None:
            tile_k = mem_budget.tile_for_group(
                n_in, n_out, batch, pipeline_depth=pipeline_depth)
        if tile_k is not None and tile_k < 1:
            raise ValueError("tile_k must be >= 1")
        sizes = tile_sizes(batch, batch if tile_k is None else tile_k)
        dac_s = adc_s = analog_s = intf_s = 0.0
        stages = 0
        remaining = min(int(resident_frames), batch)
        di = 0
        for b in sizes:
            eff = min(n_devices, b)
            pb = math.ceil(b / eff)
            res_b = min(remaining, b)
            remaining -= res_b
            wb = pb - min(math.ceil(res_b / eff), pb)
            written = b - res_b
            take = min(len(deltas) - di, written)
            if wb:
                d = self.dac.time_for(wb * n_in, self.dac_lanes)
                if take > 0 and written:
                    tile_deltas = deltas[di:di + take]
                    di += take
                    d *= (math.fsum(tile_deltas) + (written - take)) / written
                dac_s += d
            adc_s += self.adc.time_for(pb * n_out, self.adc_lanes)
            analog_s += pb * self.optical_pass_s
            intf_s += self.interface_latency_s
            stages += pb
            if n_devices > 1:
                intf_s += eff * self.device_sync_s
        w_extra = max(0, int(weight_samples) - int(resident_weights))
        if w_extra:
            dac_s += self.dac.time_for(w_extra, self.dac_lanes)
        # overlappable stages only: a serial engine composes as one stage
        # (same rule as the 4f family — keeps degenerate one-engine
        # composition exactly equal to the pipeline_depth price)
        if pipeline_depth < 2:
            stages = 1
        return dac_s, adc_s, 0.0, 0.0, analog_s, intf_s, stages

    def _compose_engines(self, engines, *, host_s: float = 0.0,
                         hold_s: float = 0.0) -> StepCost:
        """Price concurrent per-engine pipeline windows — see
        :meth:`OpticalFourierAcceleratorSpec._compose_engines`; the
        composition discipline (:func:`_compose_sides`) is shared."""
        if not engines:
            raise ValueError("engines must name at least one engine")
        sides: dict = {}
        for name, e in engines.items():
            if isinstance(e, StepCost):
                sides[name] = (e.dac_s, e.adc_s, 0.0, 0.0, e.analog_s,
                               e.interface_s, 1)
                continue
            kw = dict(e)
            sides[name] = self._group_sides(
                kw.pop("n_in"), kw.pop("n_out", None),
                batch=kw.pop("batch", 1),
                pipeline_depth=kw.pop("pipeline_depth", 1),
                n_devices=kw.pop("n_devices", 1),
                tile_k=kw.pop("tile_k", None),
                mem_budget=kw.pop("mem_budget", None),
                resident_frames=kw.pop("resident_frames", 0),
                weight_samples=kw.pop("weight_samples", 0),
                resident_weights=kw.pop("resident_weights", 0),
                delta_fractions=kw.pop("delta_fractions", ()))
            if kw:
                raise ValueError(f"unknown engine kwargs for {name!r}: "
                                 f"{sorted(kw)}")
        return _compose_sides(sides, host_s=host_s, hold_s=hold_s)

    def batched_step_cost(self, n_in: int, n_out: int | None = None, *,
                          batch: int = 1, host_s: float = 0.0,
                          pipeline_depth: int = 1,
                          n_devices: int = 1,
                          hold_s: float = 0.0,
                          tile_k: int | None = None,
                          mem_budget=None,
                          resident_frames: int = 0,
                          weight_samples: int = 0,
                          resident_weights: int = 0,
                          delta_fractions: tuple = (),
                          engines=None) -> StepCost:
        """One invocation streaming ``batch`` same-shape activation sets.

        ``hold_s`` charges continuous-batching queueing delay to the
        invocation wall, exactly as on the 4f family.

        ``pipeline_depth >= 2`` models double-buffered streaming: the DAC
        loads activation set b+1 while set b is in the optical core / ADC,
        so each steady-state stage costs ``max(dac, adc + pass)`` instead
        of their sum.  The hidden (faster) side is charged only its exposed
        1/stages prologue share — see
        :meth:`OpticalFourierAcceleratorSpec.batched_step_cost`.

        ``n_devices >= 2`` prices sharded execution across replicated MVM
        engines: max-over-devices (each device streams its
        ``ceil(batch / n_devices)`` share through its own converters) plus
        one ``device_sync_s`` per participating device (at most ``batch``
        of them can take a shard).

        ``tile_k`` / ``mem_budget`` price memory-budgeted tiled dispatch,
        exactly as on the 4f family: the batch streams as ``ceil(batch /
        tile_k)`` sub-invocations, each paying its own handshake
        (``interface_latency_s``) and — under sharding — its own per-device
        sync, with consecutive tiles overlapped two-deep when
        ``pipeline_depth >= 2``.  ``mem_budget`` duck-types
        ``tile_for_group(n_in, n_out, k, pipeline_depth=...)``
        (``repro.runtime.tiling.MemoryBudget``) — the executor's exact
        resolution, divisor refinement included.

        ``resident_frames`` prices operand residency exactly as on the 4f
        family: that many activation sets are already loaded on the device,
        so they pay no input DAC conversion, while the read side (ADC,
        optical pass) still prices the full batch.  ``weight_samples`` /
        ``resident_weights`` charge the write of a *non-resident* weight
        panel through the DAC once per invocation (``matmul_cost`` prices
        weights as held in the optical domain — residency is the mechanism
        that keeps that assumption honest).  Defaults of 0 reproduce the
        historical price bit for bit.

        ``delta_fractions`` prices delta-encoded staging exactly as on the
        4f family: per-written-frame write scales in (0, 1] applied to the
        input DAC term (the handshake and read side stay whole), with
        ``resident_frames + len(delta_fractions) <= batch`` enforced and
        hit ≤ delta ≤ full-write pricing guaranteed by construction.

        ``engines`` switches to the cross-engine composition mode, exactly
        as on the 4f family.
        """
        if engines is not None:
            return self._compose_engines(engines, host_s=host_s,
                                         hold_s=hold_s)
        dac_s, adc_s, _i1, _i2, analog_s, intf_s, stages = (
            self._group_sides(n_in, n_out, batch=batch,
                              pipeline_depth=pipeline_depth,
                              n_devices=n_devices, tile_k=tile_k,
                              mem_budget=mem_budget,
                              resident_frames=resident_frames,
                              weight_samples=weight_samples,
                              resident_weights=resident_weights,
                              delta_fractions=delta_fractions))
        if pipeline_depth >= 2 and stages > 1:
            hidden = 1.0 / stages
            if dac_s <= adc_s + analog_s:
                dac_s *= hidden
            else:
                adc_s *= hidden
                analog_s *= hidden
        return StepCost(dac_s=dac_s, adc_s=adc_s, interface_s=intf_s,
                        analog_s=analog_s, host_s=host_s, hold_s=hold_s)

    def matmul_cost(self, m: int, k: int, n: int, *,
                    weight_write: bool = False) -> StepCost:
        """Cost of an (m,k) @ (k,n) matmul tiled onto the optical core.

        The (k,n) operand is treated as weights (pre-loaded); the (m,k)
        activations stream through the converters.  Tiling: ceil(k/rows) *
        ceil(n/cols) passes per activation row-block.

        ``weight_write=True`` additionally charges loading the (k,n)
        weight panel through the DAC — the price of a residency *miss*.
        The default (False) is the historical weight-stationary assumption:
        the panel is already resident, loading amortized away.  The
        runtime's residency cache is what makes the default honest — it
        charges the write on the first sighting of a panel and skips it on
        hits, instead of assuming every panel was always resident.
        """
        row_tiles = math.ceil(k / self.rows)
        col_tiles = math.ceil(n / self.cols)
        passes = m * row_tiles * col_tiles
        n_in = m * k * col_tiles          # activations re-enter per col tile
        n_out = m * n * row_tiles         # partials exit per row tile
        dac_s = self.dac.time_for(n_in, self.dac_lanes)
        if weight_write:
            dac_s += self.dac.time_for(k * n, self.dac_lanes)
        adc_s = self.adc.time_for(n_out, self.adc_lanes)
        return StepCost(dac_s=dac_s, adc_s=adc_s, interface_s=0.0,
                        analog_s=passes * self.optical_pass_s)


# --- Named instances ---------------------------------------------------------

# Calibrated to the paper's Fig. 8 measurement: a 1024x768 Fourier transform
# takes 5.209 s end to end on the prototype, 99.599 % of it data movement,
# vs 0.219 s for the software FFT on the same Raspberry Pi 4.  The prototype
# drives the SLM and reads the camera over 60 Hz-display-class USB/DSI links.
PROTOTYPE_4F = OpticalFourierAcceleratorSpec(
    name="prototype-4f",
    slm_pixels=(1024, 768),
    dac_lanes=1,
    adc_lanes=1,
    slm_interface_hz=300_164.0,    # 2.620 s to program 786,432 pixels
    camera_interface_hz=306_256.0, # 2.568 s to read them back
    slm_settle_s=10.0e-3,
    exposure_s=11.0e-3,
    path_length_m=0.5,
)

# The paper's "ideal" accelerator for the Amdahl study: FFT/conv cost == 0.
IDEAL_4F = OpticalFourierAcceleratorSpec(
    name="ideal-4f",
    slm_pixels=(4096, 4096),
    dac_lanes=10**9,
    adc_lanes=10**9,
    slm_interface_hz=math.inf,
    camera_interface_hz=math.inf,
    slm_settle_s=0.0,
    exposure_s=0.0,
    path_length_m=0.0,
)

# Anderson et al. optical transformer MVM engine, evaluated at honest
# (on-frontier) converter costs — the paper's §2 critique target.
ANDERSON_MVM = OpticalMVMAcceleratorSpec(name="anderson-mvm")
