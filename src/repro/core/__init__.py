"""The paper's contribution as a composable library.

Subsystems:
  conversion  — DAC/ADC design-point models + survey Pareto envelope (§2, Fig. 2)
  accelerator — analog accelerator specs + step cost models (Fig. 7a, Fig. 8)
  optical     — differentiable 4f Fourier/convolution physics sim (App. A/B)
  amdahl      — Eq. 2/3 speedup machinery (App. C.2)
  complexity  — compute vs conversion complexity C=2N (§4, Fig. 3)
  profiler    — wall-time + jaxpr FLOP attribution by op category (App. C.1)
  planner     — the conversion-aware offload decision rule (§4–§6)
"""

from repro.core.accelerator import (
    ANDERSON_MVM,
    IDEAL_4F,
    PROTOTYPE_4F,
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
    StepCost,
)
from repro.core.amdahl import AmdahlReport, ideal_speedup, report, required_fraction, speedup
from repro.core.conversion import (
    KIM_2019_DAC,
    LIU_2022_ADC,
    ConverterSpec,
    conversion_complexity,
    frontier_gap,
    pareto_fom_fj,
    pareto_power_w,
)
from repro.core.optical import (
    IDEAL_SIM,
    OpticalSimParams,
    fourier_mask_for_kernel,
    optical_conv2d,
    optical_fft2_complex,
    optical_fft2_magnitude,
)
from repro.core.planner import (
    BUILD_THRESHOLD,
    CategoryProfile,
    OffloadPlan,
    plan_offload,
)
from repro.core.profiler import OpProfiler, flops_by_category

__all__ = [k for k in dir() if not k.startswith("_")]
