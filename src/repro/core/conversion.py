"""Data-converter (DAC/ADC) cost models — the paper's central object.

The paper (§2, Fig. 2) shows that published DAC (96 designs, Caragiulo &
Murmann survey) and ADC (647 designs, Murmann survey) implementations trade
power against sampling speed along a Pareto frontier, and that analog
accelerator proposals which assume converters far below that frontier
(e.g. the 32x-below-frontier converters needed for the >100,000x optical
MAC energy win of Anderson et al.) are not realizable with known technology.

This module provides:

* ``ConverterSpec`` — a concrete converter design point (bits, rate, power),
  with the Walden figure of merit and per-sample energy/latency derived.
* Reference design points used by the paper: Kim et al. (VLSI'19) DAC and
  Liu et al. (ISSCC'22) ADC — the exact converters Anderson et al. build on.
* ``pareto_fom_fj`` — a survey-envelope model of the best published Walden
  FoM as a function of sampling rate, matching the qualitative shape of the
  Murmann/Caragiulo surveys (flat floor at low speed, degrading above a
  corner frequency).
* ``frontier_gap`` — the feasibility check of §2: how far below the envelope
  a required converter energy sits (>1 means "below the published frontier",
  i.e. does not exist today).

All constants are recorded here rather than imported from the survey CSVs
(offline container); they are calibration targets, not measurements.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "ConverterSpec",
    "KIM_2019_DAC",
    "LIU_2022_ADC",
    "enob_error_bound",
    "pareto_fom_fj",
    "pareto_power_w",
    "frontier_gap",
    "conversion_complexity",
    "CodeSignature",
    "SIGNATURE_FULL_CODE_MAX",
    "quantized_codes",
    "code_signature",
    "expected_flip_fraction",
    "delta_write_scale",
]


def enob_error_bound(enob: float, slack: float = 16.0) -> float:
    """Relative-error budget implied by ``enob`` effective bits.

    A b-bit uniform quantizer on a full-scale signal contributes RMS error
    ~ q / sqrt(12) with q = 1 / (2^b - 1), i.e. a relative L2 error on the
    order of 2^-b; ``slack`` widens that ideal floor to cover detector
    squaring, ADC auto-ranging, and error accumulation across a pipeline.
    ``enob <= 0`` means the converter promises nothing — the budget is
    infinite and no result can violate it.

    Lives here (next to :class:`ConverterSpec`) because both the runtime's
    ``FidelityChecker`` and the planner's fidelity gate consume it — the
    planner must not import from ``repro.runtime``.
    """
    if enob <= 0:
        return math.inf
    return slack * 2.0 ** (-enob)


@dataclasses.dataclass(frozen=True)
class ConverterSpec:
    """A data-converter design point.

    Attributes:
      name: identifier, e.g. ``"kim2019-dac"``.
      kind: ``"dac"`` or ``"adc"``.
      bits: nominal resolution in bits.
      rate_hz: sampling rate (samples/s). For interleaved designs this is the
        aggregate rate.
      power_w: total power at ``rate_hz``.
      enob: effective number of bits (defaults to ``bits - 1.0``, a typical
        published ENOB deficit).
      channels: interleaving factor (informational).
    """

    name: str
    kind: str
    bits: int
    rate_hz: float
    power_w: float
    enob: float | None = None
    channels: int = 1

    def __post_init__(self) -> None:
        if self.kind not in ("dac", "adc"):
            raise ValueError(f"kind must be 'dac' or 'adc', got {self.kind!r}")
        if self.rate_hz <= 0 or self.power_w <= 0 or self.bits <= 0:
            raise ValueError("bits, rate_hz and power_w must be positive")

    @property
    def effective_bits(self) -> float:
        return self.enob if self.enob is not None else self.bits - 1.0

    @property
    def energy_per_sample_j(self) -> float:
        """Energy to convert one sample: P / fs."""
        return self.power_w / self.rate_hz

    @property
    def latency_per_sample_s(self) -> float:
        """Serial conversion latency for one sample: 1 / fs."""
        return 1.0 / self.rate_hz

    @property
    def walden_fom_j(self) -> float:
        """Walden figure of merit: P / (2^ENOB * fs), joules per conv-step."""
        return self.power_w / (2.0 ** self.effective_bits * self.rate_hz)

    def time_for(self, n_samples: int, lanes: int = 1) -> float:
        """Wall time to convert ``n_samples`` with ``lanes`` parallel converters."""
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        return math.ceil(n_samples / lanes) / self.rate_hz

    def energy_for(self, n_samples: int) -> float:
        """Energy to convert ``n_samples`` (lanes don't change energy/sample)."""
        return n_samples * self.energy_per_sample_j


# --- Reference design points used by the paper (§2) -------------------------
#
# Kim et al., VLSI 2019 [37]: 6 b, 28 GS/s, four-channel time-interleaved
# current-steering DAC. Published power ~ 100 mW class; we record 0.1 W.
KIM_2019_DAC = ConverterSpec(
    name="kim2019-dac", kind="dac", bits=6, rate_hz=28e9, power_w=0.100,
    enob=5.0, channels=4,
)

# Liu et al., ISSCC 2022 [42]: 8 b, 10 GS/s, 25 fJ/conversion-step
# two-step time-domain ADC in 14 nm.  P = FoM * 2^ENOB * fs with ENOB ~ 7:
# 25e-15 * 128 * 10e9 = 32 mW.
LIU_2022_ADC = ConverterSpec(
    name="liu2022-adc", kind="adc", bits=8, rate_hz=10e9, power_w=0.032,
    enob=7.0,
)


# --- Survey-envelope (Pareto frontier) model --------------------------------
#
# Shape taken from the Murmann ADC survey envelope: the best published Walden
# FoM is roughly flat (a few fJ/conv-step) up to a corner rate, then degrades
# about one decade per decade of speed.  The same qualitative shape holds for
# the Caragiulo DAC survey.  Constants below put the Liu ISSCC'22 ADC
# (25 fJ/c-s at 10 GS/s) and the Kim VLSI'19 DAC essentially *on* their
# frontiers, as the paper argues ("above the Pareto frontiers" = realizable,
# while Anderson et al.'s 32x-lower-energy converters sit far below).
_FOM_FLOOR_FJ = {"adc": 2.0, "dac": 4.0}           # fJ / conversion-step
_CORNER_HZ = {"adc": 1.0e8, "dac": 5.0e8}          # envelope corner
_SLOPE = {"adc": 0.55, "dac": 0.83}                # decades FoM per decade fs


def pareto_fom_fj(rate_hz: float, kind: str = "adc") -> float:
    """Best-published Walden FoM (fJ/conv-step) achievable at ``rate_hz``.

    Points *below* this envelope do not exist in the surveys; the paper's
    argument is that analog-accelerator energy claims requiring such points
    (e.g. 32x below) are speculative.
    """
    if kind not in _FOM_FLOOR_FJ:
        raise ValueError(f"kind must be 'dac' or 'adc', got {kind!r}")
    if rate_hz <= 0:
        raise ValueError("rate_hz must be positive")
    floor = _FOM_FLOOR_FJ[kind]
    corner = _CORNER_HZ[kind]
    if rate_hz <= corner:
        return floor
    decades_past = math.log10(rate_hz / corner)
    return floor * 10.0 ** (_SLOPE[kind] * decades_past)


def pareto_power_w(rate_hz: float, bits: float, kind: str = "adc") -> float:
    """Minimum power on the survey envelope for a (rate, resolution) target."""
    fom_j = pareto_fom_fj(rate_hz, kind) * 1e-15
    return fom_j * (2.0 ** bits) * rate_hz


def frontier_gap(spec: ConverterSpec) -> float:
    """How far below the survey envelope a converter sits.

    Returns ``envelope_fom / spec_fom``: 1.0 means on the frontier, >1 means
    the design would need to beat every published design by that factor.
    The paper's headline check: Anderson et al.'s converters need a gap of
    ~32x (``frontier_gap`` >> 1) — see ``benchmarks/pareto.py``.
    """
    envelope = pareto_fom_fj(spec.rate_hz, spec.kind) * 1e-15
    return envelope / spec.walden_fom_j if spec.walden_fom_j > 0 else math.inf


def conversion_complexity(n: int) -> int:
    """The paper's conversion complexity C = 2N (Fig. 3).

    Every datum must cross the boundary twice: DAC on the way in, ADC on the
    way out.
    """
    if n < 0:
        raise ValueError("n must be non-negative")
    return 2 * n


# --- LSB-flip model: delta-encoded DAC writes --------------------------------
#
# Ladder-style DACs (the X2X ladder of Wang et al., JSSC 2022) spend write
# latency/energy on the LSBs that actually CHANGE between consecutive codes,
# not on the full word: rewriting an unchanged operand is near-free, and a
# slowly drifting one costs only its expected flip count.  The functions
# below turn that physics into a ``write_scale`` in (0, 1] the cost models
# apply to the write-side DAC/link terms — the third price between a free
# residency hit and a full re-stage.

# Operands up to this many samples retain their full quantized codes in the
# signature, so the flip fraction is the EXACT mean XOR popcount.  Larger
# operands keep only per-bit-plane popcounts (bits integers per operand) and
# estimate the flip fraction from plane densities.
SIGNATURE_FULL_CODE_MAX = 1 << 16


@dataclasses.dataclass(frozen=True)
class CodeSignature:
    """A cheap summary of an operand's quantized DAC codes.

    ``plane_counts[b]`` is the popcount of bit-plane ``b`` across all ``n``
    codes; ``codes`` holds the full code array for small operands (exact
    flip counting) and ``None`` past :data:`SIGNATURE_FULL_CODE_MAX`.
    """

    bits: int
    n: int
    plane_counts: tuple[int, ...]
    codes: np.ndarray | None = None


def quantized_codes(arr, bits: int) -> np.ndarray:
    """The integer DAC codes ``arr`` quantizes to at ``bits`` resolution.

    Mirrors the runtime's write-path range mapping: an affine map of the
    operand's own [min, max] onto the converter's full scale, rounded to
    the nearest of ``2^bits`` levels.  A constant operand maps to code 0.
    """
    if bits < 1:
        raise ValueError("bits must be >= 1")
    a = np.asarray(arr, dtype=np.float64).ravel()
    if a.size == 0:
        return np.zeros(0, dtype=np.uint16 if bits <= 16 else np.int64)
    lo = float(a.min())
    span = float(a.max()) - lo
    levels = (1 << bits) - 1
    if span <= 0.0:
        codes = np.zeros(a.shape, dtype=np.int64)
    else:
        codes = np.rint((a - lo) * (levels / span)).astype(np.int64)
    return codes.astype(np.uint16 if bits <= 16 else np.int64)


def code_signature(arr, bits: int, *,
                   full_code_max: int = SIGNATURE_FULL_CODE_MAX,
                   ) -> CodeSignature:
    """Build the :class:`CodeSignature` of ``arr`` at ``bits`` resolution."""
    codes = quantized_codes(arr, bits)
    planes = tuple(int(((codes >> b) & 1).sum()) for b in range(bits))
    keep = codes if codes.size <= full_code_max else None
    return CodeSignature(bits=bits, n=int(codes.size), plane_counts=planes,
                         codes=keep)


def expected_flip_fraction(old: CodeSignature, new: CodeSignature) -> float:
    """Expected fraction of LSBs flipping when ``old``'s staged codes are
    rewritten with ``new``'s, in [0, 1].

    Exact (mean XOR popcount over all bit planes) when both signatures
    retain full codes; otherwise estimated per plane from the densities
    ``p``/``q`` under independence (``p + q - 2pq`` — an upper bound on the
    true per-plane flip rate ``|p - q|``, so the estimate never undercharges
    a correlated drift).  Incomparable signatures (different resolution or
    sample count) are a full rewrite: 1.0.
    """
    if old.bits != new.bits or old.n != new.n or old.n == 0:
        return 1.0
    bits = old.bits
    if old.codes is not None and new.codes is not None:
        x = np.bitwise_xor(old.codes, new.codes)
        flips = sum(int(((x >> b) & 1).sum()) for b in range(bits))
        return flips / (old.n * bits)
    total = 0.0
    for b in range(bits):
        p = old.plane_counts[b] / old.n
        q = new.plane_counts[b] / new.n
        total += p + q - 2.0 * p * q
    return min(1.0, total / bits)


def delta_write_scale(flip_fraction: float, bits: int) -> float:
    """Write-side cost scale for a delta-encoded DAC write: the fraction of
    ladder LSB transitions a partial rewrite performs, floored at ``1/bits``
    (even a bit-identical re-assert strobes one ladder slot per sample, so a
    delta write is never free — only a residency *hit* is)."""
    if bits < 1:
        raise ValueError("bits must be >= 1")
    f = min(max(float(flip_fraction), 0.0), 1.0)
    return min(1.0, max(f, 1.0 / bits))
