"""Amdahl's-law machinery (paper Appendix C.2, Eq. 2/3).

Pure-python, no JAX: these run inside benchmark drivers and the planner.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "speedup",
    "ideal_speedup",
    "required_fraction",
    "AmdahlReport",
    "report",
]


def speedup(f_accelerate: float, p: float = math.inf) -> float:
    """Eq. 2: S = 1 / (f_fixed + f_accelerate / P).

    ``f_accelerate`` is the fraction of execution time the accelerator can
    absorb, ``p`` the factor by which that fraction is accelerated.
    """
    if not 0.0 <= f_accelerate <= 1.0:
        raise ValueError(f"f_accelerate must be in [0,1], got {f_accelerate}")
    if p <= 0:
        raise ValueError("p must be positive")
    f_fixed = 1.0 - f_accelerate
    denom = f_fixed + f_accelerate / p
    if denom == 0.0:
        return math.inf
    return 1.0 / denom


def ideal_speedup(f_accelerate: float) -> float:
    """Eq. 3: S ~= 1 / f_fixed — the zero-cost-accelerator bound."""
    return speedup(f_accelerate, math.inf)


def required_fraction(target_speedup: float) -> float:
    """Fraction that must be accelerable to ever reach ``target_speedup``.

    The paper's 10x rule (§5): S >= 10 requires f_accelerate >= 0.9.
    """
    if target_speedup < 1.0:
        raise ValueError("target_speedup must be >= 1")
    if math.isinf(target_speedup):
        return 1.0
    return 1.0 - 1.0 / target_speedup


@dataclasses.dataclass(frozen=True)
class AmdahlReport:
    """One row of the paper's Table 1."""

    name: str
    accel_time_s: float        # FFT/conv (offloadable) time
    total_time_s: float
    @property
    def fraction(self) -> float:
        return 0.0 if self.total_time_s == 0 else self.accel_time_s / self.total_time_s

    @property
    def end_to_end_speedup(self) -> float:
        return ideal_speedup(min(self.fraction, 1.0))

    def row(self) -> str:
        return (f"{self.name},{self.accel_time_s:.6f},{self.total_time_s:.6f},"
                f"{100.0 * self.fraction:.2f},{self.end_to_end_speedup:.2f}")


def report(name: str, accel_time_s: float, total_time_s: float) -> AmdahlReport:
    if accel_time_s < 0 or total_time_s < 0:
        raise ValueError("times must be non-negative")
    if accel_time_s > total_time_s:
        # Profiling noise can put the category marginally above the total.
        accel_time_s = total_time_s
    return AmdahlReport(name=name, accel_time_s=accel_time_s, total_time_s=total_time_s)
