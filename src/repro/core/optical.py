"""Differentiable 4f optical Fourier/convolution accelerator simulator.

Physics pipeline (paper Fig. 5/7, Appendix A.1), end to end in JAX:

  digital input -> DAC quantization -> SLM encoding (amplitude or phase,
  optional macro-pixel aggregation and nearest-neighbour crosstalk)
  -> Fraunhofer propagation (unitary 2-D DFT; the lens does this "for free")
  -> [optional Fourier-plane mask for convolution]
  -> photodetector |field|^2 with shot + read noise
  -> ADC quantization -> digital output.

The camera is square-law: a single capture yields only the *magnitude* of
the Fourier transform (paper App. A.1).  ``phase_captures=4`` enables
four-step phase-shifting interferometry (Macfaden et al.), recovering the
complex field at 4x the read-out/conversion cost — the cost model in
``repro.core.accelerator`` charges for every capture.

Quantizers use a straight-through estimator so the whole accelerator is
differentiable (useful for hardware-in-the-loop training experiments).

This module is the *functional* model; the *cost* model lives in
``repro.core.accelerator``.  The Pallas TPU kernel implementing the fused
DFT-as-matmul + detector hot path is ``repro.kernels.optical_dft``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "OpticalSimParams",
    "dac_quantize",
    "adc_quantize",
    "adc_quantize_batched",
    "macro_pixel_aggregate",
    "slm_crosstalk",
    "fraunhofer",
    "detector_intensity",
    "optical_fft2_magnitude",
    "optical_fft2_complex",
    "optical_conv2d",
    "optical_conv2d_batched",
    "fourier_mask_for_kernel",
]


@dataclasses.dataclass(frozen=True)
class OpticalSimParams:
    """Physics-fidelity knobs for the simulator (all static under jit).

    Attributes:
      dac_bits / adc_bits: converter resolutions on the write/read paths.
      macro_pixel: aggregate k x k SLM pixels into one logical pixel
        (crosstalk mitigation per Anderson et al.; costs k^2 resolution).
      crosstalk: nearest-neighbour SLM coupling coefficient (0 disables).
      shot_noise: photon shot-noise scale (std = sqrt(I * shot_noise)).
      read_noise: additive detector read noise std (in intensity units).
      reference_amplitude: reference-beam amplitude for phase-shifting
        interferometry (complex recovery).
      encoding: how digital values drive the SLM. ``amplitude`` modulates
        field magnitude in [0,1]; ``phase`` maps [0,1] -> [0, 2pi) phase.
    """

    dac_bits: int = 8
    adc_bits: int = 8
    macro_pixel: int = 1
    crosstalk: float = 0.0
    shot_noise: float = 0.0
    read_noise: float = 0.0
    reference_amplitude: float = 1.0
    encoding: Literal["amplitude", "phase"] = "amplitude"

    def __post_init__(self) -> None:
        if self.dac_bits < 1 or self.adc_bits < 1:
            raise ValueError("converter resolutions must be >= 1 bit")
        if self.macro_pixel < 1:
            raise ValueError("macro_pixel must be >= 1")
        if not 0.0 <= self.crosstalk < 0.25:
            raise ValueError("crosstalk must be in [0, 0.25)")


IDEAL_SIM = OpticalSimParams(dac_bits=16, adc_bits=16)


# --- Converter models --------------------------------------------------------

def _ste_round(x: jax.Array) -> jax.Array:
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def dac_quantize(x: jax.Array, bits: int) -> jax.Array:
    """Uniform quantization of values in [0, 1] to ``bits`` resolution."""
    levels = (1 << bits) - 1
    x = jnp.clip(x, 0.0, 1.0)
    return _ste_round(x * levels) / levels


def adc_quantize(x: jax.Array, bits: int) -> jax.Array:
    """ADC model: auto-ranged uniform quantization of a non-negative signal.

    Real detectors auto-expose; we normalize by the (stop-gradient) max so
    the quantizer always uses its full range, then restore scale.
    """
    levels = (1 << bits) - 1
    scale = jax.lax.stop_gradient(jnp.maximum(jnp.max(x), 1e-20))
    y = jnp.clip(x / scale, 0.0, 1.0)
    return _ste_round(y * levels) / levels * scale


def adc_quantize_batched(x: jax.Array, bits: int) -> jax.Array:
    """Per-frame auto-ranged ADC over a leading batch axis.

    ``x`` is (batch, ...); each frame gets its *own* full-scale setting (a
    camera re-auto-exposes per capture, and frames packed into one batched
    invocation are still read out as independent exposures), so the result
    matches a Python loop of :func:`adc_quantize` over frames exactly —
    batching the readout must not couple one frame's range to another's.
    """
    levels = (1 << bits) - 1
    axes = tuple(range(1, x.ndim))
    scale = jax.lax.stop_gradient(
        jnp.maximum(jnp.max(x, axis=axes, keepdims=True), 1e-20))
    y = jnp.clip(x / scale, 0.0, 1.0)
    return _ste_round(y * levels) / levels * scale


# --- SLM models ---------------------------------------------------------------

def macro_pixel_aggregate(x: jax.Array, k: int) -> jax.Array:
    """Mean-pool k x k blocks (Anderson et al. 3x3 macro pixels).

    Output is (H//k, W//k): the accelerator genuinely loses resolution.
    """
    if k == 1:
        return x
    h, w = x.shape[-2], x.shape[-1]
    hk, wk = (h // k) * k, (w // k) * k
    x = x[..., :hk, :wk]
    x = x.reshape(*x.shape[:-2], hk // k, k, wk // k, k)
    return x.mean(axis=(-3, -1))


def slm_crosstalk(x: jax.Array, c: float) -> jax.Array:
    """Nearest-neighbour pixel coupling: x <- (1-4c) x + c * (4-neighbours)."""
    if c == 0.0:
        return x
    up = jnp.roll(x, 1, axis=-2)
    down = jnp.roll(x, -1, axis=-2)
    left = jnp.roll(x, 1, axis=-1)
    right = jnp.roll(x, -1, axis=-1)
    return (1.0 - 4.0 * c) * x + c * (up + down + left + right)


def _slm_field(values: jax.Array, params: OpticalSimParams) -> jax.Array:
    """Digital values in [0,1] -> complex optical field at the aperture."""
    v = dac_quantize(values, params.dac_bits)
    v = slm_crosstalk(v, params.crosstalk)
    v = macro_pixel_aggregate(v, params.macro_pixel)
    if params.encoding == "amplitude":
        return v.astype(jnp.complex64)
    phase = (2.0 * jnp.pi) * v
    return jnp.exp(1j * phase.astype(jnp.float32))


# --- Propagation and detection ------------------------------------------------

def fraunhofer(field: jax.Array) -> jax.Array:
    """Far-field (Fraunhofer) propagation == unitary 2-D DFT.

    Valid when D >> a and D >> a^2 / lambda (paper App. A.1); the lens in the
    4f system realizes this at distance f.
    """
    return jnp.fft.fft2(field, norm="ortho")


def _raw_intensity(field: jax.Array, params: OpticalSimParams,
                   key: jax.Array | None) -> jax.Array:
    """Square-law detection with shot + read noise (pre-ADC)."""
    intensity = jnp.abs(field) ** 2
    if key is not None and (params.shot_noise > 0.0 or params.read_noise > 0.0):
        shot_key, read_key = jax.random.split(key)
        std = jnp.sqrt(intensity * params.shot_noise)
        intensity = intensity + std * jax.random.normal(shot_key, intensity.shape)
        intensity = intensity + params.read_noise * jax.random.normal(
            read_key, intensity.shape)
        intensity = jnp.maximum(intensity, 0.0)
    return intensity


def detector_intensity(field: jax.Array, params: OpticalSimParams,
                       key: jax.Array | None) -> jax.Array:
    """Square-law detector with shot + read noise, then ADC quantization."""
    return adc_quantize(_raw_intensity(field, params, key), params.adc_bits)


def _phase_shift_captures(out: jax.Array, params: OpticalSimParams,
                          key: jax.Array | None) -> jax.Array:
    """Four-step interferometric capture -> recovered complex field.

    All four exposures share one ADC full-scale setting (a real camera does
    not re-auto-expose between the phase steps; per-capture auto-ranging
    would destroy the linear combination below).
    """
    r = params.reference_amplitude
    keys = jax.random.split(key, 4) if key is not None else [None] * 4
    raw = []
    for theta, k in zip((0.0, 0.5 * jnp.pi, jnp.pi, 1.5 * jnp.pi), keys):
        ref = r * jnp.exp(1j * jnp.asarray(theta, jnp.complex64))
        raw.append(_raw_intensity(out + ref, params, k))
    i0, i90, i180, i270 = jnp.split(
        adc_quantize(jnp.stack(raw), params.adc_bits), 4, axis=0)
    return ((i0 - i180) + 1j * (i90 - i270))[0] / (4.0 * r)


# --- Public accelerator ops ----------------------------------------------------

@functools.partial(jax.jit, static_argnames=("params",))
def optical_fft2_magnitude(values: jax.Array,
                           params: OpticalSimParams = IDEAL_SIM,
                           key: jax.Array | None = None) -> jax.Array:
    """Single-capture accelerator output: |F(values)| (magnitude only).

    ``values`` must be in [0,1] (host is responsible for range mapping; the
    DAC has a fixed full-scale range).
    """
    field = _slm_field(values, params)
    out = fraunhofer(field)
    # the epsilon keeps d/dI sqrt(I) finite at dark pixels (I == 0)
    return jnp.sqrt(jnp.maximum(detector_intensity(out, params, key), 1e-20))


@functools.partial(jax.jit, static_argnames=("params",))
def optical_fft2_complex(values: jax.Array,
                         params: OpticalSimParams = IDEAL_SIM,
                         key: jax.Array | None = None) -> jax.Array:
    """Four-step phase-shifting capture: recovers the complex F(values).

    I_theta = |F + r e^{i theta}|^2 for theta in {0, pi/2, pi, 3pi/2};
    F = ((I_0 - I_pi) + i (I_{pi/2} - I_{3pi/2})) / (4 r).
    Costs 4 exposures + 4 ADC passes (see accelerator cost model).
    """
    field = _slm_field(values, params)
    out = fraunhofer(field)
    return _phase_shift_captures(out, params, key)


@functools.partial(jax.jit, static_argnames=("params",))
def fourier_mask_for_kernel(kernel: jax.Array, shape: tuple[int, int] | None = None,
                            params: OpticalSimParams = IDEAL_SIM) -> jax.Array:
    """Precompute the Fourier-plane mask F(kernel) for a conv kernel.

    In the 4f accelerator the second aperture holds this mask; for repeated
    convolutions with the same kernel (CNNs) its cost is amortized, which is
    why the paper treats kernel upload as negligible next to per-image I/O.
    """
    del params  # the mask is fabricated/programmed at full precision
    if shape is not None:
        h, w = shape
        kernel = jnp.pad(kernel, ((0, h - kernel.shape[0]), (0, w - kernel.shape[1])))
    return jnp.fft.fft2(kernel, norm="ortho")


@functools.partial(jax.jit, static_argnames=("params",))
def optical_conv2d(values: jax.Array, fourier_mask: jax.Array,
                   params: OpticalSimParams = IDEAL_SIM,
                   key: jax.Array | None = None) -> jax.Array:
    """Circular 2-D convolution via the 4f system (paper Eq. 1).

    The optics compute C = F(A) * mask at the camera plane; the *host*
    performs the final inverse transform digitally (paper App. A.1: "the
    optical setup cannot perform the final inverse Fourier transform step").
    Complex capture (4-step) is required for a faithful convolution; the
    cost model charges 4 reads.

    Returns the real part of ifft2(C) scaled back to unnormalized conv units.
    """
    field = _slm_field(values, params)
    c = fraunhofer(field) * fourier_mask
    c_rec = _phase_shift_captures(c, params, key)
    # Host-side digital inverse transform (unitary), undoing the two
    # unitary forward transforms' normalization: a true circular conv is
    # ifft2(fft2(a) * fft2(k)) with no norm, = sqrt(HW) * unitary pipeline.
    h, w = c_rec.shape[-2], c_rec.shape[-1]
    scale = jnp.sqrt(jnp.asarray(h * w, jnp.float32))
    return jnp.real(jnp.fft.ifft2(c_rec, norm="ortho")) * scale


@functools.partial(jax.jit, static_argnames=("params",))
def optical_conv2d_batched(values: jax.Array, fourier_mask: jax.Array,
                           params: OpticalSimParams = IDEAL_SIM,
                           key: jax.Array | None = None) -> jax.Array:
    """Batched 4f convolution: ``values`` is (batch, H, W), ONE dispatch.

    vmap over :func:`optical_conv2d` keeps every per-frame reduction —
    the interferometric captures' shared ADC full-scale, the detector
    auto-range — scoped to its own frame, so results match a Python loop
    of single-frame calls while the host pays one dispatch and the
    simulated aperture is programmed once for the whole batch.
    """
    if key is not None:
        keys = jax.random.split(key, values.shape[0])
        return jax.vmap(
            lambda v, k: optical_conv2d(v, fourier_mask, params, k)
        )(values, keys)
    return jax.vmap(
        lambda v: optical_conv2d(v, fourier_mask, params, None)
    )(values)
