"""Application profiling: per-op-category time and FLOP attribution.

Two complementary profilers, mirroring the paper's methodology (App. C.1 —
cProfile with FFT/conv-named functions attributed to the accelerator):

* ``OpProfiler`` — wall-clock accumulation by category, used by the
  27-benchmark Amdahl suite.  Callers bracket accelerable ops with
  ``prof.op("fft")`` and the driver builds Table-1 rows from the totals.
* ``flops_by_category`` — static attribution: walks a jaxpr (recursing
  through pjit/scan/remat, multiplying by trip counts) and buckets FLOPs
  into {matmul, conv, fft, other}.  This is how the planner evaluates
  offload for the 10 assigned LM architectures without timing them.
"""

from __future__ import annotations

import collections
import contextlib
import time
from typing import Any, Callable

import jax
import numpy as np

__all__ = ["OpProfiler", "flops_by_category", "OFFLOADABLE_CATEGORIES"]

OFFLOADABLE_CATEGORIES = ("fft", "conv", "matmul")


def _block(x: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


class OpProfiler:
    """Accumulates wall time by op category.

    Uses ``time.perf_counter`` and blocks on JAX arrays leaving a bracketed
    region so device-async execution cannot leak accelerable time into the
    'other' bucket (the paper's cProfile methodology has the same role).
    """

    def __init__(self) -> None:
        self.seconds: dict[str, float] = collections.defaultdict(float)
        self.calls: dict[str, int] = collections.defaultdict(int)
        self.samples_in: dict[str, int] = collections.defaultdict(int)
        self.samples_out: dict[str, int] = collections.defaultdict(int)
        self._t0: float | None = None

    # -- session -------------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        if self._t0 is None:
            raise RuntimeError("profiler not started")
        total = time.perf_counter() - self._t0
        self.seconds["__total__"] += total
        self._t0 = None
        return total

    # -- op bracketing ---------------------------------------------------------
    @contextlib.contextmanager
    def op(self, category: str, n_in: int = 0, n_out: int = 0):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.seconds[category] += time.perf_counter() - t0
            self.calls[category] += 1
            self.samples_in[category] += int(n_in)
            self.samples_out[category] += int(n_out)

    def run(self, category: str, fn: Callable, *args, **kwargs):
        """Run ``fn`` under ``category``, blocking on its outputs."""
        n_in = sum(int(np.size(a)) for a in jax.tree_util.tree_leaves((args, kwargs))
                   if hasattr(a, "shape"))
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        _block(out)
        dt = time.perf_counter() - t0
        n_out = sum(int(np.size(a)) for a in jax.tree_util.tree_leaves(out)
                    if hasattr(a, "shape"))
        self.seconds[category] += dt
        self.calls[category] += 1
        self.samples_in[category] += n_in
        self.samples_out[category] += n_out
        return out

    # -- reporting --------------------------------------------------------------
    @property
    def total_s(self) -> float:
        return self.seconds.get("__total__", 0.0)

    def accelerable_s(self, categories=("fft", "conv")) -> float:
        return sum(self.seconds.get(c, 0.0) for c in categories)

    def fraction(self, categories=("fft", "conv")) -> float:
        tot = self.total_s
        return 0.0 if tot == 0.0 else min(self.accelerable_s(categories) / tot, 1.0)


# --- Static jaxpr FLOP attribution -----------------------------------------------


def _shape(var) -> tuple[int, ...]:
    return tuple(getattr(var.aval, "shape", ()) or ())


def _nelem(var) -> int:
    return int(np.prod(_shape(var), dtype=np.int64)) if _shape(var) else 1


def _dot_general_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = _shape(eqn.invars[0]), _shape(eqn.invars[1])
    batch = float(np.prod([lhs[i] for i in lb], dtype=np.float64)) if lb else 1.0
    contract = float(np.prod([lhs[i] for i in lc], dtype=np.float64)) if lc else 1.0
    m = float(np.prod([d for i, d in enumerate(lhs) if i not in lc and i not in lb],
                      dtype=np.float64))
    n = float(np.prod([d for i, d in enumerate(rhs) if i not in rc and i not in rb],
                      dtype=np.float64))
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out_elems = float(_nelem(eqn.outvars[0]))
    rhs = _shape(eqn.invars[1])  # (out_ch, in_ch/groups, *spatial) in default dnums
    dn = eqn.params["dimension_numbers"]
    spatial = [rhs[i] for i in dn.rhs_spec[2:]]
    in_ch = rhs[dn.rhs_spec[1]]
    return 2.0 * out_elems * in_ch * float(np.prod(spatial, dtype=np.float64))


def _fft_flops(eqn) -> float:
    shape = _shape(eqn.invars[0])
    lens = eqn.params["fft_lengths"]
    batch = float(np.prod(shape, dtype=np.float64)) / max(
        float(np.prod(lens, dtype=np.float64)), 1.0)
    n = float(np.prod(lens, dtype=np.float64))
    return 5.0 * batch * n * max(np.log2(max(n, 2.0)), 1.0)


_CALL_PARAM = {
    "jit": "jaxpr", "pjit": "jaxpr", "closed_call": "call_jaxpr",
    "remat2": "jaxpr", "checkpoint": "jaxpr",
    "custom_jvp_call": "call_jaxpr", "custom_vjp_call": "call_jaxpr",
    "custom_vjp_call_jaxpr": "fun_jaxpr",
}


def _walk(jaxpr, mult: float, out: dict[str, float]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            out["matmul"] += mult * _dot_general_flops(eqn)
        elif name == "conv_general_dilated":
            out["conv"] += mult * _conv_flops(eqn)
        elif name == "fft":
            out["fft"] += mult * _fft_flops(eqn)
        elif name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            _walk(inner, mult * float(eqn.params["length"]), out)
        elif name == "while":
            # Trip count is data-dependent; attribute one iteration and flag.
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, out)
            out["__while_unknown_trips__"] += 1.0
        elif name == "cond":
            for br in eqn.params["branches"]:
                _walk(br.jaxpr, mult / max(len(eqn.params["branches"]), 1), out)
        elif name in _CALL_PARAM:
            inner = eqn.params.get(_CALL_PARAM[name])
            if inner is not None:
                _walk(getattr(inner, "jaxpr", inner), mult, out)
        else:
            out["other"] += mult * sum(float(_nelem(v)) for v in eqn.outvars)


_NO_TRAFFIC = {"reshape", "bitcast", "bitcast_convert_type", "squeeze",
               "broadcast_in_dim", "stop_gradient", "copy"}


def _walk_bytes(jaxpr, mult: float, acc: list) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            _walk_bytes(eqn.params["jaxpr"].jaxpr,
                        mult * float(eqn.params["length"]), acc)
        elif name == "while":
            _walk_bytes(eqn.params["body_jaxpr"].jaxpr, mult, acc)
        elif name == "cond":
            for br in eqn.params["branches"]:
                _walk_bytes(br.jaxpr, mult / max(len(eqn.params["branches"]), 1),
                            acc)
        elif name in _CALL_PARAM:
            inner = eqn.params.get(_CALL_PARAM[name])
            if inner is not None:
                _walk_bytes(getattr(inner, "jaxpr", inner), mult, acc)
        elif name in _NO_TRAFFIC:
            continue
        else:
            b = 0.0
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is None or not getattr(aval, "shape", None):
                    continue
                b += float(np.prod(aval.shape, dtype=np.float64)) \
                    * np.dtype(aval.dtype).itemsize
            acc[0] += mult * b


def traffic_bytes(fn: Callable, *args, **kwargs) -> float:
    """Scan-aware estimate of total memory traffic (operand+result bytes of
    every op, trip-count multiplied).  Fusion-naive: elementwise chains are
    counted per op, so this is an *upper bound* on HBM traffic — but unlike
    cost_analysis it does not under-count loop bodies or over-scale one-time
    ops, making it the consistent numerator for the roofline memory term.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    acc = [0.0]
    _walk_bytes(closed.jaxpr, 1.0, acc)
    return acc[0]


def flops_by_category(fn: Callable, *args, **kwargs) -> dict[str, float]:
    """Trace ``fn`` and attribute FLOPs to {matmul, conv, fft, other}.

    'other' counts one FLOP per produced element of every non-contraction op
    (a deliberate *under*-estimate of memory-bound time: the planner treats
    'other' as non-offloadable, so under-counting it makes the offload verdict
    *more* generous to the accelerator — the paper's best-case methodology).
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    out: dict[str, float] = collections.defaultdict(float)
    _walk(closed.jaxpr, 1.0, out)
    return dict(out)
