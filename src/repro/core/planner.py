"""Conversion-aware offload planner (the paper's §4–§6 decision rule, executable).

Given a per-category workload profile (host seconds + boundary sample counts)
and an analog accelerator spec, the planner:

  1. prices each accelerable category on the accelerator *including* the
     DAC/ADC + interface costs (the paper's whole point — never price the
     analog compute alone);
  2. offloads a category only when the priced accelerator time beats the host
     AND its observed quantization error (``CategoryProfile.rel_err``, fed by
     the runtime's fidelity shadowing) stays inside the budget implied by the
     converters' ENOB — the paper's argument cuts both ways: skimping on
     conversion buys speed by spending accuracy, and a category whose error
     blows the bound must not be offloaded no matter how fast it runs
     (``OffloadDecision.fidelity_bound`` records the veto);
  3. reports the end-to-end Amdahl speedup, the zero-cost ideal bound
     (paper Table 1), and the verdict against the 10x build-threshold (§5).

The same machinery runs against the 27-benchmark suite (time-profiled) and
the 10 assigned LM architectures (FLOP-profiled via
``repro.core.profiler.flops_by_category``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core import amdahl
from repro.core.accelerator import (
    OpticalFourierAcceleratorSpec,
    OpticalMVMAcceleratorSpec,
)
from repro.core.conversion import enob_error_bound

__all__ = [
    "CategoryProfile",
    "OffloadDecision",
    "OffloadPlan",
    "plan_offload",
    "BUILD_THRESHOLD",
]

# §5: accelerators must deliver >= 10x on a metric users care about.
BUILD_THRESHOLD = 10.0


@dataclasses.dataclass(frozen=True)
class CategoryProfile:
    """Workload of one op category over a full application run.

    host_s: wall time the host spends in this category.
    calls: number of accelerator invocations offload would require.
    samples_in / samples_out: scalars crossing the conversion boundary per
      *run* (summed over calls).
    host_post_s: digital post-processing that offload cannot remove (e.g.
      the host-side inverse FFT of the 4f convolution pipeline).
    rel_err: observed relative error of this category's offloaded execution
      (worst ``FidelityChecker`` shadow score), or None when never shadowed.
      Fed by ``PlanRouter.replan`` so a category whose measured error blows
      the converters' ENOB budget is fidelity-gated off the accelerator.
    """

    name: str
    host_s: float
    calls: int = 1
    samples_in: int = 0
    samples_out: int = 0
    host_post_s: float = 0.0
    rel_err: float | None = None


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    category: str
    host_s: float
    accel_s: float          # conversion + interface + analog + residual host
    conversion_s: float     # DAC+ADC share of accel_s
    offload: bool
    # True when the category's observed rel_err exceeds the ENOB budget:
    # offload is vetoed on accuracy grounds regardless of speedup.
    fidelity_bound: bool = False

    @property
    def category_speedup(self) -> float:
        if not self.offload or self.accel_s <= 0:
            return 1.0
        return self.host_s / self.accel_s


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    accelerator: str
    decisions: tuple[OffloadDecision, ...]
    total_host_s: float
    total_planned_s: float

    @property
    def end_to_end_speedup(self) -> float:
        if self.total_planned_s <= 0:
            return math.inf
        return self.total_host_s / self.total_planned_s

    @property
    def offloaded_fraction(self) -> float:
        if self.total_host_s <= 0:
            return 0.0
        off = sum(d.host_s for d in self.decisions if d.offload)
        return min(off / self.total_host_s, 1.0)

    @property
    def ideal_speedup(self) -> float:
        """Paper Table 1 column: zero-cost accelerator Amdahl bound."""
        return amdahl.ideal_speedup(self.offloaded_fraction)

    @property
    def worthwhile(self) -> bool:
        return self.end_to_end_speedup >= BUILD_THRESHOLD

    @property
    def conversion_bound(self) -> bool:
        """True when conversion dominates planned accelerator time."""
        conv = sum(d.conversion_s for d in self.decisions if d.offload)
        acc = sum(d.accel_s for d in self.decisions if d.offload)
        return acc > 0 and conv / acc > 0.5

    @property
    def fidelity_bound(self) -> bool:
        """True when any category was vetoed on accuracy: its observed
        quantization error exceeds the converters' ENOB budget, so it stays
        on the host regardless of speedup."""
        return any(d.fidelity_bound for d in self.decisions)

    def summary(self) -> str:
        rows = [f"plan[{self.accelerator}] speedup={self.end_to_end_speedup:.2f}x "
                f"(ideal={self.ideal_speedup:.2f}x, f={self.offloaded_fraction:.2%}, "
                f"worthwhile={self.worthwhile}, "
                f"conversion_bound={self.conversion_bound}, "
                f"fidelity_bound={self.fidelity_bound})"]
        for d in self.decisions:
            gate = " FIDELITY-GATED" if d.fidelity_bound else ""
            rows.append(f"  {d.category:>8}: host={d.host_s:.4g}s "
                        f"accel={d.accel_s:.4g}s (conv {d.conversion_s:.4g}s) "
                        f"offload={d.offload}{gate}")
        return "\n".join(rows)


_SUPPORTS: Mapping[type, tuple[str, ...]] = {
    OpticalFourierAcceleratorSpec: ("fft", "conv"),
    OpticalMVMAcceleratorSpec: ("matmul",),
}


def _price(spec, prof: CategoryProfile,
           max_batch: int = 1) -> tuple[float, float]:
    """Accelerator wall time and its conversion share for one category.

    With ``max_batch > 1`` the category's calls are priced as coalesced
    invocations of up to ``max_batch`` same-shape calls each (the runtime
    executor's batching): fixed per-invocation boundary costs amortize, so
    the verdict reflects how the offload would actually be executed.
    """
    if prof.calls <= 0:
        return 0.0, 0.0
    n_in = max(prof.samples_in // prof.calls, 1)
    n_out = max(prof.samples_out // prof.calls, 1) if prof.samples_out else n_in
    batch = max(min(max_batch, prof.calls), 1)
    if batch > 1 and hasattr(spec, "batched_step_cost"):
        full, rem = divmod(prof.calls, batch)
        total = conv = 0.0
        for b, count in ((batch, full), (rem, 1 if rem else 0)):
            if count:
                cost = spec.batched_step_cost(n_in, n_out, batch=b)
                total += cost.total_s * count
                conv += cost.conversion_s * count
        return total + prof.host_post_s, conv
    cost = spec.step_cost(n_in, n_out)
    total = cost.total_s * prof.calls + prof.host_post_s
    return total, cost.conversion_s * prof.calls


def plan_offload(profiles: Sequence[CategoryProfile],
                 spec: OpticalFourierAcceleratorSpec | OpticalMVMAcceleratorSpec,
                 *, max_batch: int | Mapping[str, int] = 1,
                 fidelity_slack: float = 16.0) -> OffloadPlan:
    """Price every category on ``spec`` and keep only profitable offloads.

    ``max_batch=1`` (default) is the paper's serial one-call-per-crossing
    model; a larger int prices the runtime's batched execution uniformly,
    and a ``{category: batch}`` mapping prices each category at its own
    coalescing depth (absent categories price serially).

    Offload is additionally *fidelity-gated*: a profile carrying an
    observed ``rel_err`` above the relative-error budget implied by the
    spec's limiting converter ENOB (``enob_error_bound``, widened by
    ``fidelity_slack`` — the ``FidelityChecker`` default) is kept on the
    host even when the accelerator is faster, and its decision records
    ``fidelity_bound=True``.  Profiles without an observed error (never
    shadowed) are gated on speed alone, as before.
    """
    supported = ()
    for klass, cats in _SUPPORTS.items():
        if isinstance(spec, klass):
            supported = cats
            break
    enob = min(spec.dac.effective_bits, spec.adc.effective_bits)
    err_budget = enob_error_bound(enob, fidelity_slack)
    decisions = []
    total_host = 0.0
    total_planned = 0.0
    for prof in profiles:
        total_host += prof.host_s
        if prof.name in supported and prof.host_s > 0:
            cat_batch = max_batch.get(prof.name, 1) \
                if isinstance(max_batch, Mapping) else max_batch
            accel_s, conv_s = _price(spec, prof, cat_batch)
            fidelity_bound = (prof.rel_err is not None
                              and prof.rel_err > err_budget)
            offload = accel_s < prof.host_s and not fidelity_bound
            decisions.append(OffloadDecision(
                category=prof.name, host_s=prof.host_s, accel_s=accel_s,
                conversion_s=conv_s, offload=offload,
                fidelity_bound=fidelity_bound))
            total_planned += accel_s if offload else prof.host_s
        else:
            decisions.append(OffloadDecision(
                category=prof.name, host_s=prof.host_s, accel_s=math.inf,
                conversion_s=0.0, offload=False))
            total_planned += prof.host_s
    return OffloadPlan(accelerator=spec.name, decisions=tuple(decisions),
                       total_host_s=total_host, total_planned_s=total_planned)
