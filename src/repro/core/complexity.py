"""Computational vs conversion complexity (paper §4, Fig. 3).

The paper's rule: an analog accelerator is only worth feeding when the
computational complexity of the offloaded op dominates the conversion
complexity C = 2N of moving its operands across the digital/analog boundary.
"""

from __future__ import annotations

import math
from typing import Callable

__all__ = ["PROBLEM_CLASSES", "crossover_n", "advantage"]


# name -> f(N) compute cost (abstract op counts), as plotted in Fig. 3.
PROBLEM_CLASSES: dict[str, Callable[[float], float]] = {
    "elementwise O(N)": lambda n: n,
    "fft O(N log N)": lambda n: n * max(math.log2(n), 1.0),
    "matvec O(N^2)": lambda n: n ** 2,
    "matmul O(N^3)": lambda n: n ** 3,
    "ising O(2^N)": lambda n: 2.0 ** min(n, 1000.0),  # capped: float overflow
}


def conversion_cost(n: float) -> float:
    """C = 2N: DAC in + ADC out for every datum."""
    return 2.0 * n


def advantage(problem: str, n: float) -> float:
    """compute_cost / conversion_cost — how much headroom offload has."""
    if problem not in PROBLEM_CLASSES:
        raise KeyError(f"unknown problem class {problem!r}")
    if n <= 0:
        raise ValueError("n must be positive")
    return PROBLEM_CLASSES[problem](n) / conversion_cost(n)


def crossover_n(problem: str, threshold: float = 1.0,
                n_max: float = 2.0 ** 40) -> float | None:
    """Smallest N (power of two) where compute/conversion >= threshold.

    Returns None when the class never crosses (e.g. O(N) is pinned at 0.5x:
    such accelerators are *always* conversion-bound — the paper's point).
    """
    n = 1.0
    while n <= n_max:
        if advantage(problem, n) >= threshold:
            return n
        n *= 2.0
    return None
