"""Batched serving runtime (continuous batching over fixed cache slots)."""

from repro.serving.engine import Request, ServingEngine

__all__ = ["Request", "ServingEngine"]
