"""Batched serving engine: continuous batching over fixed cache slots.

  * ``submit`` queues requests (prompt token lists);
  * ``step`` admits queued requests into free slots (single-lane prefill,
    cache splice) and runs ONE batched ``decode_step`` for all slots —
    the cache carries per-lane positions, so lanes at different depths
    decode together (continuous batching);
  * finished sequences (EOS / max_new_tokens / cache full) free slots.

Static shapes: one compilation for prefill (per prompt length bucket) and
one for decode.  The decode step function is exactly what the decode_32k /
long_500k dry-run cells lower.

Analog offload (opt-in): pass ``offload=`` a ``repro.runtime``
``OffloadScheduler``, ``PlanRouter``, or bare ``OffloadExecutor`` and
attention-adjacent FFT/conv work — e.g. spectral retrieval scoring or conv
feature extraction riding along with generation — can be queued via
:meth:`ServingEngine.submit_aux`.  With a scheduler, the decode step runs
an admission *poll* instead of a forced flush: aux groups may be held open
across decode steps under the scheduler's deadline, so trickle aux traffic
accumulates occupancy across steps instead of crossing the conversion
boundary once per step — continuous batching on both sides of the engine.
With a plain router/executor the engine keeps the legacy behavior
(flush once per decode step), which already coalesces aux calls submitted
by different requests within a step into one boundary crossing (the
paper's §6 lever).  Either way the runtime's telemetry observes real
serving traffic for re-planning.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import LM

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None,
                 prompt_bucket: int = 1, offload: Any | None = None) -> None:
        self.cfg = cfg
        self.model = LM(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.bucket = prompt_bucket
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.cache = self.model.init_cache(batch_slots, max_len)
        self.last_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self.live = [False] * batch_slots
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len=max_len))
        # analog-offload hook: an OffloadScheduler / PlanRouter /
        # OffloadExecutor (duck-typed on submit/flush/pending, schedulers
        # additionally on poll) or None; aux submissions batch across
        # decode steps.
        self.offload = offload

    # -- client API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def submit_aux(self, category: str, x: jax.Array, **kwargs):
        """Queue attention-adjacent FFT/conv/matmul work on the offload
        runtime; returns an ``OffloadResult`` handle.  With a plain
        router/executor hook it materializes at the next decode step; with
        an ``OffloadScheduler`` hook it materializes when admission control
        releases its group (full / deadline / futile — possibly several
        decode steps later).  ``handle.get()`` always forces it.  Requires
        the engine to have been constructed with ``offload=``."""
        if self.offload is None:
            raise RuntimeError("engine built without offload= runtime")
        return self.offload.submit(category, x, **kwargs)

    @property
    def pending_aux(self) -> int:
        # the runtime's queue is the single source of truth: callers may
        # drain it directly (handle.get(), router.flush()) between steps
        return self.offload.pending if self.offload is not None else 0

    def flush_aux(self) -> list:
        """Dispatch queued aux work as batched accelerator invocations."""
        return self.offload.flush() if self.offload is not None else []

    def idle(self) -> bool:
        return not self.queue and not self.active and not self.pending_aux

    # -- internals -------------------------------------------------------------
    def _splice_slot(self, slot: int, slot_cache: Any) -> None:
        """Copy a prefilled 1-lane cache into lane ``slot`` of the batch
        cache (every cache leaf's lane dim is the one sized batch_slots
        where the source's is 1)."""
        def put(dst, src):
            if not hasattr(dst, "ndim") or dst.ndim == 0:
                return dst
            for d in range(dst.ndim):
                if dst.shape[d] == self.slots and src.shape[d] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[d] = slice(slot, slot + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            return dst
        self.cache = jax.tree_util.tree_map(put, self.cache, slot_cache)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            plen = len(req.prompt)
            # optional left-pad bucketing bounds prefill recompiles; pad
            # tokens occupy real cache slots (set prompt_bucket=1 for exact)
            pad = (-plen) % self.bucket
            toks = jnp.asarray([0] * pad + req.prompt, jnp.int32)[None, :]
            slot_cache, logits = self._prefill(self.params, {"tokens": toks})
            self._splice_slot(slot, slot_cache)
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            self.last_token = self.last_token.at[slot, 0].set(nxt)
            self.live[slot] = True
            self.active[slot] = req

    def step(self) -> list[Request]:
        """Admit waiting requests, run the aux offload admission pass (a
        scheduler poll when one is driving — held groups survive the step;
        a forced flush otherwise), then one batched decode step."""
        self._admit()
        poll = getattr(self.offload, "poll", None)
        if poll is not None:
            # scheduler-driven: release only full/due/futile groups; a
            # partially filled group rides to the next decode step
            poll()
        elif self.pending_aux:
            self.flush_aux()
        if not self.active:
            return []
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_token)
        finished = []
        pos_host = jax.device_get(self.cache["pos"])
        for slot, req in list(self.active.items()):
            nxt = int(jnp.argmax(logits[slot]))
            req.out_tokens.append(nxt)
            self.last_token = self.last_token.at[slot, 0].set(nxt)
            if (self.eos_id is not None and nxt == self.eos_id) \
                    or len(req.out_tokens) >= req.max_new_tokens \
                    or int(pos_host[slot]) >= self.max_len - 1:
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.live[slot] = False
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self.idle():
                break
        return done
