"""Data pipeline: deterministic, resumable, sharded synthetic sources."""

from repro.data.pipeline import MarkovTask, SyntheticTask, make_batch_sharding

__all__ = ["SyntheticTask", "MarkovTask", "make_batch_sharding"]
