"""Deterministic, resumable, sharded data pipeline.

Batches are pure functions of ``(seed, step)`` via PRNG fold-in, so the
pipeline's entire checkpointable state is one integer: restart/elastic
resume re-produce bit-identical batches with no data-loader state files,
and any host can materialize exactly its shard of any step (multi-host
determinism for free).

Two sources:
  * ``SyntheticTask``  — uniform random tokens (shape/throughput testing).
  * ``MarkovTask``     — an order-1 Markov chain with low-entropy rows; a
    model that learns must drive CE below the unigram entropy, so training
    examples show real loss curves (used by examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticTask", "MarkovTask", "make_batch_sharding"]


@dataclasses.dataclass(frozen=True)
class SyntheticTask:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = jax.random.randint(key, (self.global_batch, self.seq_len + 1),
                                  0, self.vocab_size, jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class MarkovTask:
    """Order-1 Markov chain over the vocab; rows concentrate on ~8 tokens."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8

    def _transitions(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        nxt = rng.integers(0, self.vocab_size,
                           size=(self.vocab_size, self.branching))
        return nxt.astype(np.int32)

    def batch(self, step: int) -> dict[str, jax.Array]:
        nxt = jnp.asarray(self._transitions())
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k0, k1 = jax.random.split(key)
        state = jax.random.randint(k0, (self.global_batch,), 0,
                                   self.vocab_size, jnp.int32)
        choices = jax.random.randint(k1, (self.global_batch, self.seq_len),
                                     0, self.branching, jnp.int32)

        def walk(s, c):
            s = nxt[s, c]
            return s, s

        _, seq = jax.lax.scan(walk, state, choices.T)
        toks = jnp.concatenate([state[:, None], seq.T], axis=1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @property
    def entropy_floor_nats(self) -> float:
        """CE floor for a perfect model: log(branching) (uniform choices)."""
        return float(np.log(self.branching))


def make_batch_sharding(mesh: jax.sharding.Mesh) -> jax.sharding.NamedSharding:
    """Batch dim sharded over every data-like mesh axis (pod + data)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes if axes else None))
