import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script builds the real step function (train / prefill /
decode), lowers it under the production mesh with explicit in/out
shardings, compiles it, and records:

  * ``memory_analysis()``   — per-device bytes (proves the cell fits HBM)
  * ``cost_analysis()``     — HLO FLOPs / bytes-accessed (roofline terms)
  * collective bytes        — parsed from the optimized HLO text: summed
    operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute ops (cost_analysis does not report these)

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline reader (benchmarks/roofline.py) consumes them.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfgs
from repro.distributed.specs import batch_pspecs, cache_pspecs, opt_pspecs
from repro.launch.mesh import make_production_mesh
from repro.models import LM, param_pspecs, param_shape_structs
from repro.models.params import param_counts
from repro.optim import adafactor, adamw
from repro.train.steps import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")

# microbatch accumulation per (arch family size): bounds activation peak
ACCUM = {"nemotron-4-340b": 8, "deepseek-v3-671b": 8, "qwen2-72b": 4,
         "qwen2.5-32b": 4, "llava-next-34b": 4, "recurrentgemma-9b": 2}

# >=30B params: Adafactor (factored 2nd moment); else AdamW
ADAFACTOR_ARCHS = {"qwen2-72b", "qwen2.5-32b", "nemotron-4-340b",
                   "llava-next-34b", "deepseek-v3-671b"}

# Winning per-arch settings from the Sec-Perf hillclimb (EXPERIMENTS.md):
# act: residual-stream sharding mode; group: 2-level remat group size;
# accum: microbatch count override; moe_cf: MoE capacity factor override.
OPT_SETTINGS = {
    "qwen2-72b": {"act": "sp"},
    "deepseek-v3-671b": {"moe_cf": 1.0},
    "nemotron-4-340b": {"group": 8, "accum": 16},
}


def apply_opt(arch: str) -> None:
    o = OPT_SETTINGS.get(arch, {})
    os.environ["REPRO_ACT_SHARDING"] = o.get("act", "baseline")
    os.environ["REPRO_REMAT_GROUP"] = str(o.get("group", 1))
    if "accum" in o:
        ACCUM[arch] = o["accum"]
    if "moe_cf" in o:
        # the override is read by build_cell from the environment
        os.environ["REPRO_MOE_CF"] = str(o["moe_cf"])
    else:
        os.environ.pop("REPRO_MOE_CF", None)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                     r"((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s16|u16|s8|u8|pred|f64|c64)"
                       r"\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_BYTES = {"f64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4, "f16": 2,
          "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_text: str) -> float:
    nbytes = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _BYTES[dt]
    return nbytes


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by collectives in the optimized (partitioned)
    HLO.  For each collective op we count max(result bytes, operand bytes):
    result-dominant for all-gather, operand-dominant for reduce-scatter,
    equal for all-reduce / all-to-all / collective-permute.  Async pairs
    count once (the -start; -done is skipped)."""
    defs: dict[str, float] = {}
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_text, opname = m.groups()
        defs[name] = _shape_bytes(shape_text)
        kind = next((c for c in _COLLECTIVES if opname.startswith(c)), None)
        if kind is None or opname.endswith("-done"):
            continue
        args_text = line[m.end():]
        args_text = args_text.split("metadata=")[0].split("replica_groups=")[0]
        operand_bytes = sum(defs.get(nm, 0.0)
                            for nm in _OPERAND_RE.findall(args_text))
        out[kind] = out.get(kind, 0.0) + max(defs[name], operand_bytes)
    return out


def _tree_bytes(sds_tree) -> int:
    tot = 0
    for leaf in jax.tree_util.tree_leaves(sds_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        tot += n * jnp.dtype(leaf.dtype).itemsize
    return tot


def analytic_memory(cfg, sh, mesh, accum, p_sds, opt_sds, cache_sds) -> dict:
    """Per-chip residency model for the TPU target (HLO `temp` on the CPU
    backend over-reports: xla:cpu upcasts bf16 math to f32 and hoists
    whole-stack converts out of scan loops — see EXPERIMENTS.md §Dry-run).

    params/opt: template bytes / (tp x fsdp);  grads: one more param copy;
    activations: saved scan carries (n_layers x microbatch x S x d) x1.5
    for per-block extras;  cache: sharded decode cache.
    """
    tp = mesh.shape["model"]
    dp = mesh.size // tp
    fsdp = mesh.shape["data"] if cfg.param_dtype == "bfloat16" else 1
    shard = tp * fsdp
    out = {"params": _tree_bytes(p_sds) / shard}
    out["opt"] = _tree_bytes(opt_sds) / shard if opt_sds is not None else 0.0
    out["grads"] = out["params"]
    if sh.kind == "train":
        mb = max(sh.global_batch // (dp * accum), 1)
        act = 2  # bf16 activations
        layers = cfg.n_layers + cfg.encoder_layers
        out["activations"] = 1.5 * layers * mb * sh.seq_len * cfg.d_model * act
    else:
        out["grads"] = 0.0
        mb = max(sh.global_batch // dp, 1)
        out["activations"] = 3 * mb * sh.seq_len * cfg.d_model * 2 \
            if sh.kind == "prefill" else 0.0
    out["cache"] = _tree_bytes(cache_sds) / mesh.size if cache_sds is not None else 0.0
    out["total"] = sum(out.values())
    out = {k: float(v) for k, v in out.items()}
    out["fits_16gb"] = bool(out["total"] < 16 * 2 ** 30)
    return out


def _named(tree_pspec, mesh):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), tree_pspec,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate, extras)."""
    cfg = cfgs.get_config(arch)
    if os.environ.get("REPRO_MOE_CF") and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(os.environ["REPRO_MOE_CF"])))
    sh = cfgs.SHAPES[shape_name]
    model = LM(cfg)
    tp = mesh.shape["model"]
    fsdp = mesh.shape["data"] if cfg.param_dtype == "bfloat16" else 0
    p_ps = param_pspecs(cfg, fsdp_size=fsdp, tp_size=tp)
    p_sds = param_shape_structs(cfg)
    mesh_axes = tuple(mesh.axis_names)

    if sh.kind == "train":
        opt = (adafactor(1e-4) if arch in ADAFACTOR_ARCHS else adamw(1e-4))
        step_fn = make_train_step(LM(cfg), opt, accum_steps=ACCUM.get(arch, 1))
        batch_sds = cfgs.input_specs(cfg, sh)
        opt_sds = jax.eval_shape(opt.init, p_sds)
        o_ps = opt_pspecs(opt_sds, p_ps)
        b_ps = batch_pspecs(batch_sds, mesh_axes)
        in_sh = (_named(p_ps, mesh), _named(o_ps, mesh), _named(b_ps, mesh),
                 NamedSharding(mesh, P()))
        out_sh = (_named(p_ps, mesh), _named(o_ps, mesh), None)
        args = (p_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        extras = {"opt_sds": opt_sds, "cache_sds": None,
                  "accum": ACCUM.get(arch, 1)}
        return step_fn, args, in_sh, out_sh, (0, 1), extras

    if sh.kind == "prefill":
        batch_sds = cfgs.input_specs(cfg, sh)
        b_ps = batch_pspecs(batch_sds, mesh_axes)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len=sh.seq_len + 128)

        cache_sds = jax.eval_shape(prefill_fn, p_sds, batch_sds)[0]
        c_ps = cache_pspecs(cfg, cache_sds, mesh_axes, tp, sh.global_batch)
        in_sh = (_named(p_ps, mesh), _named(b_ps, mesh))
        out_sh = (_named(c_ps, mesh), None)
        extras = {"opt_sds": None, "cache_sds": cache_sds, "accum": 1}
        return prefill_fn, (p_sds, batch_sds), in_sh, out_sh, (), extras

    # decode: one token against a seq_len cache
    def init_cache():
        return model.init_cache(sh.global_batch, sh.seq_len)

    cache_sds = jax.eval_shape(init_cache)
    if cfg.is_encdec:  # decode against encoder memory
        enc_sds = jax.ShapeDtypeStruct(
            (sh.global_batch, 4096, cfg.d_model), cfg.activation_dtype)
        cache_sds = dict(cache_sds, enc_out=enc_sds)
    c_ps = cache_pspecs(cfg, cache_sds, mesh_axes, tp, sh.global_batch)
    tok_sds = cfgs.input_specs(cfg, sh)["tokens"]
    b_ps = batch_pspecs({"tokens": tok_sds}, mesh_axes)["tokens"]

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    in_sh = (_named(p_ps, mesh), _named(c_ps, mesh), NamedSharding(mesh, b_ps))
    out_sh = (None, _named(c_ps, mesh))
    extras = {"opt_sds": None, "cache_sds": cache_sds, "accum": 1}
    return decode_fn, (p_sds, cache_sds, tok_sds), in_sh, out_sh, (1,), extras


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, art_dir: str | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh, donate, extras = build_cell(arch, shape_name, mesh)
    from repro.distributed.compat import enter_mesh
    enter_mesh(mesh)   # context mesh: makes with_sharding_constraint live
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
    # XLA cost_analysis counts while/scan bodies ONCE; our jaxpr walker
    # multiplies by trip counts, giving exact *global* FLOPs.  The ratio
    # (jaxpr_flops/devices) / hlo_flops is the scan-correction factor we
    # apply to the (same-shaped) bytes and collective estimates.
    from repro.core.profiler import flops_by_category, traffic_bytes
    with mesh:
        jcat = flops_by_category(fn, *args)
        jbytes = traffic_bytes(fn, *args)
    jflops = sum(v for k, v in jcat.items() if not k.startswith("__"))
    hlo_flops = float(cost.get("flops", 0.0))
    scan_corr = (jflops / mesh.size) / hlo_flops if hlo_flops > 0 else 1.0
    scan_corr = max(scan_corr, 1.0)

    cfg = cfgs.get_config(arch)
    total_p, active_p = param_counts(cfg)
    analytic = analytic_memory(cfg, cfgs.SHAPES[shape_name], mesh,
                               extras["accum"], args[0], extras["opt_sds"],
                               extras["cache_sds"])
    record = {
        "cell": cell_id, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": int(mesh.size),
        "flops": hlo_flops,
        "jaxpr_flops_global": float(jflops),
        "jaxpr_flops_by_category": {k: float(v) for k, v in jcat.items()},
        "scan_correction": float(scan_corr),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "bytes_accessed_corrected": float(cost.get("bytes accessed", 0.0))
        * float(scan_corr),
        "jaxpr_traffic_bytes_global": float(jbytes),
        "collective_bytes": coll,
        "collective_bytes_total": float(sum(coll.values())),
        "collective_bytes_corrected": float(sum(coll.values())) * float(scan_corr),
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        # residency estimate: args + outputs + temps - aliased (donated) pairs
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes),
        "analytic_memory_per_device": analytic,
        "params_total": total_p, "params_active": active_p,
        "accum_steps": extras["accum"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    print(f"[dryrun] {cell_id}: flops={record['flops']:.3e} "
          f"bytes={record['bytes_accessed']:.3e} "
          f"coll={record['collective_bytes_total']:.3e} "
          f"peak/dev={(record['peak_bytes_per_device'] or 0)/2**30:.2f}GiB "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    if save:
        d = art_dir or ARTIFACT_DIR
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, cell_id + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in cfgs.ARCHS:
        fam = cfgs.get_config(arch).family
        for shape_name in cfgs.applicable_shapes(fam):
            out.append((arch, shape_name))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="optimized activation sharding (REPRO_ACT_SHARDING=dp)")
    ap.add_argument("--outdir", default=None)
    args = ap.parse_args()
    if args.opt:
        if args.outdir is None:
            args.outdir = os.path.join(os.path.dirname(ARTIFACT_DIR),
                                       "dryrun_opt")
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            cell_id = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
            path = os.path.join(args.outdir or ARTIFACT_DIR, cell_id + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] {cell_id}: cached, skipping")
                continue
            try:
                if args.opt:
                    apply_opt(arch)
                run_cell(arch, shape_name, multi, art_dir=args.outdir)
            except Exception as e:
                traceback.print_exc()
                failures.append((cell_id, repr(e)))
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILED cells:")
        for cid, err in failures:
            print(f"  {cid}: {err[:200]}")
        raise SystemExit(1)
    print("\n[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
