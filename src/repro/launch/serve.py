"""Serving driver: batched requests through the continuous-batching engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --requests 8 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs as cfgs
from repro.models import init_params
from repro.serving import Request, ServingEngine

__all__ = ["main"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=sorted(cfgs.ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = cfgs.get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_len=args.max_len)
    rng = jax.random.PRNGKey(1)
    t0 = time.time()
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 8 + (rid % 3) * 4
        prompt = list(map(int, jax.random.randint(
            k, (plen,), 0, cfg.vocab_size)))
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new))
    done = engine.run_to_completion()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"[serve] rid={r.rid} prompt_len={len(r.prompt)} -> "
              f"{r.out_tokens}")
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s, {args.slots} slots, "
          f"continuous batching)")


if __name__ == "__main__":
    main()
