"""Training driver: data pipeline + sharded train step + fault tolerance.

CPU-runnable end to end with ``--smoke`` configs (the examples train a
~100M model for a few hundred steps); the same driver lowers unchanged on
the production mesh (see dryrun.py for the no-hardware path).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.checkpoint import CheckpointManager
from repro.data import MarkovTask
from repro.distributed.fault import FaultTolerantRunner
from repro.models import LM, init_params
from repro.optim import adamw, warmup_cosine
from repro.train import make_train_step

__all__ = ["train_loop", "main"]


def train_loop(arch: str, *, smoke: bool = True, steps: int = 100,
               batch: int = 8, seq: int = 64, ckpt_dir: str | None = None,
               peak_lr: float = 3e-3, accum: int = 1, log_every: int = 10,
               seed: int = 0, fault_hook=None):
    cfg = cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch)
    model = LM(cfg)
    task = MarkovTask(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=seed)
    lr = lambda s: warmup_cosine(s, peak_lr=peak_lr, warmup_steps=steps // 10 + 1,
                                 total_steps=steps)
    opt = adamw(lr)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, accum_steps=accum),
                      donate_argnums=(0, 1))

    losses: list[float] = []

    def one_step(state, step):
        params, opt_state = state
        batch_t = task.batch(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch_t,
                                             jnp.asarray(step, jnp.int32))
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train {arch}] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics.get('lr', 0)):.2e}")
        return (params, opt_state)

    state = (params, opt_state)
    if ckpt_dir is not None:
        manager = CheckpointManager(ckpt_dir, keep=3)
        runner = FaultTolerantRunner(one_step, manager,
                                     checkpoint_every=max(steps // 4, 10))
        start = manager.latest_step() or 0
        if start:
            start, state = manager.restore_latest(state)
            print(f"[train {arch}] resumed from step {start}")
        state, report = runner.run(state, start, steps - start,
                                   fault_hook=fault_hook)
        print(f"[train {arch}] done: {report.steps_run} steps, "
              f"{report.failures_recovered} recoveries, "
              f"{report.checkpoints_written} checkpoints")
    else:
        for step in range(steps):
            state = one_step(state, step)
    return state, losses, task


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=sorted(cfgs.ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    t0 = time.time()
    _, losses, task = train_loop(args.arch, smoke=args.smoke, steps=args.steps,
                                 batch=args.batch, seq=args.seq,
                                 ckpt_dir=args.ckpt_dir, accum=args.accum,
                                 peak_lr=args.lr)
    print(f"[train] first loss {losses[0]:.3f} -> last {losses[-1]:.3f} "
          f"(markov entropy floor {task.entropy_floor_nats:.3f} nats) "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
