"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count *before* first jax use).
"""

from __future__ import annotations

import jax

from repro.distributed.compat import make_auto_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "TP"]

TP = 16  # model-parallel extent of one v5e pod row


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ``data`` carries batch/FSDP, ``model`` carries TP/EP, ``pod``
    carries cross-pod data parallelism (batch + gradient reduction only, so
    per-chip memory is pod-count invariant — elastic over pods).
    """
    if multi_pod:
        return make_auto_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_auto_mesh((16, 16), ("data", "model"))


def make_test_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices the test process has."""
    return make_auto_mesh(shape, axes)
