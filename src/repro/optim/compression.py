"""Int8 error-feedback gradient compression for the cross-pod link.

At 2+ pods the gradient all-reduce crosses the slow inter-pod boundary —
the training-time analogue of the paper's conversion bottleneck: data must
cross an expensive interface before compute can proceed.  Error-feedback
quantization (Seide et al. 2014; Karimireddy et al. 2019) cuts those bytes
4x vs fp32 (2x vs bf16) while the residual state keeps the *long-run*
gradient unbiased.

Usage inside a shard_map'd train step (see repro/train/steps.py):

    scale = ef_scale(g, res)                        # per-tensor fp32 scalars
    scale = jax.tree.map(lambda s: jax.lax.pmax(s, "pod"), scale)
    q, scale, res = ef_compress(g, res, scale=scale)
    q = jax.lax.psum(q.astype(jnp.int16), "pod")   # 2 pods: |sum| <= 254
    g = ef_decompress(q, scale) / n_pods

Sharing the quantization scale across the reducing axis (the pmax — one
scalar collective per tensor) matters: if each pod quantizes with its own
scale but the sum is dequantized with an averaged one, the mismatch never
enters the residual and the long-run mean stays biased.  With a shared
scale every pod's dequantization is exact w.r.t. what it sent, so the
error-feedback guarantee holds across the link.

The wire payload is the int8/int16 tensor — 2-4x smaller than the bf16
all-reduce it replaces; §Perf quantifies the collective-term saving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "ef_scale", "ef_compress", "ef_decompress"]

_QMAX = 127.0


def ef_init(grads):
    """Residual (error-feedback) state: one fp32 tensor per gradient."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_scale(grads, residuals):
    """Per-tensor quantization scales for the feedback-corrected gradient.

    Callers reducing across an axis should pmax these before passing them
    back via ``ef_compress(..., scale=)`` so all participants quantize and
    dequantize on the same grid."""
    return jax.tree_util.tree_map(
        lambda g, r: jnp.maximum(
            jnp.max(jnp.abs(g.astype(jnp.float32) + r)), 1e-20) / _QMAX,
        grads, residuals)


def _compress_one(g: jax.Array, res: jax.Array, scale: jax.Array | None):
    x = g.astype(jnp.float32) + res
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)
    new_res = x - q.astype(jnp.float32) * scale
    return q, scale, new_res


def ef_compress(grads, residuals, scale=None):
    """tree of grads -> (int8 tree, scale tree, new residual tree).

    ``scale``: optional externally-agreed scale tree (e.g. pmax'd across
    the reducing axis); defaults to the local per-tensor scale."""
    if scale is None:
        flat = jax.tree_util.tree_map(
            lambda g, r: _compress_one(g, r, None), grads, residuals)
    else:
        flat = jax.tree_util.tree_map(_compress_one, grads, residuals, scale)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1), pick(2)


def ef_decompress(q_tree, scale_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)
