"""Optimizer interface: pure (init, update) pairs over param pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "global_norm_clip", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) ->
    (updates, new_state, metrics).  Updates are *deltas* added to params."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]


def global_norm_clip(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)
