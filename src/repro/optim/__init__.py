"""Optimizers and distributed-optimization tricks (no optax dependency).

  adamw       — AdamW with fp32 state and global-norm clipping
  adafactor   — factored second moment; the >=70B default (state ~ O(r+c))
  schedules   — linear-warmup cosine decay
  compression — int8 error-feedback gradient compression (cross-pod link)
"""

from repro.optim.adafactor import adafactor
from repro.optim.adamw import adamw
from repro.optim.base import Optimizer, apply_updates, global_norm_clip
from repro.optim.compression import ef_compress, ef_decompress, ef_init, ef_scale
from repro.optim.schedules import warmup_cosine

__all__ = ["Optimizer", "adamw", "adafactor", "warmup_cosine",
           "apply_updates", "global_norm_clip", "ef_init", "ef_compress",
           "ef_decompress", "ef_scale"]
