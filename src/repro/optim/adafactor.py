"""Adafactor (Shazeer & Stern 2018): factored second moment, no momentum.

The optimizer-state footprint is O(rows + cols) per matrix instead of
O(rows * cols) — the difference between DeepSeek-V3-671B training state
fitting 16 GB/chip and needing ~16 GB/chip for Adam moments alone
(DESIGN.md §5).  Update-RMS clipping (d=1.0) replaces global-norm clipping.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

__all__ = ["adafactor"]


def adafactor(lr: Callable | float, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor \
            and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree_util.tree_map(one, params,
                                            is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                u = g * (jax.lax.rsqrt(vr / jnp.maximum(denom, eps))[..., None]
                         * jax.lax.rsqrt(vc)[..., None, :])
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                ns = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            upd = -lr_t * u
            if weight_decay:
                upd = upd - lr_t * weight_decay * p.astype(jnp.float32)
            return upd, ns

        flat = jax.tree_util.tree_map(one, grads, state["v"], params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda x: x[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"v": pick(1)}, {"lr": lr_t}

    return Optimizer(init=init, update=update)
