"""AdamW (decoupled weight decay), fp32 moments, schedule-aware."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, global_norm_clip

__all__ = ["adamw"]


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step):
        if clip_norm:
            grads, gn = global_norm_clip(grads, clip_norm)
        else:
            gn = jnp.zeros(())
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = -(lr_t * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                          + weight_decay * p.astype(jnp.float32)))
            return u, m, v

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda x: x[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}, {"grad_norm": gn,
                                                       "lr": lr_t}

    return Optimizer(init=init, update=update)
