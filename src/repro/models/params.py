"""Parameter templates: shapes, sharding, and init — one source of truth.

Every block kind declares its parameters once as ``ParamSpec``s; from the
same template tree we derive (a) real initialized arrays for smoke tests and
examples, (b) ``ShapeDtypeStruct`` stand-ins for the no-allocation dry-run,
(c) ``PartitionSpec`` trees for pjit, and (d) exact parameter counts for the
roofline's MODEL_FLOPS = 6·N·D term.

Sharding convention (mesh axes ``pod``/``data``/``model``):
  * vocab tables shard the (padded) vocab dim over ``model``;
  * attention/MLP follow Megatron TP: column-parallel in, row-parallel out;
  * MoE experts shard the expert dim over ``model`` (EP) or each expert's
    ffn dim (TP) per ``MoEConfig.shard_mode``;
  * recurrent-family inner widths shard over ``model`` when divisible,
    else replicate (xlstm-125m deliberately replicates — DP-only is the
    right call at 125M; see DESIGN.md).
Parameters are always replicated over ``pod`` and ``data`` (ZeRO-1 shards
*optimizer state*, not params — see repro.optim).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["ParamSpec", "model_templates", "init_params", "param_shape_structs",
           "param_pspecs", "param_counts"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    pspec: tuple[Any, ...]
    init: str = "fan_in"     # fan_in | normal02 | zeros | ones | lru_lambda
    dtype: str | None = None  # override config.param_dtype


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# --- per-kind templates --------------------------------------------------------


def _norm(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), "ones")


def _mlp_templates(cfg: ModelConfig, dense: bool) -> dict[str, ParamSpec]:
    d = cfg.d_model
    if cfg.moe is not None and not dense:
        m = cfg.moe
        fe, fs = m.d_expert, (m.d_shared or m.d_expert) * max(m.n_shared, 1)
        if m.shard_mode == "ep":
            ep = lambda *s: ("model",) + (None,) * (len(s) - 1)
        else:  # tp: shard each expert's ffn dim
            ep = lambda *s: (None, None, "model") if len(s) == 3 else (None,)
        t = {
            "router": ParamSpec((d, m.n_routed), (None, None), "normal02"),
            "we_in": ParamSpec((m.n_routed, d, fe), ep(m.n_routed, d, fe)),
            "we_gate": ParamSpec((m.n_routed, d, fe), ep(m.n_routed, d, fe)),
            "we_out": ParamSpec(
                (m.n_routed, fe, d),
                ("model", None, None) if m.shard_mode == "ep" else (None, "model", None)),
        }
        if m.n_shared:
            t.update({
                "ws_in": ParamSpec((d, fs), (None, "model")),
                "ws_gate": ParamSpec((d, fs), (None, "model")),
                "ws_out": ParamSpec((fs, d), ("model", None)),
            })
        return t
    f = cfg.d_ff
    t = {"w_in": ParamSpec((d, f), (None, "model")),
         "w_out": ParamSpec((f, d), ("model", None))}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        t["w_gate"] = ParamSpec((d, f), (None, "model"))
    return t


def _attn_templates(cfg: ModelConfig, cross: bool = False) -> dict[str, ParamSpec]:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cfg.mla is not None and not cross:
        m = cfg.mla
        return {
            "w_dq": ParamSpec((d, m.q_lora_rank), (None, None)),
            "q_norm": _norm(m.q_lora_rank),
            "w_uq": ParamSpec((m.q_lora_rank, h * m.qk_head_dim), (None, "model")),
            "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim), (None, None)),
            "kv_norm": _norm(m.kv_lora_rank),
            "w_uk": ParamSpec((m.kv_lora_rank, h * m.qk_nope_head_dim), (None, "model")),
            "w_uv": ParamSpec((m.kv_lora_rank, h * m.v_head_dim), (None, "model")),
            "w_o": ParamSpec((h * m.v_head_dim, d), ("model", None)),
        }
    t = {
        "w_q": ParamSpec((d, h * hd), (None, "model")),
        "w_k": ParamSpec((d, hk * hd), (None, "model")),
        "w_v": ParamSpec((d, hk * hd), (None, "model")),
        "w_o": ParamSpec((h * hd, d), ("model", None)),
    }
    if cfg.attn_bias:
        t.update({
            "b_q": ParamSpec((h * hd,), ("model",), "zeros"),
            "b_k": ParamSpec((hk * hd,), ("model",), "zeros"),
            "b_v": ParamSpec((hk * hd,), ("model",), "zeros"),
        })
    return t


def _rglru_templates(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d = cfg.d_model
    w = cfg.lru_width or d
    shard = "model" if w % 128 == 0 else None
    return {
        "w_y": ParamSpec((d, w), (None, shard)),
        "w_x": ParamSpec((d, w), (None, shard)),
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, shard), "normal02"),
        "conv_b": ParamSpec((w,), (shard,), "zeros"),
        "w_a": ParamSpec((w, w), (None, shard)),
        "b_a": ParamSpec((w,), (shard,), "zeros"),
        "w_i": ParamSpec((w, w), (None, shard)),
        "b_i": ParamSpec((w,), (shard,), "zeros"),
        "lam": ParamSpec((w,), (shard,), "lru_lambda"),
        "w_ro": ParamSpec((w, d), (shard, None)),
    }


def _mlstm_templates(cfg: ModelConfig) -> dict[str, ParamSpec]:
    """xLSTM mLSTM block: pf=2 up-projection, conv, matrix-memory cell."""
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    rep = None  # 125M-class: replicate inner mats, DP-only (DESIGN.md)
    return {
        "w_up": ParamSpec((d, di), (None, rep)),
        "w_gate_up": ParamSpec((d, di), (None, rep)),
        "conv_w": ParamSpec((cfg.conv1d_width, di), (None, rep), "normal02"),
        "conv_b": ParamSpec((di,), (rep,), "zeros"),
        "w_q": ParamSpec((di, di), (None, rep)),
        "w_k": ParamSpec((di, di), (None, rep)),
        "w_v": ParamSpec((di, di), (None, rep)),
        "w_if": ParamSpec((di, h), (None, None), "normal02"),
        "b_if": ParamSpec((h,), (None,), "zeros"),
        "w_ff": ParamSpec((di, h), (None, None), "normal02"),
        "b_ff": ParamSpec((h,), (None,), "zeros"),
        "skip_scale": ParamSpec((di,), (rep,), "ones"),
        "w_down": ParamSpec((di, d), (rep, None)),
    }


def _slstm_templates(cfg: ModelConfig) -> dict[str, ParamSpec]:
    """xLSTM sLSTM block: scalar memory, block-diagonal recurrence, pf-4/3 FFN."""
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    f = ((4 * d // 3) + 127) // 128 * 128
    t: dict[str, ParamSpec] = {}
    for g in ("i", "f", "z", "o"):
        t[f"w_{g}"] = ParamSpec((d, d), (None, None))
        t[f"r_{g}"] = ParamSpec((h, hd, hd), (None, None, None))
        t[f"b_{g}"] = ParamSpec((d,), (None,), "zeros")
    t["ffn_in"] = ParamSpec((d, f), (None, "model" if f % 128 == 0 else None))
    t["ffn_gate"] = ParamSpec((d, f), (None, "model" if f % 128 == 0 else None))
    t["ffn_out"] = ParamSpec((f, d), ("model" if f % 128 == 0 else None, None))
    return t


def block_templates(cfg: ModelConfig, kind: str, dense: bool,
                    cross_attn: bool = False) -> dict[str, Any]:
    d = cfg.d_model
    if kind == "attn":
        t = {"ln1": _norm(d), "attn": _attn_templates(cfg),
             "ln2": _norm(d), "mlp": _mlp_templates(cfg, dense)}
        if cross_attn:
            t["ln_x"] = _norm(d)
            t["xattn"] = _attn_templates(cfg, cross=True)
        return t
    if kind == "rglru":
        return {"ln1": _norm(d), "rglru": _rglru_templates(cfg),
                "ln2": _norm(d), "mlp": _mlp_templates(cfg, True)}
    if kind == "mlstm":
        return {"ln1": _norm(d), "mlstm": _mlstm_templates(cfg)}
    if kind == "slstm":
        return {"ln1": _norm(d), "slstm": _slstm_templates(cfg), "ln2": _norm(d)}
    raise ValueError(f"unknown block kind {kind!r}")


# --- whole-model templates -------------------------------------------------------


def _super_block_templates(cfg: ModelConfig, cross_attn: bool = False) -> dict:
    """One scanned repetition of the pattern: keys '<i>_<kind>'."""
    return {f"{i}_{kind}": block_templates(cfg, kind, dense=False,
                                           cross_attn=cross_attn)
            for i, kind in enumerate(cfg.layer_plan().super_block)}


def _stack(tree: dict, n: int) -> dict:
    """Prepend a scan dim of length n to every leaf spec."""
    def add(spec: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + spec.shape, (None,) + spec.pspec, spec.init,
                         spec.dtype)
    return jax.tree_util.tree_map(add, tree, is_leaf=_is_spec)


def model_templates(cfg: ModelConfig) -> dict:
    plan = cfg.layer_plan()
    d, vp = cfg.d_model, cfg.padded_vocab
    t: dict[str, Any] = {
        "embed": ParamSpec((vp, d), ("model", None), "normal02"),
        "final_norm": _norm(d),
    }
    if not cfg.tie_embeddings:
        t["head"] = ParamSpec((vp, d), ("model", None), "normal02")
    cross = cfg.is_encdec
    if plan.prefix:
        t["prefix"] = {f"{i}_{k}": block_templates(cfg, k, dense=True, cross_attn=cross)
                       for i, k in enumerate(plan.prefix)}
    if plan.n_super:
        t["stack"] = _stack(_super_block_templates(cfg, cross), plan.n_super)
    if plan.tail:
        t["tail"] = {f"{i}_{k}": block_templates(cfg, k, dense=False, cross_attn=cross)
                     for i, k in enumerate(plan.tail)}
    if cfg.is_encdec:
        enc = {f"0_attn": block_templates(cfg, "attn", dense=True)}
        t["encoder"] = {"stack": _stack(enc, cfg.encoder_layers),
                        "final_norm": _norm(d)}
    if cfg.frontend is not None:
        t["frontend"] = {"adapter": ParamSpec((d, d), (None, None))}
    return t


# --- materialization ---------------------------------------------------------------


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "lru_lambda":
        # a = exp(-8 * softplus(lam)) in [0.9, 0.999] at init (Griffin A.2)
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               minval=0.9 ** 2, maxval=0.999 ** 2)
        lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * 8.0)))
        return lam.astype(dtype)
    if spec.init == "normal02":
        return (0.02 * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)
    # fan_in: std = 1/sqrt(fan_in); fan_in = second-to-last dim (or last for 1-D)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    tree = model_templates(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.param_dtype)
    out = [_init_leaf(spec, k, jnp.dtype(spec.dtype) if spec.dtype else dtype)
           for spec, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shape_structs(cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype) if s.dtype else dtype),
        model_templates(cfg), is_leaf=_is_spec)


def param_pspecs(cfg: ModelConfig, *, fsdp_size: int = 0,
                 tp_size: int = 16) -> dict:
    """PartitionSpec tree; optionally adds FSDP sharding over ``data``.

    ``fsdp_size`` > 0 (set for >=30B configs) shards each parameter's
    largest still-unsharded, divisible dimension over the ``data`` axis —
    ZeRO-3-style: XLA SPMD then all-gathers each layer's weights just
    before use inside the scan (persistent footprint /= fsdp_size).
    The scan-stack dim (dim 0 of stacked params) is never FSDP-sharded.
    FSDP never spans ``pod`` so per-chip shards are pod-count invariant
    (elastic scaling across pods, DESIGN.md §5).
    """
    def to_pspec(spec: ParamSpec, stacked_hint: bool) -> P:
        axes = list(spec.pspec)
        # drop TP axes the mesh can't divide (e.g. tiny test meshes)
        for i, ax in enumerate(axes):
            if ax == "model" and spec.shape[i] % tp_size != 0:
                axes[i] = None
        if fsdp_size:
            start = 1 if stacked_hint else 0
            cands = [i for i in range(start, len(axes))
                     if axes[i] is None and spec.shape[i] % fsdp_size == 0
                     and spec.shape[i] >= 4 * fsdp_size]
            if cands:
                best = max(cands, key=lambda i: spec.shape[i])
                axes[best] = "data"
        return P(*axes)

    def walk(node, under_stack: bool):
        if _is_spec(node):
            return to_pspec(node, under_stack)
        return {k: walk(v, under_stack or k == "stack") for k, v in node.items()}

    return walk(model_templates(cfg), False)


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts from the template tree."""
    total = 0
    inactive = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            model_templates(cfg), is_leaf=_is_spec)[0]:
        n = int(math.prod(spec.shape))
        total += n
        if cfg.moe is not None:
            keys = [getattr(p, "key", None) for p in path]
            if any(k in ("we_in", "we_gate", "we_out") for k in keys):
                inactive += n * (cfg.moe.n_routed - cfg.moe.top_k) // cfg.moe.n_routed
    return total, total - inactive
