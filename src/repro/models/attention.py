"""Attention: GQA (optional sliding window / bias / partial rotary) and
DeepSeek-V3 MLA, each with full-sequence and single-token-decode paths.

Full-sequence attention is computed in query chunks (``lax.scan``) so the
score tensor peak is (B, H, q_chunk, S) instead of (B, H, S, S) — the
difference between fitting and not fitting 32k prefill in HBM.  On real TPU
the Pallas flash kernel (``repro.kernels.local_attention``) replaces the
chunked-jnp path; the jnp path is what the dry-run lowers (DESIGN.md §3).

Decode caches:
  GQA:  k/v (B, Hkv, S_max, hd), written at ``pos`` per step.  Windowed
        layers use a ring buffer of size ``window`` plus a slot->absolute
        position buffer, so a 500k-token stream needs O(window) memory.
  MLA:  the compressed (B, S_max, kv_rank + rope_dim) latent cache; decode
        uses the *absorbed* form (score via W_uk-absorbed queries against
        the latent cache) so neither K nor V is ever materialized.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, rope

__all__ = ["gqa_full", "gqa_decode", "mla_full", "mla_decode",
           "init_gqa_cache", "init_mla_cache"]

_NEG = -1.0e30


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: int, q_pos0: int, k_pos0: int,
                  q_chunk: int = 256, softmax_scale: float | None = None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,Sk,Hkv,hd).  Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    nc = s // q_chunk if (s % q_chunk == 0 and s > q_chunk) else 1
    qc = s // nc
    qr = q.reshape(b, nc, qc, hkv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kt = k.transpose(0, 2, 1, 3)  # (B,Hkv,Sk,hd)
    vt = v.transpose(0, 2, 1, 3)
    kpos = k_pos0 + jnp.arange(sk)

    def chunk(ci, qb):
        # qb: (B,Hkv,G,qc,hd)
        s_ = jnp.einsum("bkgqd,bksd->bkgqs", qb.astype(jnp.float32),
                        kt.astype(jnp.float32)) * scale
        qpos = q_pos0 + ci * qc + jnp.arange(qc)
        m = jnp.ones((qc, sk), bool)
        if causal:
            m &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            m &= (qpos[:, None] - kpos[None, :]) < window
        s_ = jnp.where(m, s_, _NEG)
        p = jax.nn.softmax(s_, axis=-1)
        return jnp.einsum("bkgqs,bksd->bkgqd", p, vt.astype(jnp.float32))

    # checkpoint: backward re-forms each chunk's (bq x Sk) score block from
    # q/k instead of saving softmax residuals for every chunk — the chunked
    # equivalent of flash attention's recompute (O(S) not O(S^2) memory).
    out = jax.lax.scan(
        jax.checkpoint(lambda _, xs: (None, chunk(xs[0], xs[1]))), None,
        (jnp.arange(nc), qr))[1]
    hdv = v.shape[-1]  # v head dim can differ from q/k head dim (MLA)
    # (nc,B,Hkv,G,qc,hdv) -> (B,S,H,hdv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, hdv)
    return out.astype(q.dtype)


# --- GQA -----------------------------------------------------------------------


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array,
         kv_x: jax.Array | None = None):
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    kv_in = x if kv_x is None else kv_x
    q = x @ p["w_q"].astype(x.dtype)
    k = kv_in @ p["w_k"].astype(x.dtype)
    v = kv_in @ p["w_v"].astype(x.dtype)
    if "b_q" in p:
        q = q + p["b_q"].astype(x.dtype)
        k = k + p["b_k"].astype(x.dtype)
        v = v + p["b_v"].astype(x.dtype)
    q = q.reshape(*x.shape[:-1], h, hd)
    k = k.reshape(*kv_in.shape[:-1], hk, hd)
    v = v.reshape(*kv_in.shape[:-1], hk, hd)
    return q, k, v


def gqa_full(cfg: ModelConfig, p: dict, x: jax.Array, *, pos0: int = 0,
             window: int = 0, causal: bool = True,
             cross_kv: jax.Array | None = None, use_rope: bool = True,
             return_cache: bool = False):
    """Full-sequence attention. cross_kv: encoder memory for cross-attention."""
    q, k, v = _qkv(cfg, p, x, cross_kv)
    if use_rope and cross_kv is None:
        s = x.shape[1]
        qpos = pos0 + jnp.arange(s)
        q = rope(q, qpos, theta=cfg.rope_theta, pct=cfg.rope_pct)
        k = rope(k, qpos, theta=cfg.rope_theta, pct=cfg.rope_pct)
    out = _sdpa_chunked(q, k, v, causal=causal and cross_kv is None,
                        window=window if cross_kv is None else 0,
                        q_pos0=pos0, k_pos0=pos0 if cross_kv is None else 0)
    y = out.reshape(*x.shape[:-1], -1) @ p["w_o"].astype(x.dtype)
    if return_cache:
        return y, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    return y


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    hk, hd = cfg.n_kv_heads, cfg.head_dim_
    size = min(window, max_len) if window > 0 else max_len
    dt = cfg.activation_dtype
    return {
        "k": jnp.zeros((batch, hk, size, hd), dt),
        "v": jnp.zeros((batch, hk, size, hd), dt),
        # per-lane ring map: slot -> absolute position (continuous batching:
        # every batch lane decodes at its own position)
        "slot_pos": jnp.full((batch, size), -1, jnp.int32),
    }


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array, *, window: int = 0,
               cross_kv: jax.Array | None = None):
    """One-token decode. x: (B, 1, D); pos: (B,) int32 per-lane positions."""
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q, k, v = _qkv(cfg, p, x)
    q = rope(q, pos[:, None], theta=cfg.rope_theta, pct=cfg.rope_pct)
    k = rope(k, pos[:, None], theta=cfg.rope_theta, pct=cfg.rope_pct)
    size = cache["k"].shape[2]
    slot = pos % size if window > 0 else jnp.minimum(pos, size - 1)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(hk)[None, :]
    ck = cache["k"].at[bi, hi, slot[:, None], :].set(
        k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bi, hi, slot[:, None], :].set(
        v[:, 0].astype(cache["v"].dtype))
    spos = cache["slot_pos"].at[jnp.arange(b), slot].set(pos)
    new_cache = {"k": ck, "v": cv, "slot_pos": spos}

    if cross_kv is not None:
        raise NotImplementedError("use gqa_decode_cross for cross attention")

    qh = q.reshape(b, 1, hk, h // hk, hd).transpose(0, 2, 3, 1, 4)
    s_ = jnp.einsum("bkgqd,bksd->bkgqs", qh.astype(jnp.float32),
                    ck.astype(jnp.float32)) * hd ** -0.5
    valid = spos >= 0                               # (B, size)
    if window > 0:
        valid &= (pos[:, None] - spos) < window
    else:
        valid &= spos <= pos[:, None]
    s_ = jnp.where(valid[:, None, None, None, :], s_, _NEG)
    pw = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", pw, cv.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd).astype(x.dtype)
    return out @ p["w_o"].astype(x.dtype), new_cache


def gqa_decode_cross(cfg: ModelConfig, p: dict, x: jax.Array,
                     enc_out: jax.Array):
    """Cross-attention during decode: static encoder memory, no cache update."""
    y = gqa_full(cfg, p, x, cross_kv=enc_out, causal=False, use_rope=False)
    return y


# --- MLA (DeepSeek-V3) ------------------------------------------------------------


def _mla_q(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    h = cfg.n_heads
    cq = rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(*x.shape[:-1], h, m.qk_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    dkv = x @ p["w_dkv"].astype(x.dtype)           # (B,S,Rkv+rope)
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]  # (B,S,1,rope)
    k_rope = rope(k_rope, positions, theta=cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_full(cfg: ModelConfig, p: dict, x: jax.Array, *, pos0: int = 0,
             return_cache: bool = False):
    m = cfg.mla
    h = cfg.n_heads
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = (c_kv @ p["w_uk"].astype(x.dtype)).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(x.dtype)).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))], axis=-1)
    out = _sdpa_chunked(q, k, v, causal=True, window=0, q_pos0=pos0, k_pos0=pos0,
                        softmax_scale=m.qk_head_dim ** -0.5)
    y = out.reshape(b, s, -1) @ p["w_o"].astype(x.dtype)
    if return_cache:
        return y, jnp.concatenate([c_kv, k_rope], axis=-1)  # (B,S,Rkv+rope)
    return y


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {"latent": jnp.zeros((batch, max_len, cfg.mla.cache_dim),
                                cfg.activation_dtype)}


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array):
    """Absorbed-matrix MLA decode: attention runs entirely in latent space.
    pos: (B,) int32 per-lane positions."""
    m = cfg.mla
    h = cfg.n_heads
    b = x.shape[0]
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])       # (B,1,H,*)
    c_kv, k_rope = _mla_latent(cfg, p, x, pos[:, None])
    new_lat = jnp.concatenate([c_kv, k_rope], axis=-1)     # (B,1,D_lat)
    lat = cache["latent"].at[jnp.arange(b), pos, :].set(
        new_lat[:, 0].astype(cache["latent"].dtype))
    c_all, r_all = lat[..., :m.kv_lora_rank], lat[..., m.kv_lora_rank:]

    # absorb W_uk into the query: q_eff[b,h,r] = sum_d q_nope[b,h,d] W_uk[r, h*d]
    wuk = p["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk)
    scores = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                         c_all.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                           r_all.astype(jnp.float32))) * m.qk_head_dim ** -0.5
    valid = jnp.arange(lat.shape[1])[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, :], scores, _NEG)
    pw = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", pw, c_all.astype(jnp.float32))
    wuv = p["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(x.dtype), wuv)
    y = out.reshape(b, 1, h * m.v_head_dim) @ p["w_o"].astype(x.dtype)
    return y, {"latent": lat}
