"""Model configuration — one dataclass covering all assigned arch families.

Families: dense decoder LMs (GQA), MoE (shared+routed top-k), MLA+MoE
(DeepSeek-V3), hybrid recurrent (RG-LRU + local attention), xLSTM
(sLSTM/mLSTM), encoder-decoder (Seamless), and VLM/audio-frontend stubs.

Layer stacking: each layer has a *kind* (``attn``, ``moe``, ``rglru``,
``mlstm``, ``slstm``).  The stack is compiled as
``prefix (unrolled) + scan over repeated pattern super-blocks + tail
(unrolled)`` so the HLO stays small for 60–96-layer models while still
supporting mixed patterns like RecurrentGemma's 2:1 recurrent:attention.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp

__all__ = ["MoEConfig", "MLAConfig", "ModelConfig", "LayerPlan"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_expert: int                 # intermediate size of each routed expert
    n_shared: int = 0
    d_shared: int | None = None   # intermediate size of each shared expert
    router_noise: float = 0.0
    aux_loss_coef: float = 0.001
    # "ep"  -> experts sharded over the model axis (one expert group/chip)
    # "tp"  -> every expert's ffn dim sharded over the model axis
    shard_mode: str = "ep"
    # tokens per expert = ceil(S * top_k * capacity_factor / n_routed);
    # overflow tokens fall through the residual (standard dropped-token MoE)
    capacity_factor: float = 1.25

    @property
    def d_shared_total(self) -> int:
        return (self.d_shared or self.d_expert) * self.n_shared


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def cache_dim(self) -> int:
        """Per-token decode cache: compressed kv + shared rope key."""
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # audio|dense|hybrid|vlm|moe|ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # layer stack: kinds cycled from ``pattern``; ``dense_prefix`` forces the
    # first k layers to plain attn+dense-mlp (DeepSeek-V3's first 3 layers).
    pattern: tuple[str, ...] = ("attn",)
    dense_prefix: int = 0
    # attention
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0         # stablelm-2 uses 25% partial rotary
    local_window: int = 0         # >0: sliding-window for ``attn`` layers
    mla: MLAConfig | None = None
    # mlp
    mlp_kind: str = "swiglu"      # swiglu|relu2|geglu|none
    moe: MoEConfig | None = None
    # recurrent families
    lru_width: int | None = None  # RG-LRU state width (default d_model)
    conv1d_width: int = 4
    # encoder-decoder
    encoder_layers: int = 0
    # frontends (stubs: input_specs provide precomputed embeddings)
    frontend: str | None = None   # audio|vision|None
    frontend_tokens: int = 0      # e.g. 576 vision patches
    # numerics
    dtype: str = "bfloat16"       # activations
    param_dtype: str = "float32"  # parameters (bf16 + Adafactor for >=30B)
    norm_eps: float = 1.0e-6
    tie_embeddings: bool = False
    logit_chunks: int = 8         # chunked CE to bound the logits peak
    vocab_pad_multiple: int = 2048  # pad tables so "model"-axis sharding divides

    # ----- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kinds(self) -> tuple[str, ...]:
        kinds = ["attn"] * self.dense_prefix
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(self.pattern[i % len(self.pattern)])
            i += 1
        return tuple(kinds[: self.n_layers])

    def layer_plan(self) -> "LayerPlan":
        return LayerPlan.build(self.layer_kinds(), self.pattern, self.dense_prefix)

    def uses_moe_at(self, layer_idx: int) -> bool:
        return self.moe is not None and layer_idx >= self.dense_prefix

    # Parameter counts are computed from the actual template tree — see
    # ``repro.models.params.param_counts`` — so they can never drift from
    # the implementation.


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """How the layer list compiles to prefix + scanned super-blocks + tail."""

    prefix: tuple[str, ...]        # unrolled leading layer kinds
    super_block: tuple[str, ...]   # one scanned repetition
    n_super: int                   # scan length
    tail: tuple[str, ...]          # unrolled trailing layer kinds

    @staticmethod
    def build(kinds: Sequence[str], pattern: Sequence[str],
              dense_prefix: int) -> "LayerPlan":
        prefix = tuple(kinds[:dense_prefix])
        body = tuple(kinds[dense_prefix:])
        plen = len(pattern)
        n_super = len(body) // plen
        tail = body[n_super * plen:]
        return LayerPlan(prefix=prefix, super_block=tuple(pattern),
                         n_super=n_super, tail=tail)

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.n_super * len(self.super_block) + len(self.tail)
