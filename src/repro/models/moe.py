"""Mixture-of-Experts layer: shared + routed top-k, sort-based dispatch.

Dispatch strategy (chosen for SPMD friendliness at 256 experts / 512 chips):
tokens are routed *per batch row* — assignments are sorted along the
unsharded (S*k) axis, positions-within-expert computed from segment starts,
and tokens beyond each expert's capacity C = ceil(S*k*cf / E) are dropped
(standard capacity-factor semantics).  The gathered (B, E, C, D) activation
is then sharding-constrained to (data, model, ..., ...) so XLA lowers the
expert exchange as an all-to-all on the ``model`` axis — expert parallelism
— rather than an all-gather of the full token set.

``shard_mode='ep'``  : expert dim over ``model``  (DeepSeek-V3: 256 experts).
``shard_mode='tp'``  : each expert's ffn dim over ``model``
                       (Qwen2-MoE: 60 experts don't divide 16 chips).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig

__all__ = ["moe_apply"]


def _router(cfg: ModelConfig, p: dict, x2d: jax.Array):
    """x2d: (B, S, D) -> (probs (B,S,k), idx (B,S,k), aux_loss)."""
    m = cfg.moe
    logits = (x2d @ p["router"].astype(x2d.dtype)).astype(jnp.float32)
    if cfg.name.startswith("deepseek"):
        scores = jax.nn.sigmoid(logits)             # DeepSeek-V3 sigmoid router
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top, idx = jax.lax.top_k(scores, m.top_k)
    top = top / jnp.maximum(top.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    e = m.n_routed
    assign = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(-2)   # (B,S,E)
    frac = assign.mean(axis=(0, 1)) / m.top_k
    prob = jax.nn.softmax(logits, axis=-1).mean(axis=(0, 1))
    aux = m.aux_loss_coef * e * jnp.sum(frac * prob)
    return top, idx, aux


def _shared(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if "ws_in" not in p:
        return jnp.zeros_like(x)
    h = jax.nn.silu(x @ p["ws_in"].astype(x.dtype)) * (x @ p["ws_gate"].astype(x.dtype))
    return h @ p["ws_out"].astype(x.dtype)


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_routed, m.top_k
    cap = max(-(-s * k * int(4 * m.capacity_factor) // (4 * e)), 1)

    top, idx, aux = _router(cfg, p, x)

    # ---- build per-row dispatch (all along unsharded axes) ----------------
    flat_e = idx.reshape(b, s * k)                        # expert of each slot
    flat_t = jnp.repeat(jnp.arange(s), k)[None, :]        # token of each slot
    flat_t = jnp.broadcast_to(flat_t, (b, s * k))
    flat_p = top.reshape(b, s * k)

    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, -1)
    st = jnp.take_along_axis(flat_t, order, -1)
    sp = jnp.take_along_axis(flat_p, order, -1)
    # position within expert segment
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    pos_in_e = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, se, -1)
    keep = pos_in_e < cap
    dest = jnp.where(keep, se * cap + pos_in_e, e * cap)  # overflow -> slot E*C

    # token index per (expert, capacity) slot; S = padding token
    slot_tok = jnp.full((b, e * cap + 1), s, jnp.int32)
    slot_w = jnp.zeros((b, e * cap + 1), jnp.float32)
    rows = jnp.arange(b)[:, None]
    slot_tok = slot_tok.at[rows, dest].set(jnp.where(keep, st, s).astype(jnp.int32))
    slot_w = slot_w.at[rows, dest].set(jnp.where(keep, sp, 0.0))
    slot_tok, slot_w = slot_tok[:, :-1], slot_w[:, :-1]

    # ---- gather -> expert compute -> combine ------------------------------
    xp = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    gx = jnp.take_along_axis(xp, slot_tok[..., None], axis=1)  # (B, E*C, D)
    gx = gx.reshape(b, e, cap, d)
    if m.shard_mode == "ep":
        gx = constrain(gx, ("pod", "data"), "model", None, None)

    w_in = p["we_in"].astype(x.dtype)
    w_gate = p["we_gate"].astype(x.dtype)
    w_out = p["we_out"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", gx, w_in))
    h = h * jnp.einsum("becd,edf->becf", gx, w_gate)
    eo = jnp.einsum("becf,efd->becd", h, w_out)            # (B,E,C,D)

    eo = eo.reshape(b, e * cap, d) * slot_w[..., None].astype(x.dtype)
    out = jnp.zeros((b, s + 1, d), x.dtype)
    out = out.at[rows, slot_tok].add(eo)[:, :s, :]

    return out + _shared(cfg, p, x), aux
