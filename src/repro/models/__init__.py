"""Model substrate: configs, parameter templates, and the LM assembly."""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig
from repro.models.model import LM
from repro.models.params import (
    init_params,
    param_counts,
    param_pspecs,
    param_shape_structs,
)

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "LM", "init_params",
           "param_counts", "param_pspecs", "param_shape_structs"]
